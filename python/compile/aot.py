"""AOT bridge: lower the L2 model to HLO *text* artifacts for rust.

Emits one artifact per shape variant plus a manifest the rust runtime
uses to pick the smallest variant that fits the live query set
(``rust/src/runtime/artifacts.rs``).  Interchange format is HLO text —
NOT a serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import build_tables

# (batch B, states m, bins N).  m=16 covers Q1 (11 states) and Q3/Q4 up
# to n=14; m=32 covers Q2 (15 states) with batch room for multi-query
# sweeps; the small variant keeps single-pattern model builds cheap.
VARIANTS = [
    (2, 8, 128),
    (4, 16, 256),
    (4, 16, 512),
    (8, 32, 512),
]

MANIFEST = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(batch: int, m: int, nbins: int) -> str:
    t = jax.ShapeDtypeStruct((batch, m, m), jnp.float32)
    r = jax.ShapeDtypeStruct((batch, m), jnp.float32)
    lowered = jax.jit(
        lambda tt, rr: build_tables(tt, rr, nbins)
    ).lower(t, r)
    return to_hlo_text(lowered)


def artifact_name(batch: int, m: int, nbins: int) -> str:
    return f"utility_B{batch}_M{m}_N{nbins}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=None,
        help="comma list like 2x8x128,4x16x256 (default: built-ins)",
    )
    args = ap.parse_args()

    variants = VARIANTS
    if args.variants:
        variants = [
            tuple(int(x) for x in v.split("x"))
            for v in args.variants.split(",")
        ]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for batch, m, nbins in variants:
        text = lower_variant(batch, m, nbins)
        name = artifact_name(batch, m, nbins)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{batch} {m} {nbins} {name}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, MANIFEST), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest ({len(manifest_lines)} variants)")


if __name__ == "__main__":
    main()
