"""L2 — the pSPICE model-builder compute graph (JAX, build-time only).

``build_tables`` is the paper's model builder math (§III-C) as one fused
``lax.scan`` whose body is the L1 Pallas kernel:

* completion probability  ``C[j] = T^(j+1) . e_m``           (Eq. 3),
* remaining processing time ``TAU[j]`` via Markov-reward value iteration
  (Bellman backup, §III-C-2),

for a *batch* of patterns at once, one row per remaining-events *bin*.
The rust coordinator composes the learned one-event chain ``(T, r)`` into
a per-bin chain ``(T_bs, r_bs)`` (exact, by Chapman-Kolmogorov doubling)
before invoking the compiled artifact, and assembles the utility table
``UT = w_q * scale(C) / scale(TAU)`` from the outputs (§III-C-3).

This module is lowered once by ``aot.py`` to HLO text; python never runs
on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.step import markov_step

__all__ = ["build_tables", "initial_carry"]


def initial_carry(batch, m):
    """Boundary conditions of the recurrences.

    ``c_0 = e_m`` (a PM already in the final state has completed with
    probability 1) and ``tau_0 = 0`` (no events left => no work left).
    """
    c0 = jnp.zeros((batch, m), jnp.float32).at[:, m - 1].set(1.0)
    tau0 = jnp.zeros((batch, m), jnp.float32)
    return c0, tau0


@functools.partial(jax.jit, static_argnames=("nbins",))
def build_tables(t, r, nbins):
    """Scan the fused kernel ``nbins`` times, stacking every bin row.

    Args:
      t:     ``(B, m, m)`` float32 — per-bin transition matrices (already
             composed for the bin size by the caller).
      r:     ``(B, m)``    float32 — per-bin expected reward per state.
      nbins: static int — number of bins (= ceil(ws / bs)).

    Returns:
      ``(C, TAU)`` of shape ``(nbins, B, m)``; row ``j`` corresponds to
      ``j+1`` bins remaining in the window.
    """
    batch, m = r.shape
    c0, tau0 = initial_carry(batch, m)

    def body(carry, _):
        c, tau = carry
        c2, tau2 = markov_step(t, r, c, tau)
        return (c2, tau2), (c2, tau2)

    (_, _), (c_rows, tau_rows) = jax.lax.scan(
        body, (c0, tau0), xs=None, length=nbins
    )
    return c_rows, tau_rows
