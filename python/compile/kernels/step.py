"""L1 — Pallas kernel: fused Markov model-builder step.

This is the compute hot-spot of the pSPICE model builder (paper §III-C).
One step advances, for a *batch* of patterns, the coupled recurrences

    c'   = T @ c          -- completion probability   (paper Eq. 3)
    tau' = r + T @ tau    -- Markov-reward value iteration (Bellman step)

where, per pattern ``b``:

* ``T[b]``   is the (bin-composed) ``m x m`` state-transition matrix,
* ``r[b]``   is the expected per-bin reward (processing time) per state,
* ``c[b]``   is the completion-probability vector given ``j`` bins remain,
* ``tau[b]`` is the expected remaining processing time per state.

The kernel fuses both matvecs and the reward add into one pass so ``T`` is
read exactly once per step.  The grid iterates over the batch dimension;
each grid step keeps the full ``m x m`` tile of ``T`` and both carry
vectors resident in VMEM (see DESIGN.md §Hardware-Adaptation for the TPU
mapping and VMEM/MXU estimate).

``interpret=True`` is mandatory here: the artifacts are executed by the
CPU PJRT client from rust, which cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["markov_step"]


def _step_kernel(t_ref, r_ref, c_ref, tau_ref, c_out_ref, tau_out_ref):
    """Fused step body for one pattern of the batch.

    Refs are blocked to a single batch element: ``t_ref`` is ``(1, m, m)``,
    the vector refs are ``(1, m)``.
    """
    t = t_ref[0]
    c = c_ref[0]
    tau = tau_ref[0]
    # Single read of T feeds both matvecs; jnp.dot maps onto the MXU on a
    # real TPU (f32 here; bf16-able, see DESIGN.md).
    c_out_ref[0, :] = jnp.dot(t, c, preferred_element_type=jnp.float32)
    tau_out_ref[0, :] = r_ref[0] + jnp.dot(
        t, tau, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def markov_step(t, r, c, tau):
    """Advance the batched model-builder recurrence by one bin.

    Args:
      t:   ``(B, m, m)`` float32 — per-pattern transition matrices.
      r:   ``(B, m)``    float32 — per-pattern expected bin reward.
      c:   ``(B, m)``    float32 — completion-probability carry.
      tau: ``(B, m)``    float32 — remaining-processing-time carry.

    Returns:
      ``(c', tau')`` with the same shapes as ``c`` / ``tau``.
    """
    batch, m = c.shape
    assert t.shape == (batch, m, m), (t.shape, (batch, m, m))
    assert r.shape == (batch, m)

    vec = pl.BlockSpec((1, m), lambda b: (b, 0))
    return pl.pallas_call(
        _step_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, m, m), lambda b: (b, 0, 0)),
            vec,
            vec,
            vec,
        ],
        out_specs=[vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((batch, m), jnp.float32),
            jax.ShapeDtypeStruct((batch, m), jnp.float32),
        ],
        interpret=True,
    )(t, r, c, tau)
