"""Pure-jnp correctness oracle for the L1 kernel and the L2 scan.

Everything here is the mathematically obvious formulation; the Pallas
kernel (`step.py`) and the fused scan (`model.py`) must agree with these
to float tolerance.  The rust fallback engine
(``rust/src/runtime/fallback.rs``) implements the same recurrences and is
differentially tested against the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["markov_step_ref", "build_tables_ref", "completion_via_power"]


def markov_step_ref(t, r, c, tau):
    """Reference for kernels.step.markov_step (batched einsum form)."""
    c_next = jnp.einsum("bij,bj->bi", t, c)
    tau_next = r + jnp.einsum("bij,bj->bi", t, tau)
    return c_next, tau_next


def build_tables_ref(t, r, nbins):
    """Reference for model.build_tables: plain python loop, stacked rows.

    Row ``j`` (0-based) of the outputs corresponds to ``j+1`` bins
    remaining in the window.
    """
    batch, m = r.shape
    c = jnp.zeros((batch, m), jnp.float32).at[:, m - 1].set(1.0)
    tau = jnp.zeros((batch, m), jnp.float32)
    c_rows, tau_rows = [], []
    for _ in range(nbins):
        c, tau = markov_step_ref(t, r, c, tau)
        c_rows.append(c)
        tau_rows.append(tau)
    return jnp.stack(c_rows), jnp.stack(tau_rows)


def completion_via_power(t_single, nsteps):
    """Completion probability by direct matrix power: ``T^j(:, m-1)``.

    Independent check of paper Eq. 3 for a single pattern: returns an
    ``(nsteps, m)`` array whose row ``j`` is ``T^(j+1)[:, m-1]``.
    """
    m = t_single.shape[0]
    acc = jnp.eye(m, dtype=jnp.float32)
    rows = []
    for _ in range(nsteps):
        acc = acc @ t_single
        rows.append(acc[:, m - 1])
    return jnp.stack(rows)
