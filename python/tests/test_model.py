"""L2 correctness: fused scan vs oracle + analytic Markov facts."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import (
    build_tables_ref,
    completion_via_power,
)
from compile.model import build_tables, initial_carry
from .test_kernel import random_chain

hypothesis.settings.register_profile(
    "ci-model", deadline=None, max_examples=15, derandomize=True
)
hypothesis.settings.load_profile("ci-model")


@st.composite
def scan_case(draw):
    batch = draw(st.integers(min_value=1, max_value=4))
    m = draw(st.integers(min_value=2, max_value=16))
    nbins = draw(st.integers(min_value=1, max_value=24))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return batch, m, nbins, seed


@hypothesis.given(scan_case())
def test_scan_matches_ref(case):
    batch, m, nbins, seed = case
    rng = np.random.default_rng(seed)
    t = jnp.array(random_chain(rng, batch, m))
    r = jnp.array(rng.uniform(0.1, 2.0, size=(batch, m)).astype(np.float32))
    c_s, tau_s = build_tables(t, r, nbins)
    c_r, tau_r = build_tables_ref(t, r, nbins)
    np.testing.assert_allclose(np.asarray(c_s), np.asarray(c_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tau_s), np.asarray(tau_r), rtol=1e-4, atol=1e-5)


@hypothesis.given(scan_case())
def test_completion_equals_matrix_power(case):
    """Paper Eq. 3: C[j, b, i] == (T_b)^(j+1) [i, m-1]."""
    batch, m, nbins, seed = case
    rng = np.random.default_rng(seed)
    t = jnp.array(random_chain(rng, batch, m))
    r = jnp.zeros((batch, m), jnp.float32)
    c_s, _ = build_tables(t, r, nbins)
    for b in range(batch):
        power = completion_via_power(t[b], nbins)
        np.testing.assert_allclose(
            np.asarray(c_s)[:, b, :], np.asarray(power), rtol=1e-4, atol=1e-5
        )


def test_completion_monotone_in_remaining_events():
    """More remaining events can only raise absorbing-completion prob."""
    rng = np.random.default_rng(123)
    t = jnp.array(random_chain(rng, 3, 8))
    r = jnp.ones((3, 8), jnp.float32)
    c_s, _ = build_tables(t, r, 64)
    c = np.asarray(c_s)
    assert (np.diff(c, axis=0) >= -1e-6).all()


def test_tau_zero_reward_is_zero():
    rng = np.random.default_rng(5)
    t = jnp.array(random_chain(rng, 2, 6))
    r = jnp.zeros((2, 6), jnp.float32)
    _, tau = build_tables(t, r, 32)
    np.testing.assert_allclose(np.asarray(tau), 0.0, atol=1e-7)


def test_initial_carry():
    c0, tau0 = initial_carry(3, 5)
    expect = np.zeros((3, 5), np.float32)
    expect[:, 4] = 1.0
    np.testing.assert_allclose(np.asarray(c0), expect)
    np.testing.assert_allclose(np.asarray(tau0), 0.0)


def test_absorbing_row_probabilities_bounded():
    rng = np.random.default_rng(42)
    t = jnp.array(random_chain(rng, 2, 10))
    r = jnp.ones((2, 10), jnp.float32)
    c_s, tau_s = build_tables(t, r, 50)
    c = np.asarray(c_s)
    assert (c >= -1e-6).all() and (c <= 1 + 1e-5).all()
    assert (np.asarray(tau_s) >= -1e-6).all()
