"""L1 correctness: Pallas fused step vs pure-jnp oracle.

Hypothesis sweeps shapes and transition-matrix structure; every case
asserts allclose against ``ref.markov_step_ref``.  This is the CORE
correctness signal for the kernel that ends up inside the AOT artifact.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import markov_step_ref
from compile.kernels.step import markov_step

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("ci")


def random_chain(rng, batch, m, absorbing=True):
    """Random row-stochastic matrices (optionally absorbing final state)."""
    t = rng.gamma(1.0, 1.0, size=(batch, m, m)).astype(np.float32)
    t /= t.sum(axis=2, keepdims=True)
    if absorbing:
        t[:, m - 1, :] = 0.0
        t[:, m - 1, m - 1] = 1.0
    return t


@st.composite
def step_case(draw):
    batch = draw(st.integers(min_value=1, max_value=8))
    m = draw(st.integers(min_value=2, max_value=32))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return batch, m, seed


@hypothesis.given(step_case())
def test_step_matches_ref(case):
    batch, m, seed = case
    rng = np.random.default_rng(seed)
    t = random_chain(rng, batch, m)
    r = rng.uniform(0.0, 5.0, size=(batch, m)).astype(np.float32)
    c = rng.uniform(0.0, 1.0, size=(batch, m)).astype(np.float32)
    tau = rng.uniform(0.0, 10.0, size=(batch, m)).astype(np.float32)

    c_k, tau_k = markov_step(jnp.array(t), jnp.array(r), jnp.array(c), jnp.array(tau))
    c_r, tau_r = markov_step_ref(jnp.array(t), jnp.array(r), jnp.array(c), jnp.array(tau))
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tau_k), np.asarray(tau_r), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("batch,m", [(1, 2), (2, 8), (4, 16), (8, 32), (3, 5)])
def test_step_shapes(batch, m):
    rng = np.random.default_rng(7)
    t = random_chain(rng, batch, m)
    r = np.ones((batch, m), np.float32)
    c = np.zeros((batch, m), np.float32)
    c[:, m - 1] = 1.0
    tau = np.zeros((batch, m), np.float32)
    c2, tau2 = markov_step(jnp.array(t), jnp.array(r), jnp.array(c), jnp.array(tau))
    assert c2.shape == (batch, m)
    assert tau2.shape == (batch, m)
    # absorbing final state: completion prob from final state stays 1
    np.testing.assert_allclose(np.asarray(c2)[:, m - 1], 1.0, rtol=1e-6)


def test_step_identity_chain():
    """T = I: c never changes, tau accumulates exactly r per step."""
    batch, m = 2, 4
    t = np.broadcast_to(np.eye(m, dtype=np.float32), (batch, m, m)).copy()
    r = np.full((batch, m), 0.25, np.float32)
    c = np.zeros((batch, m), np.float32)
    c[:, m - 1] = 1.0
    tau = np.zeros((batch, m), np.float32)
    for step in range(1, 5):
        c, tau = markov_step(jnp.array(t), jnp.array(r), jnp.array(c), jnp.array(tau))
    np.testing.assert_allclose(np.asarray(tau), 4 * 0.25, rtol=1e-6)
    expect = np.zeros((batch, m), np.float32)
    expect[:, m - 1] = 1.0
    np.testing.assert_allclose(np.asarray(c), expect, rtol=1e-6)


def test_step_deterministic_advance():
    """Deterministic chain s_i -> s_{i+1}: completion prob is a shift."""
    m = 4
    t = np.zeros((1, m, m), np.float32)
    for i in range(m - 1):
        t[0, i, i + 1] = 1.0
    t[0, m - 1, m - 1] = 1.0
    r = np.zeros((1, m), np.float32)
    c = np.zeros((1, m), np.float32)
    c[0, m - 1] = 1.0
    tau = np.zeros((1, m), np.float32)
    # after j steps, states within j hops of the end have completed
    c1, _ = markov_step(jnp.array(t), jnp.array(r), jnp.array(c), jnp.array(tau))
    np.testing.assert_allclose(np.asarray(c1)[0], [0, 0, 1, 1], atol=1e-6)
    c2, _ = markov_step(jnp.array(t), jnp.array(r), c1, jnp.array(tau))
    np.testing.assert_allclose(np.asarray(c2)[0], [0, 1, 1, 1], atol=1e-6)
