"""AOT emission smoke: HLO text is produced, parseable-looking, and the
lowered computation matches the eager model on a fixed input."""

import os

import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.model import build_tables
from .test_kernel import random_chain


def test_lower_variant_produces_hlo_text():
    text = aot.lower_variant(2, 8, 16)
    assert "HloModule" in text
    assert "ENTRY" in text
    # scan lowers to a while loop — the artifact must not be fully unrolled
    assert "while" in text


def test_artifact_name_format():
    assert aot.artifact_name(4, 16, 256) == "utility_B4_M16_N256.hlo.txt"


def test_emission_writes_manifest(tmp_path):
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--variants", "2x8x16"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest == ["2 8 16 utility_B2_M8_N16.hlo.txt"]
    assert (tmp_path / "utility_B2_M8_N16.hlo.txt").exists()


def test_variants_cover_builtin_queries():
    """Q1 needs m=11, Q2 m=15, Q3/Q4 small n: variants must cover them."""
    ms = sorted({m for (_, m, _) in aot.VARIANTS})
    assert any(m >= 11 for m in ms)
    assert any(m >= 15 for m in ms)
    # multi-query experiments (fig 8) need batch >= 2
    assert any(b >= 2 for (b, _, _) in aot.VARIANTS)
