//! Offline stand-in for the `log` facade crate: levels, the [`Log`]
//! trait, a global logger slot, and the `error!`..`trace!` macros —
//! exactly the surface `pspice::util::logger` and the engine modules
//! use, nothing more.

use std::cmp::Ordering;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// error conditions
    Error = 1,
    /// warnings
    Warn,
    /// informational
    Info,
    /// debugging detail
    Debug,
    /// very verbose tracing
    Trace,
}

/// Level filter: [`Level`] plus `Off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// disable all logging
    Off = 0,
    /// error and up
    Error,
    /// warn and up
    Warn,
    /// info and up
    Info,
    /// debug and up
    Debug,
    /// everything
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a log call site.
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// The record's severity.
    pub fn level(&self) -> Level {
        self.level
    }

    /// The record's target (module path by default).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// Call-site metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    /// Severity shortcut.
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    /// Target shortcut.
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    /// The formatted message.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    /// Would this logger accept a record with this metadata?
    fn enabled(&self, metadata: &Metadata) -> bool;
    /// Handle one record.
    fn log(&self, record: &Record);
    /// Flush buffered output.
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, AtomicOrdering::Relaxed);
}

/// The global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(AtomicOrdering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger (a no-op logger before `set_logger`).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    logger().log(&record);
}

/// Log at an explicit level.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

/// Log at `Error` level.
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at `Warn` level.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at `Info` level.
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at `Debug` level.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at `Trace` level.
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Trace <= LevelFilter::Info));
        assert!(!(Level::Debug <= LevelFilter::Off));
    }

    #[test]
    fn macros_respect_max_level() {
        // no logger installed: must not panic either way
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        trace!("filtered out {}", 2);
    }
}
