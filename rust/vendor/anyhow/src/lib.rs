//! Offline stand-in for the `anyhow` crate: just the API surface this
//! workspace uses — [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! The real crate keeps the source error alive for downcasting; this
//! stand-in flattens the chain to strings at conversion time, which is
//! all the pspice crates need (they only ever display errors, plain
//! `{e}` or alternate `{e:#}` with the full context chain).

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error.  Like `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` conversion below stays coherent.
pub struct Error {
    /// Outermost context first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain like anyhow does
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").starts_with("outer"));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert_eq!(format!("{}", f(20).unwrap_err()), "x too big: 20");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fallthrough 1");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
