//! Tail a growing CSV file of events (the archive format of
//! [`crate::datasets::csv`]): complete appended lines become events,
//! stamped with the clock time at which the poll observed them — in
//! the real-time plane an external event "arrives" when the engine
//! first sees it.

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::events::Event;

use super::source::{Source, SourcePoll};

/// A [`Source`] following a file that another process appends to.
pub struct FileTailSource {
    path: PathBuf,
    reader: BufReader<File>,
    /// partial trailing line carried across polls until its newline
    /// shows up
    carry: String,
    /// lines that failed to parse (skipped, counted)
    pub bad_lines: u64,
}

impl FileTailSource {
    /// Tail `path` from the beginning of the file.
    pub fn from_start(path: &Path) -> crate::Result<Self> {
        let file = File::open(path)
            .with_context(|| format!("tailing {}", path.display()))?;
        Ok(FileTailSource {
            path: path.to_path_buf(),
            reader: BufReader::new(file),
            carry: String::new(),
            bad_lines: 0,
        })
    }

    /// Tail `path` from its current end (only new appends are read).
    pub fn from_end(path: &Path) -> crate::Result<Self> {
        let mut s = Self::from_start(path)?;
        s.reader
            .seek(SeekFrom::End(0))
            .with_context(|| format!("seeking {}", s.path.display()))?;
        Ok(s)
    }

    /// The tailed path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Parse the carried line if it is complete; returns the event.
    fn take_complete_line(&mut self) -> Option<Event> {
        if !self.carry.ends_with('\n') {
            return None;
        }
        let line = std::mem::take(&mut self.carry);
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with("seq,") {
            return None; // blank / comment / archive header
        }
        match Event::parse_csv(t) {
            Ok(e) => Some(e),
            Err(_) => {
                self.bad_lines += 1;
                None
            }
        }
    }
}

impl Source for FileTailSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        while pushed < max {
            match self.reader.read_line(&mut self.carry) {
                // EOF *for now* — the file may keep growing; no
                // schedule to report
                Ok(0) => break,
                Ok(_) => {
                    if let Some(e) = self.take_complete_line() {
                        sink.push((e, now_ns));
                        pushed += 1;
                    }
                    // incomplete trailing line stays in `carry` and is
                    // finished by a later poll; skipped lines just loop
                }
                Err(err) => {
                    if err.kind() == std::io::ErrorKind::Interrupted {
                        continue;
                    }
                    break;
                }
            }
        }
        if pushed > 0 {
            SourcePoll::Ready
        } else {
            SourcePoll::Pending {
                next_arrival_ns: None,
            }
        }
    }

    fn name(&self) -> &'static str {
        "tail"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pspice_tail_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tails_appended_lines_and_skips_garbage() {
        let path = tmp("grow.csv");
        std::fs::write(&path, "seq,ts_ms,etype,a0,a1,a2,a3,a4,a5\n").unwrap();
        let mut src = FileTailSource::from_start(&path).unwrap();
        let mut sink = Vec::new();

        assert_eq!(
            src.poll_into(10.0, 16, &mut sink),
            SourcePoll::Pending { next_arrival_ns: None },
            "header only: nothing to emit"
        );

        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "0,100,1,3.5").unwrap();
        writeln!(f, "this is not an event").unwrap();
        writeln!(f, "1,200,2,4.5,1").unwrap();
        // and one incomplete line with no newline yet
        write!(f, "2,300,").unwrap();
        f.flush().unwrap();

        assert_eq!(src.poll_into(50.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 2);
        assert_eq!(sink[0].0.seq, 0);
        assert_eq!(sink[0].0.etype, 1);
        assert_eq!(sink[0].1, 50.0, "arrival = observation time");
        assert_eq!(sink[1].0.seq, 1);
        assert_eq!(src.bad_lines, 1);

        // completing the partial line makes it parseable
        writeln!(f, "0,9.0").unwrap();
        f.flush().unwrap();
        sink.clear();
        assert_eq!(src.poll_into(60.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 2);
        assert_eq!(sink[0].0.ts_ms, 300);
        assert_eq!(sink[0].0.etype, 0);
        assert_eq!(sink[0].0.attr(0), 9.0);
        assert_eq!(src.name(), "tail");
    }
}
