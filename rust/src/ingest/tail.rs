//! Tail a growing CSV file of events (the archive format of
//! [`crate::datasets::csv`]): complete appended lines become events,
//! stamped with the clock time at which the poll observed them — in
//! the real-time plane an external event "arrives" when the engine
//! first sees it.
//!
//! Log-style rotation is survived: when a poll hits EOF the source
//! stats the path, and if the file shrank below what was already
//! consumed (in-place truncation) or its inode changed (`rename(2)`
//! rotation), it reopens the path from the start of the new file and
//! counts the event in [`FileTailSource::rotations`].

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::events::Event;

use super::source::{Source, SourcePoll};

/// A [`Source`] following a file that another process appends to.
pub struct FileTailSource {
    path: PathBuf,
    reader: BufReader<File>,
    /// bytes consumed from the currently-open file — a stat length
    /// below this means the file was truncated under us
    consumed: u64,
    /// inode of the currently-open file (0 on non-unix targets, where
    /// only the truncation check applies)
    ino: u64,
    /// partial trailing line carried across polls until its newline
    /// shows up
    carry: String,
    /// lines that failed to parse (skipped, counted)
    pub bad_lines: u64,
    /// rotations/truncations detected (path reopened from its start)
    pub rotations: u64,
}

/// Inode identity of an open file, for rotation detection.
fn ino_of(file: &File) -> u64 {
    #[cfg(unix)]
    {
        use std::os::unix::fs::MetadataExt;
        file.metadata().map(|m| m.ino()).unwrap_or(0)
    }
    #[cfg(not(unix))]
    {
        0
    }
}

impl FileTailSource {
    /// Tail `path` from the beginning of the file.
    pub fn from_start(path: &Path) -> crate::Result<Self> {
        let file = File::open(path)
            .with_context(|| format!("tailing {}", path.display()))?;
        let ino = ino_of(&file);
        Ok(FileTailSource {
            path: path.to_path_buf(),
            reader: BufReader::new(file),
            consumed: 0,
            ino,
            carry: String::new(),
            bad_lines: 0,
            rotations: 0,
        })
    }

    /// Tail `path` from its current end (only new appends are read).
    pub fn from_end(path: &Path) -> crate::Result<Self> {
        let mut s = Self::from_start(path)?;
        s.consumed = s
            .reader
            .seek(SeekFrom::End(0))
            .with_context(|| format!("seeking {}", s.path.display()))?;
        Ok(s)
    }

    /// The tailed path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// At EOF: was the path rotated (new inode) or truncated (stat
    /// length below what we already consumed)?  If so reopen from the
    /// start of the new file.  Returns whether a reopen happened.
    fn reopen_if_rotated(&mut self) -> bool {
        let Ok(meta) = std::fs::metadata(&self.path) else {
            // mid-rotation: the new file may not exist yet — keep the
            // old handle and try again on a later poll
            return false;
        };
        let truncated = meta.len() < self.consumed;
        if !truncated && !self.inode_changed(&meta) {
            return false;
        }
        let Ok(file) = File::open(&self.path) else {
            return false; // raced with the rotator; retry next poll
        };
        self.ino = ino_of(&file);
        self.reader = BufReader::new(file);
        self.consumed = 0;
        // a partial line carried from the old file can never complete
        self.carry.clear();
        self.rotations += 1;
        true
    }

    #[cfg(unix)]
    fn inode_changed(&self, meta: &std::fs::Metadata) -> bool {
        use std::os::unix::fs::MetadataExt;
        meta.ino() != self.ino
    }

    #[cfg(not(unix))]
    fn inode_changed(&self, _meta: &std::fs::Metadata) -> bool {
        false
    }

    /// Parse the carried line if it is complete; returns the event.
    fn take_complete_line(&mut self) -> Option<Event> {
        if !self.carry.ends_with('\n') {
            return None;
        }
        let line = std::mem::take(&mut self.carry);
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with("seq,") {
            return None; // blank / comment / archive header
        }
        match Event::parse_csv(t) {
            Ok(e) => Some(e),
            Err(_) => {
                self.bad_lines += 1;
                None
            }
        }
    }
}

impl Source for FileTailSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        while pushed < max {
            match self.reader.read_line(&mut self.carry) {
                // EOF *for now* — the file may keep growing, or may
                // just have been rotated/truncated under us
                Ok(0) => {
                    if self.reopen_if_rotated() {
                        continue; // fresh file: read it from the start
                    }
                    break;
                }
                Ok(n) => {
                    self.consumed += n as u64;
                    if let Some(e) = self.take_complete_line() {
                        sink.push((e, now_ns));
                        pushed += 1;
                    }
                    // incomplete trailing line stays in `carry` and is
                    // finished by a later poll; skipped lines just loop
                }
                Err(err) => {
                    if err.kind() == std::io::ErrorKind::Interrupted {
                        continue;
                    }
                    break;
                }
            }
        }
        if pushed > 0 {
            SourcePoll::Ready
        } else {
            SourcePoll::Pending {
                next_arrival_ns: None,
            }
        }
    }

    fn name(&self) -> &'static str {
        "tail"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pspice_tail_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tails_appended_lines_and_skips_garbage() {
        let path = tmp("grow.csv");
        std::fs::write(&path, "seq,ts_ms,etype,a0,a1,a2,a3,a4,a5\n").unwrap();
        let mut src = FileTailSource::from_start(&path).unwrap();
        let mut sink = Vec::new();

        assert_eq!(
            src.poll_into(10.0, 16, &mut sink),
            SourcePoll::Pending { next_arrival_ns: None },
            "header only: nothing to emit"
        );

        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "0,100,1,3.5").unwrap();
        writeln!(f, "this is not an event").unwrap();
        writeln!(f, "1,200,2,4.5,1").unwrap();
        // and one incomplete line with no newline yet
        write!(f, "2,300,").unwrap();
        f.flush().unwrap();

        assert_eq!(src.poll_into(50.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 2);
        assert_eq!(sink[0].0.seq, 0);
        assert_eq!(sink[0].0.etype, 1);
        assert_eq!(sink[0].1, 50.0, "arrival = observation time");
        assert_eq!(sink[1].0.seq, 1);
        assert_eq!(src.bad_lines, 1);

        // completing the partial line makes it parseable
        writeln!(f, "0,9.0").unwrap();
        f.flush().unwrap();
        sink.clear();
        assert_eq!(src.poll_into(60.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 2);
        assert_eq!(sink[0].0.ts_ms, 300);
        assert_eq!(sink[0].0.etype, 0);
        assert_eq!(sink[0].0.attr(0), 9.0);
        assert_eq!(src.name(), "tail");
    }

    #[test]
    #[cfg(unix)] // the rename-rotation leg needs inode identity
    fn detects_rotation_and_truncation_and_reopens() {
        let path = tmp("rotate.csv");
        std::fs::write(&path, "0,100,1,3.5\n").unwrap();
        let mut src = FileTailSource::from_start(&path).unwrap();
        let mut sink = Vec::new();

        assert_eq!(src.poll_into(10.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 0);
        assert_eq!(src.rotations, 0);

        // rename(2)-style rotation: a new file (new inode) slides in
        // under the tailed path; the old handle only ever sees EOF
        let staged = tmp("rotate.csv.new");
        std::fs::write(&staged, "10,500,1,1.5\n").unwrap();
        std::fs::rename(&staged, &path).unwrap();
        sink.clear();
        assert_eq!(
            src.poll_into(20.0, 16, &mut sink),
            SourcePoll::Ready,
            "rotation detected at EOF, new file read from its start"
        );
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 10);
        assert_eq!(sink[0].0.ts_ms, 500);
        assert_eq!(src.rotations, 1);

        // in-place truncation: same inode, but the file shrank below
        // what was already consumed
        std::fs::write(&path, "20,600,0,2\n").unwrap();
        sink.clear();
        assert_eq!(src.poll_into(30.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 20);
        assert_eq!(src.rotations, 2);

        // steady state: plain EOF on an unchanged file is not a
        // rotation, and appends still flow
        assert_eq!(
            src.poll_into(40.0, 16, &mut sink),
            SourcePoll::Pending { next_arrival_ns: None }
        );
        assert_eq!(src.rotations, 2);
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "21,700,1,8").unwrap();
        f.flush().unwrap();
        sink.clear();
        assert_eq!(src.poll_into(50.0, 16, &mut sink), SourcePoll::Ready);
        assert_eq!(sink[0].0.seq, 21);
        assert_eq!(src.bad_lines, 0);
    }
}
