//! Synthetic overload generators: deterministic arrival processes with
//! adversarial rate profiles, replaying a pool of real dataset events.
//!
//! Each generator integrates an instantaneous rate profile `r(t)`
//! (events per virtual ns): the next arrival is always
//! `t + 1/r(t)`, so the emitted inter-arrival gaps follow the profile
//! exactly and every run is reproducible — no RNG anywhere.  Emitted
//! events cycle through the supplied pool with their sequence numbers
//! and timestamps re-stamped to the *arrival* timeline (in the
//! real-time plane, an event's time is when it arrives), continuing
//! from a caller-supplied origin so windows see one monotonic stream
//! across a warm-up prefix and the generated load.

use crate::events::Event;

use super::source::{Source, SourcePoll};

/// Floor on the instantaneous rate so an adversarial profile can stall
/// arrivals but never divide by zero (one event per 10 virtual s).
const MIN_RATE_PER_NS: f64 = 1e-10;

/// An instantaneous target arrival rate over the run's timeline.
pub trait RateProfile: Send {
    /// Events per virtual nanosecond at time `t_ns`.
    fn rate_per_ns(&self, t_ns: f64) -> f64;

    /// Selector-style name for reports.
    fn name(&self) -> &'static str;
}

/// Square-wave bursts: `peak` rate for the first `burst_ns` of every
/// `period_ns`, `base` rate the rest of the time.
#[derive(Debug, Clone, Copy)]
pub struct Burst {
    /// quiet-phase rate (events/ns)
    pub base_per_ns: f64,
    /// burst-phase rate (events/ns)
    pub peak_per_ns: f64,
    /// full cycle length (ns)
    pub period_ns: f64,
    /// burst length at the start of each cycle (ns)
    pub burst_ns: f64,
}

impl Burst {
    /// Bursts expressed as multiples of a measured per-event capacity
    /// cost: `base_factor`/`peak_factor` are fractions of the maximum
    /// drain rate `1/capacity_ns` (1.0 = exactly saturating).
    pub fn from_capacity(
        capacity_ns: f64,
        base_factor: f64,
        peak_factor: f64,
        period_ns: f64,
        burst_ns: f64,
    ) -> Self {
        assert!(capacity_ns > 0.0 && period_ns > 0.0 && burst_ns <= period_ns);
        Burst {
            base_per_ns: base_factor / capacity_ns,
            peak_per_ns: peak_factor / capacity_ns,
            period_ns,
            burst_ns,
        }
    }
}

impl RateProfile for Burst {
    fn rate_per_ns(&self, t_ns: f64) -> f64 {
        let phase = t_ns.rem_euclid(self.period_ns);
        if phase < self.burst_ns {
            self.peak_per_ns
        } else {
            self.base_per_ns
        }
    }

    fn name(&self) -> &'static str {
        "burst"
    }
}

/// One flash crowd: ramp linearly from `base` to `peak` over
/// `ramp_ns`, hold the peak for `hold_ns`, decay linearly back over
/// `decay_ns`, then stay at `base`.
#[derive(Debug, Clone, Copy)]
pub struct FlashCrowd {
    /// steady-state rate before/after the crowd (events/ns)
    pub base_per_ns: f64,
    /// crowd peak rate (events/ns)
    pub peak_per_ns: f64,
    /// when the ramp starts (ns)
    pub start_ns: f64,
    /// ramp-up length (ns)
    pub ramp_ns: f64,
    /// plateau length (ns)
    pub hold_ns: f64,
    /// decay length (ns)
    pub decay_ns: f64,
}

impl FlashCrowd {
    /// Flash crowd expressed as multiples of the maximum drain rate
    /// `1/capacity_ns` (see [`Burst::from_capacity`]).
    pub fn from_capacity(
        capacity_ns: f64,
        base_factor: f64,
        peak_factor: f64,
        start_ns: f64,
        ramp_ns: f64,
        hold_ns: f64,
        decay_ns: f64,
    ) -> Self {
        assert!(capacity_ns > 0.0 && ramp_ns > 0.0 && decay_ns > 0.0);
        FlashCrowd {
            base_per_ns: base_factor / capacity_ns,
            peak_per_ns: peak_factor / capacity_ns,
            start_ns,
            ramp_ns,
            hold_ns,
            decay_ns,
        }
    }
}

impl RateProfile for FlashCrowd {
    fn rate_per_ns(&self, t_ns: f64) -> f64 {
        let t = t_ns - self.start_ns;
        if t < 0.0 {
            self.base_per_ns
        } else if t < self.ramp_ns {
            let f = t / self.ramp_ns;
            self.base_per_ns + f * (self.peak_per_ns - self.base_per_ns)
        } else if t < self.ramp_ns + self.hold_ns {
            self.peak_per_ns
        } else if t < self.ramp_ns + self.hold_ns + self.decay_ns {
            let f = (t - self.ramp_ns - self.hold_ns) / self.decay_ns;
            self.peak_per_ns + f * (self.base_per_ns - self.peak_per_ns)
        } else {
            self.base_per_ns
        }
    }

    fn name(&self) -> &'static str {
        "flashcrowd"
    }
}

/// Sinusoidal load: `mean + amplitude·sin(2πt/period)`, clamped below
/// by [`MIN_RATE_PER_NS`].  With `mean` slightly above capacity the
/// crests sustain genuine overload while the troughs let the queue
/// drain — the adversarial regime the CI smoke job replays.
#[derive(Debug, Clone, Copy)]
pub struct OscillatingRate {
    /// mean rate (events/ns)
    pub mean_per_ns: f64,
    /// oscillation amplitude (events/ns)
    pub amplitude_per_ns: f64,
    /// oscillation period (ns)
    pub period_ns: f64,
}

impl OscillatingRate {
    /// Oscillation expressed as multiples of the maximum drain rate
    /// `1/capacity_ns` (see [`Burst::from_capacity`]).
    pub fn from_capacity(
        capacity_ns: f64,
        mean_factor: f64,
        amplitude_factor: f64,
        period_ns: f64,
    ) -> Self {
        assert!(capacity_ns > 0.0 && period_ns > 0.0);
        OscillatingRate {
            mean_per_ns: mean_factor / capacity_ns,
            amplitude_per_ns: amplitude_factor / capacity_ns,
            period_ns,
        }
    }
}

impl RateProfile for OscillatingRate {
    fn rate_per_ns(&self, t_ns: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t_ns / self.period_ns;
        (self.mean_per_ns + self.amplitude_per_ns * phase.sin()).max(MIN_RATE_PER_NS)
    }

    fn name(&self) -> &'static str {
        "oscillate"
    }
}

/// A [`Source`] driving a pool of real events through a
/// [`RateProfile`].
pub struct SyntheticSource {
    pool: Vec<Event>,
    profile: Box<dyn RateProfile>,
    /// next pool slot to replay (cycles)
    pool_idx: usize,
    /// events emitted so far
    emitted: u64,
    /// stop after this many events (`u64::MAX` = run to the deadline)
    limit: u64,
    /// arrival instant of the next event (ns)
    next_arrival_ns: f64,
    /// re-stamped sequence numbers start here
    seq0: u64,
    /// re-stamped timestamps are `ts0_ns + arrival` (ns)
    ts0_ns: f64,
}

impl SyntheticSource {
    /// Generator replaying `pool` (cycling) on `profile`'s schedule,
    /// with arrivals starting at t=0 on the ingest timeline.
    /// Re-stamped events get sequence numbers `seq0, seq0+1, …` and
    /// timestamps `(ts0_ns + arrival_ns)/1e6` ms, so they extend
    /// whatever stream primed the operator.
    pub fn new(pool: Vec<Event>, profile: Box<dyn RateProfile>, seq0: u64, ts0_ns: f64) -> Self {
        assert!(!pool.is_empty(), "synthetic source needs a non-empty pool");
        SyntheticSource {
            pool,
            profile,
            pool_idx: 0,
            emitted: 0,
            limit: u64::MAX,
            next_arrival_ns: 0.0,
            seq0,
            ts0_ns,
        }
    }

    /// Cap the total number of emitted events.
    pub fn with_limit(mut self, limit: u64) -> Self {
        self.limit = limit;
        self
    }

    /// Events emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }
}

impl Source for SyntheticSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        while pushed < max {
            if self.emitted >= self.limit {
                return if pushed > 0 {
                    SourcePoll::Ready
                } else {
                    SourcePoll::Exhausted
                };
            }
            if self.next_arrival_ns > now_ns {
                return if pushed > 0 {
                    SourcePoll::Ready
                } else {
                    SourcePoll::Pending {
                        next_arrival_ns: Some(self.next_arrival_ns),
                    }
                };
            }
            let mut e = self.pool[self.pool_idx];
            self.pool_idx += 1;
            if self.pool_idx == self.pool.len() {
                self.pool_idx = 0;
            }
            e.seq = self.seq0 + self.emitted;
            e.ts_ms = ((self.ts0_ns + self.next_arrival_ns) / 1e6) as u64;
            sink.push((e, self.next_arrival_ns));
            self.emitted += 1;
            pushed += 1;
            let rate = self.profile.rate_per_ns(self.next_arrival_ns).max(MIN_RATE_PER_NS);
            self.next_arrival_ns += 1.0 / rate;
        }
        SourcePoll::Ready
    }

    fn name(&self) -> &'static str {
        self.profile.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Vec<Event> {
        (0..4).map(|i| Event::new(i, i, (i % 2) as u16, &[i as f64])).collect()
    }

    /// Drain every arrival up to `until_ns` and return the arrival times.
    fn arrivals(src: &mut SyntheticSource, until_ns: f64) -> Vec<f64> {
        let mut sink = Vec::new();
        loop {
            match src.poll_into(until_ns, 1_000, &mut sink) {
                SourcePoll::Ready => continue,
                _ => break,
            }
        }
        sink.iter().map(|&(_, a)| a).collect()
    }

    #[test]
    fn burst_profile_alternates_gap_lengths() {
        // capacity 100ns/event: quiet at 0.5x (gap 200), burst at 2x
        // (gap 50); 10µs period with a 2µs burst
        let prof = Burst::from_capacity(100.0, 0.5, 2.0, 10_000.0, 2_000.0);
        assert_eq!(prof.name(), "burst");
        let mut src = SyntheticSource::new(pool(), Box::new(prof), 0, 0.0);
        let at = arrivals(&mut src, 20_000.0);
        assert!(!at.is_empty());
        let mut bursty = 0usize;
        let mut quiet = 0usize;
        for w in at.windows(2) {
            let gap = w[1] - w[0];
            if (gap - 50.0).abs() < 1e-6 {
                bursty += 1;
            } else if (gap - 200.0).abs() < 1e-6 {
                quiet += 1;
            } else {
                panic!("unexpected gap {gap}");
            }
        }
        assert!(bursty > 0 && quiet > 0, "both phases must appear");
        // burst phase density: 2µs at gap 50 ≈ 40 events vs 8µs at gap
        // 200 ≈ 40 — roughly balanced counts, wildly different rates
        let rate_peak = 1.0 / 50.0;
        let rate_base = 1.0 / 200.0;
        assert!(rate_peak / rate_base > 3.9);
    }

    #[test]
    fn flash_crowd_ramps_and_decays() {
        let prof = FlashCrowd::from_capacity(100.0, 0.5, 2.0, 1_000.0, 1_000.0, 500.0, 1_000.0);
        assert_eq!(prof.rate_per_ns(0.0), 0.005);
        assert!((prof.rate_per_ns(1_500.0) - 0.0125).abs() < 1e-12, "mid-ramp");
        assert_eq!(prof.rate_per_ns(2_250.0), 0.02, "plateau");
        assert!((prof.rate_per_ns(3_000.0) - 0.0125).abs() < 1e-12, "mid-decay");
        assert_eq!(prof.rate_per_ns(10_000.0), 0.005, "back to base");
        // the emitted gaps shrink toward the peak then recover
        let mut src = SyntheticSource::new(pool(), Box::new(prof), 0, 0.0);
        let at = arrivals(&mut src, 5_000.0);
        let gaps: Vec<f64> = at.windows(2).map(|w| w[1] - w[0]).collect();
        let min_gap = gaps.iter().cloned().fold(f64::MAX, f64::min);
        assert!((min_gap - 50.0).abs() < 5.0, "peak gap ≈ 1/peak rate, got {min_gap}");
        assert!(gaps[0] > 2.0 * min_gap, "starts slow");
        assert!(gaps[gaps.len() - 1] > 2.0 * min_gap, "ends slow");
    }

    #[test]
    fn oscillating_rate_has_the_requested_period() {
        let prof = OscillatingRate::from_capacity(100.0, 1.2, 0.8, 10_000.0);
        assert_eq!(prof.name(), "oscillate");
        // crest at t=P/4, trough at t=3P/4
        let crest = prof.rate_per_ns(2_500.0);
        let trough = prof.rate_per_ns(7_500.0);
        assert!((crest - 0.02).abs() < 1e-9);
        assert!((trough - 0.004).abs() < 1e-9);
        assert!((prof.rate_per_ns(0.0) - 0.012).abs() < 1e-9, "mean at phase 0");
        // periodicity
        assert!((prof.rate_per_ns(1_234.0) - prof.rate_per_ns(11_234.0)).abs() < 1e-9);
        // never goes negative even with amplitude > mean
        let wild = OscillatingRate::from_capacity(100.0, 0.5, 5.0, 1_000.0);
        assert!(wild.rate_per_ns(750.0) >= MIN_RATE_PER_NS);
    }

    #[test]
    fn synthetic_source_restamps_and_cycles() {
        let prof = Burst::from_capacity(100.0, 1.0, 1.0, 1_000.0, 500.0);
        let mut src = SyntheticSource::new(pool(), Box::new(prof), 100, 2e6).with_limit(10);
        let mut sink = Vec::new();
        assert_eq!(src.poll_into(1e9, 100, &mut sink), SourcePoll::Ready);
        assert_eq!(src.poll_into(1e9, 100, &mut sink), SourcePoll::Exhausted);
        assert_eq!(sink.len(), 10);
        assert_eq!(src.emitted(), 10);
        // sequence numbers continue from seq0, monotonically
        assert_eq!(sink[0].0.seq, 100);
        assert_eq!(sink[9].0.seq, 109);
        // timestamps ride the arrival timeline offset by ts0
        assert_eq!(sink[0].0.ts_ms, 2);
        assert!(sink.windows(2).all(|w| w[0].0.ts_ms <= w[1].0.ts_ms));
        // pool of 4 cycles: payloads repeat with period 4
        assert_eq!(sink[0].0.attrs, sink[4].0.attrs);
        assert_eq!(sink[1].0.attrs, sink[5].0.attrs);
    }
}
