//! The real-time ingestion plane: sources, the bounded ingest queue,
//! and the arrival timestamps that turn the latency bound into a
//! defended SLO.
//!
//! Virtual-time experiments model arrivals with a [`crate::sim::RateSource`]
//! schedule; this module is the path for *actual* arrivals.  A
//! [`Source`] is polled with the current clock time and yields
//! timestamped events; they pass through a bounded [`IngestQueue`]
//! whose arrival stamps measure genuine queueing delay; the pipeline's
//! [`crate::pipeline::Pipeline::run_realtime`] loop drains it under a
//! [`crate::sim::Clock`] — the virtual [`crate::sim::SimClock`] for
//! deterministic replay, or a [`crate::sim::WallClock`] for wall-clock
//! pressure.
//!
//! Sources:
//!
//! * [`TraceSource`] — today's datasets on the deterministic schedule,
//! * [`FileTailSource`] — follow a growing CSV file,
//! * [`SocketSource`] — events over TCP, lenient line framing or the
//!   strict CSV file format ([`WireCodec`]),
//! * [`Burst`], [`FlashCrowd`], [`OscillatingRate`] — synthetic
//!   adversarial overload generators (via [`SyntheticSource`]).

pub mod queue;
pub mod socket;
pub mod source;
pub mod synthetic;
pub mod tail;

pub use queue::{IngestQueue, OverflowPolicy, PushOutcome};
pub use socket::{SocketSource, WireCodec};
pub use source::{Source, SourcePoll, TraceSource};
pub use synthetic::{Burst, FlashCrowd, OscillatingRate, RateProfile, SyntheticSource};
pub use tail::FileTailSource;

/// CLI/config selector for the ingest source.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SourceKind {
    /// pre-materialized dataset trace on the deterministic schedule
    #[default]
    Trace,
    /// tail a growing CSV file
    Tail,
    /// line-oriented events over TCP
    Socket,
    /// square-wave overload bursts
    Burst,
    /// one ramp–hold–decay flash crowd
    FlashCrowd,
    /// sinusoidal load straddling capacity
    Oscillate,
}

/// Every source selector, in canonical order.
pub const ALL_SOURCE_KINDS: [SourceKind; 6] = [
    SourceKind::Trace,
    SourceKind::Tail,
    SourceKind::Socket,
    SourceKind::Burst,
    SourceKind::FlashCrowd,
    SourceKind::Oscillate,
];

impl SourceKind {
    /// Canonical selector name.
    pub fn name(self) -> &'static str {
        match self {
            SourceKind::Trace => "trace",
            SourceKind::Tail => "tail",
            SourceKind::Socket => "socket",
            SourceKind::Burst => "burst",
            SourceKind::FlashCrowd => "flashcrowd",
            SourceKind::Oscillate => "oscillate",
        }
    }

    /// Is this one of the synthetic overload generators?
    pub fn is_synthetic(self) -> bool {
        matches!(
            self,
            SourceKind::Burst | SourceKind::FlashCrowd | SourceKind::Oscillate
        )
    }
}

impl std::str::FromStr for SourceKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "trace" => Ok(SourceKind::Trace),
            "tail" => Ok(SourceKind::Tail),
            "socket" => Ok(SourceKind::Socket),
            "burst" => Ok(SourceKind::Burst),
            "flashcrowd" | "flash-crowd" => Ok(SourceKind::FlashCrowd),
            "oscillate" | "oscillating" => Ok(SourceKind::Oscillate),
            other => anyhow::bail!(
                "unknown source {other:?} (trace|tail|socket|burst|flashcrowd|oscillate)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_kind_names_round_trip() {
        for kind in ALL_SOURCE_KINDS {
            assert_eq!(kind.name().parse::<SourceKind>().unwrap(), kind);
        }
        assert!("warp-drive".parse::<SourceKind>().is_err());
        assert_eq!(SourceKind::default(), SourceKind::Trace);
        assert!(SourceKind::Burst.is_synthetic());
        assert!(!SourceKind::Socket.is_synthetic());
    }
}
