//! TCP ingest: a non-blocking listener accepting line-oriented event
//! streams.  Two wire codecs ([`WireCodec`]):
//!
//! * [`WireCodec::Lines`] (default) — lenient `seq,ts_ms,etype,a0,...`
//!   lines via [`crate::events::Event::parse_csv`]: trailing attribute
//!   columns optional, comments/headers skipped, bad lines counted.
//! * [`WireCodec::Csv`] — the exact [`crate::datasets::csv`] file
//!   format on the wire: each connection must open with the
//!   `seq,ts_ms,etype,...` header, and every row must carry all
//!   attribute columns (strict, shared row parser
//!   [`crate::datasets::csv::parse_csv_row`]), so `gen-data` output can
//!   be piped straight into a socket unchanged.
//!
//! Events are stamped with the poll time — arrival is when the engine
//! reads them off the wire.  One peer at a time; when it disconnects
//! (cleanly or mid-stream with a read error) the listener goes back to
//! accepting, counts the hand-off in [`SocketSource::reconnects`], and
//! the CSV codec expects a fresh header from the next peer.  A dangling
//! partial line from the dead peer is discarded so the next stream
//! starts on a line boundary.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::Context;

use crate::events::Event;

use super::source::{Source, SourcePoll};

/// Framing of the byte stream a [`SocketSource`] peer sends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodec {
    /// lenient line format (default): comments and header lines
    /// skipped, trailing attribute columns optional
    #[default]
    Lines,
    /// strict [`crate::datasets::csv`] file format: per-connection
    /// header required, all attribute columns required
    Csv,
}

impl WireCodec {
    /// Canonical selector name.
    pub fn name(self) -> &'static str {
        match self {
            WireCodec::Lines => "lines",
            WireCodec::Csv => "csv",
        }
    }
}

impl std::str::FromStr for WireCodec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "lines" => Ok(WireCodec::Lines),
            "csv" => Ok(WireCodec::Csv),
            other => anyhow::bail!("unknown codec {other:?} (lines|csv)"),
        }
    }
}

/// A [`Source`] reading events from a TCP peer.
pub struct SocketSource {
    listener: TcpListener,
    conn: Option<TcpStream>,
    /// bytes carried until a full line is available
    carry: Vec<u8>,
    /// wire framing (see [`WireCodec`])
    codec: WireCodec,
    /// CSV codec: current connection has sent its header row
    header_seen: bool,
    /// lines that failed to parse (skipped, counted)
    pub bad_lines: u64,
    /// accepted connections after the first — every time a peer went
    /// away (hang-up or mid-stream error) and a new one took over
    pub reconnects: u64,
    /// at least one peer has ever connected
    ever_connected: bool,
}

impl SocketSource {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and listen without blocking,
    /// with the default lenient [`WireCodec::Lines`] framing.
    pub fn bind(addr: &str) -> crate::Result<Self> {
        Self::bind_with(addr, WireCodec::default())
    }

    /// Bind `addr` with an explicit wire codec.
    pub fn bind_with(addr: &str, codec: WireCodec) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding ingest socket {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("non-blocking ingest listener")?;
        Ok(SocketSource {
            listener,
            conn: None,
            carry: Vec::new(),
            codec,
            header_seen: false,
            bad_lines: 0,
            reconnects: 0,
            ever_connected: false,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> crate::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Try to accept a peer if none is connected.
    fn ensure_conn(&mut self) -> bool {
        if self.conn.is_some() {
            return true;
        }
        match self.listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    return false;
                }
                self.conn = Some(stream);
                // a fresh peer must send its own CSV header
                self.header_seen = false;
                if self.ever_connected {
                    self.reconnects += 1;
                } else {
                    self.ever_connected = true;
                }
                // a dangling partial line from the previous peer can
                // never complete; drop it (keeping any still-undrained
                // complete lines) so the new stream starts on a line
                // boundary instead of gluing onto stale bytes
                match self.carry.iter().rposition(|&b| b == b'\n') {
                    Some(last_nl) => self.carry.truncate(last_nl + 1),
                    None => self.carry.clear(),
                }
                true
            }
            Err(_) => false, // WouldBlock or transient: no peer yet
        }
    }

    /// Split complete lines out of `carry`, decode them with the wire
    /// codec, stamp `now_ns`.
    fn drain_lines(&mut self, now_ns: f64, max: usize, sink: &mut Vec<(Event, f64)>) -> usize {
        let mut pushed = 0usize;
        let mut start = 0usize;
        while pushed < max {
            let Some(rel) = self.carry[start..].iter().position(|&b| b == b'\n') else {
                break;
            };
            let end = start + rel;
            let line = String::from_utf8_lossy(&self.carry[start..end]);
            let t = line.trim();
            match self.codec {
                WireCodec::Lines => {
                    if !(t.is_empty()
                        || t.starts_with('#')
                        || crate::datasets::csv::is_csv_header(t))
                    {
                        match Event::parse_csv(t) {
                            Ok(e) => {
                                sink.push((e, now_ns));
                                pushed += 1;
                            }
                            Err(_) => self.bad_lines += 1,
                        }
                    }
                }
                WireCodec::Csv => {
                    if t.is_empty() {
                        // blank lines are legal in the file format too
                    } else if !self.header_seen {
                        // strict framing: the connection must open with
                        // the canonical header before any data row
                        if crate::datasets::csv::is_csv_header(t) {
                            self.header_seen = true;
                        } else {
                            self.bad_lines += 1;
                        }
                    } else {
                        match crate::datasets::csv::parse_csv_row(t) {
                            Ok(e) => {
                                sink.push((e, now_ns));
                                pushed += 1;
                            }
                            Err(_) => self.bad_lines += 1,
                        }
                    }
                }
            }
            start = end + 1;
        }
        if start > 0 {
            self.carry.drain(..start);
        }
        pushed
    }
}

impl Source for SocketSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        if self.ensure_conn() {
            let mut buf = [0u8; 4096];
            loop {
                let Some(conn) = self.conn.as_mut() else { break };
                match conn.read(&mut buf) {
                    Ok(0) => {
                        // peer hung up: back to accepting
                        self.conn = None;
                        break;
                    }
                    Ok(n) => {
                        self.carry.extend_from_slice(&buf[..n]);
                        pushed += self.drain_lines(now_ns, max - pushed, sink);
                        if pushed >= max {
                            break;
                        }
                    }
                    Err(err) if err.kind() == std::io::ErrorKind::WouldBlock => {
                        break; // drained the wire for now
                    }
                    Err(err) if err.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        // peer broke mid-stream (reset, aborted, ...):
                        // this connection is dead, not merely idle —
                        // back to accepting instead of treating the
                        // source as drained forever
                        self.conn = None;
                        break;
                    }
                }
            }
        }
        // lines may already be buffered even without fresh bytes
        if pushed < max {
            pushed += self.drain_lines(now_ns, max - pushed, sink);
        }
        if pushed > 0 {
            SourcePoll::Ready
        } else {
            SourcePoll::Pending {
                next_arrival_ns: None,
            }
        }
    }

    fn name(&self) -> &'static str {
        "socket"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn receives_lines_over_tcp() {
        let mut src = SocketSource::bind("127.0.0.1:0").unwrap();
        let addr = src.local_addr().unwrap();
        let mut sink = Vec::new();

        // no peer yet
        assert_eq!(
            src.poll_into(1.0, 8, &mut sink),
            SourcePoll::Pending { next_arrival_ns: None }
        );

        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(b"0,100,1,2.5\ngarbage\n1,200,0").unwrap();
        peer.flush().unwrap();

        // give the kernel a beat to move the bytes
        let mut got = 0;
        for _ in 0..200 {
            if let SourcePoll::Ready = src.poll_into(10.0, 8, &mut sink) {
                got = sink.len();
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(got, 1, "only the one complete good line so far");
        assert_eq!(sink[0].0.seq, 0);
        assert_eq!(sink[0].0.attr(0), 2.5);
        assert_eq!(sink[0].1, 10.0);
        assert_eq!(src.bad_lines, 1);

        // finish the partial line and close
        peer.write_all(b",7\n").unwrap();
        drop(peer);
        sink.clear();
        let mut ok = false;
        for _ in 0..200 {
            if let SourcePoll::Ready = src.poll_into(20.0, 8, &mut sink) {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(ok, "completed line must arrive");
        assert_eq!(sink[0].0.seq, 1);
        assert_eq!(sink[0].0.ts_ms, 200);
        assert_eq!(sink[0].0.etype, 0);
        assert_eq!(sink[0].0.attr(0), 7.0);
        assert_eq!(src.name(), "socket");
    }

    #[test]
    fn survives_peer_disconnect_and_takes_a_new_connection() {
        let mut src = SocketSource::bind("127.0.0.1:0").unwrap();
        let addr = src.local_addr().unwrap();
        let mut sink = Vec::new();

        // peer #1: one complete line plus a dangling partial, then gone
        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(b"0,100,1,2.5\n7,7").unwrap();
        peer.flush().unwrap();
        drop(peer);
        for _ in 0..500 {
            src.poll_into(10.0, 8, &mut sink);
            if !sink.is_empty() && src.conn.is_none() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sink.len(), 1);
        assert_eq!(sink[0].0.seq, 0);
        assert!(src.conn.is_none(), "hang-up returns to accepting");
        assert_eq!(src.reconnects, 0, "the first peer is not a reconnect");

        // peer #2: a new stream must parse cleanly — the dangling
        // `7,7` from peer #1 must not glue onto its first line
        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(b"1,200,0,7\n").unwrap();
        peer.flush().unwrap();
        drop(peer);
        sink.clear();
        for _ in 0..500 {
            src.poll_into(20.0, 8, &mut sink);
            if !sink.is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sink.len(), 1, "second peer's line arrives");
        assert_eq!(sink[0].0.seq, 1);
        assert_eq!(sink[0].0.ts_ms, 200);
        assert_eq!(sink[0].0.attr(0), 7.0);
        assert_eq!(src.reconnects, 1, "hand-off counted");
        assert_eq!(src.bad_lines, 0, "stale partial discarded, not parsed");
    }

    #[test]
    fn csv_codec_round_trips_generated_trace() {
        use crate::events::EventStream;

        // materialize a real trace through the canonical CSV file
        // format, then replay those exact bytes over the wire
        let events = crate::datasets::StockGen::with_seed(77).take_events(64);
        let dir = std::env::temp_dir().join("pspice_socket_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wire.csv");
        crate::datasets::csv::write_csv(&path, &events).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        let mut src = SocketSource::bind_with("127.0.0.1:0", WireCodec::Csv).unwrap();
        let addr = src.local_addr().unwrap();
        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(&bytes).unwrap();
        peer.flush().unwrap();
        drop(peer);

        let mut sink = Vec::new();
        for _ in 0..500 {
            src.poll_into(5.0, events.len(), &mut sink);
            if sink.len() >= events.len() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let back: Vec<_> = sink.iter().map(|(e, _)| *e).collect();
        assert_eq!(back, events, "wire replay must be byte-identical");
        assert_eq!(src.bad_lines, 0, "the canonical format has no bad lines");

        // strict framing: a row before the header is rejected, the
        // header unlocks the connection
        let mut src = SocketSource::bind_with("127.0.0.1:0", WireCodec::Csv).unwrap();
        let addr = src.local_addr().unwrap();
        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(b"0,1,2,0,0,0,0,0,0\nseq,ts_ms,etype,a0,a1,a2,a3,a4,a5\n3,4,5,1,2,3,4,5,6\n5,6,7,1.5\n")
            .unwrap();
        peer.flush().unwrap();
        drop(peer);
        let mut sink = Vec::new();
        for _ in 0..500 {
            src.poll_into(6.0, 8, &mut sink);
            if !sink.is_empty() && src.bad_lines >= 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sink.len(), 1, "only the complete post-header row parses");
        assert_eq!(sink[0].0.seq, 3);
        // headerless row + short row (strict codec wants every column)
        assert_eq!(src.bad_lines, 2);
        assert_eq!("csv".parse::<WireCodec>().unwrap(), WireCodec::Csv);
        assert_eq!(WireCodec::default().name(), "lines");
        assert!("json".parse::<WireCodec>().is_err());
    }
}
