//! TCP ingest: a non-blocking listener accepting line-oriented event
//! streams in the CSV wire format (`seq,ts_ms,etype,a0,...`, one event
//! per line; see [`crate::events::Event::parse_csv`]).  Events are
//! stamped with the poll time — arrival is when the engine reads them
//! off the wire.  One peer at a time; when it disconnects the listener
//! goes back to accepting.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::Context;

use crate::events::Event;

use super::source::{Source, SourcePoll};

/// A [`Source`] reading events from a TCP peer.
pub struct SocketSource {
    listener: TcpListener,
    conn: Option<TcpStream>,
    /// bytes carried until a full line is available
    carry: Vec<u8>,
    /// lines that failed to parse (skipped, counted)
    pub bad_lines: u64,
}

impl SocketSource {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and listen without blocking.
    pub fn bind(addr: &str) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding ingest socket {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("non-blocking ingest listener")?;
        Ok(SocketSource {
            listener,
            conn: None,
            carry: Vec::new(),
            bad_lines: 0,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> crate::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Try to accept a peer if none is connected.
    fn ensure_conn(&mut self) -> bool {
        if self.conn.is_some() {
            return true;
        }
        match self.listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    return false;
                }
                self.conn = Some(stream);
                true
            }
            Err(_) => false, // WouldBlock or transient: no peer yet
        }
    }

    /// Split complete lines out of `carry`, parse them, stamp `now_ns`.
    fn drain_lines(&mut self, now_ns: f64, max: usize, sink: &mut Vec<(Event, f64)>) -> usize {
        let mut pushed = 0usize;
        let mut start = 0usize;
        while pushed < max {
            let Some(rel) = self.carry[start..].iter().position(|&b| b == b'\n') else {
                break;
            };
            let end = start + rel;
            let line = String::from_utf8_lossy(&self.carry[start..end]);
            let t = line.trim();
            if !(t.is_empty() || t.starts_with('#') || t.starts_with("seq,")) {
                match Event::parse_csv(t) {
                    Ok(e) => {
                        sink.push((e, now_ns));
                        pushed += 1;
                    }
                    Err(_) => self.bad_lines += 1,
                }
            }
            start = end + 1;
        }
        if start > 0 {
            self.carry.drain(..start);
        }
        pushed
    }
}

impl Source for SocketSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        if self.ensure_conn() {
            let mut buf = [0u8; 4096];
            loop {
                let Some(conn) = self.conn.as_mut() else { break };
                match conn.read(&mut buf) {
                    Ok(0) => {
                        // peer hung up: back to accepting
                        self.conn = None;
                        break;
                    }
                    Ok(n) => {
                        self.carry.extend_from_slice(&buf[..n]);
                        pushed += self.drain_lines(now_ns, max - pushed, sink);
                        if pushed >= max {
                            break;
                        }
                    }
                    Err(err) if err.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break, // WouldBlock: drained the wire for now
                }
            }
        }
        // lines may already be buffered even without fresh bytes
        if pushed < max {
            pushed += self.drain_lines(now_ns, max - pushed, sink);
        }
        if pushed > 0 {
            SourcePoll::Ready
        } else {
            SourcePoll::Pending {
                next_arrival_ns: None,
            }
        }
    }

    fn name(&self) -> &'static str {
        "socket"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn receives_lines_over_tcp() {
        let mut src = SocketSource::bind("127.0.0.1:0").unwrap();
        let addr = src.local_addr().unwrap();
        let mut sink = Vec::new();

        // no peer yet
        assert_eq!(
            src.poll_into(1.0, 8, &mut sink),
            SourcePoll::Pending { next_arrival_ns: None }
        );

        let mut peer = TcpStream::connect(addr).unwrap();
        peer.write_all(b"0,100,1,2.5\ngarbage\n1,200,0").unwrap();
        peer.flush().unwrap();

        // give the kernel a beat to move the bytes
        let mut got = 0;
        for _ in 0..200 {
            if let SourcePoll::Ready = src.poll_into(10.0, 8, &mut sink) {
                got = sink.len();
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(got, 1, "only the one complete good line so far");
        assert_eq!(sink[0].0.seq, 0);
        assert_eq!(sink[0].0.attr(0), 2.5);
        assert_eq!(sink[0].1, 10.0);
        assert_eq!(src.bad_lines, 1);

        // finish the partial line and close
        peer.write_all(b",7\n").unwrap();
        drop(peer);
        sink.clear();
        let mut ok = false;
        for _ in 0..200 {
            if let SourcePoll::Ready = src.poll_into(20.0, 8, &mut sink) {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(ok, "completed line must arrive");
        assert_eq!(sink[0].0.seq, 1);
        assert_eq!(sink[0].0.ts_ms, 200);
        assert_eq!(sink[0].0.etype, 0);
        assert_eq!(sink[0].0.attr(0), 7.0);
        assert_eq!(src.name(), "socket");
    }
}
