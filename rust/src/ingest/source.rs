//! The [`Source`] abstraction: where timestamped events come from.
//!
//! A source is polled with the current clock time and pushes the
//! events that have *arrived by then* — each paired with its arrival
//! timestamp — into a caller-recycled sink.  Scheduled sources
//! ([`TraceSource`], the synthetic overload generators) know their next
//! arrival and report it when they have nothing due, so the ingest loop
//! can fast-forward across idle gaps; external sources (file tail, TCP
//! socket) report [`SourcePoll::Pending`] with no schedule and the loop
//! briefly idles instead.

use crate::events::Event;
use crate::sim::RateSource;

/// Result of one [`Source::poll_into`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SourcePoll {
    /// at least one event was pushed into the sink
    Ready,
    /// nothing due yet; `next_arrival_ns` is the schedule's next
    /// arrival when the source knows it (None for external sources)
    Pending {
        /// earliest instant at which polling again can yield an event
        next_arrival_ns: Option<f64>,
    },
    /// the source will never produce again
    Exhausted,
}

/// A producer of timestamped events for the real-time ingest plane.
pub trait Source: Send {
    /// Push up to `max` events that have arrived by `now_ns` into
    /// `sink` as `(event, arrival_ns)` pairs (appending; the caller
    /// owns clearing).  Must return [`SourcePoll::Ready`] iff at least
    /// one event was pushed.
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll;

    /// Short selector-style name (`trace`, `burst`, …) for reports.
    fn name(&self) -> &'static str;
}

/// Today's virtual-time experiments as a [`Source`]: a pre-materialized
/// trace whose `i`-th event arrives on the deterministic [`RateSource`]
/// schedule.  Polled to exhaustion under a [`crate::sim::SimClock`]
/// this reproduces exactly the arrival sequence the classic
/// [`crate::pipeline::Pipeline::feed`] loop models.
#[derive(Debug, Clone)]
pub struct TraceSource {
    events: Vec<Event>,
    schedule: RateSource,
    idx: usize,
}

impl TraceSource {
    /// Source over `events` arriving on `schedule`.
    pub fn new(events: Vec<Event>, schedule: RateSource) -> Self {
        TraceSource {
            events,
            schedule,
            idx: 0,
        }
    }

    /// Events not yet emitted.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.idx
    }
}

impl Source for TraceSource {
    fn poll_into(
        &mut self,
        now_ns: f64,
        max: usize,
        sink: &mut Vec<(Event, f64)>,
    ) -> SourcePoll {
        let mut pushed = 0usize;
        while pushed < max {
            if self.idx >= self.events.len() {
                return if pushed > 0 {
                    SourcePoll::Ready
                } else {
                    SourcePoll::Exhausted
                };
            }
            let arrival = self.schedule.arrival_ns(self.idx as u64);
            if arrival > now_ns {
                return if pushed > 0 {
                    SourcePoll::Ready
                } else {
                    SourcePoll::Pending {
                        next_arrival_ns: Some(arrival),
                    }
                };
            }
            sink.push((self.events[self.idx], arrival));
            self.idx += 1;
            pushed += 1;
        }
        SourcePoll::Ready
    }

    fn name(&self) -> &'static str {
        "trace"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64) -> Event {
        Event::new(seq, seq, 0, &[])
    }

    #[test]
    fn trace_source_follows_the_schedule() {
        let events: Vec<Event> = (0..10).map(ev).collect();
        let mut src = TraceSource::new(events, RateSource::from_capacity(100.0, 1.0, 0.0));
        let mut sink = Vec::new();

        // nothing has arrived before t=0 ... event 0 arrives at 0
        assert_eq!(src.poll_into(-1.0, 8, &mut sink), SourcePoll::Pending {
            next_arrival_ns: Some(0.0)
        });
        // at t=250, events 0,1,2 (arrivals 0,100,200) are due
        assert_eq!(src.poll_into(250.0, 8, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 3);
        assert_eq!(sink[2].0.seq, 2);
        assert_eq!(sink[2].1, 200.0);
        assert_eq!(src.remaining(), 7);

        // max caps the batch even when more is due
        sink.clear();
        assert_eq!(src.poll_into(1e9, 4, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 4);

        sink.clear();
        assert_eq!(src.poll_into(1e9, 100, &mut sink), SourcePoll::Ready);
        assert_eq!(sink.len(), 3);
        assert_eq!(src.poll_into(1e9, 100, &mut sink), SourcePoll::Exhausted);
        assert_eq!(src.name(), "trace");
    }
}
