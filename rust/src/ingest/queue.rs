//! The bounded ingest queue between sources and the pipeline.
//!
//! Every admitted event carries its arrival timestamp, so the queue
//! *is* the measurement instrument for queueing delay: the real-time
//! loop derives `l_q` from the stamps of the batch it pops, and the
//! measured overload detector derives ρ from that delay — no cost
//! model involved.
//!
//! Overflow is governed by [`OverflowPolicy`]:
//!
//! * [`OverflowPolicy::DropOldest`] — a full queue evicts its oldest
//!   entry to admit the new one (bounding queueing delay at the price
//!   of losing input; the drops are counted and reported separately
//!   from shedding).
//! * [`OverflowPolicy::Block`] — a full queue refuses the push; the
//!   ingest loop then stops pulling from the source, i.e. backpressure
//!   propagates upstream (a TCP source's peer eventually blocks on its
//!   socket, a scheduled source simply falls behind and later floods).
//!
//! Independently of the hard capacity, the queue latches a
//! *backpressure* flag at a high watermark and releases it at a low
//! watermark.  Under [`OverflowPolicy::Block`] the ingest loop stops
//! pulling as soon as the flag latches — the hysteresis band keeps the
//! loop from flapping between pull and stall on every event.

use std::collections::VecDeque;

use crate::events::Event;

/// What a full [`IngestQueue`] does with a new event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// evict the oldest queued event to admit the new one
    DropOldest,
    /// refuse the new event; the producer must stop pulling
    Block,
}

impl std::str::FromStr for OverflowPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "drop-oldest" | "drop_oldest" | "dropoldest" => Ok(OverflowPolicy::DropOldest),
            "block" => Ok(OverflowPolicy::Block),
            other => anyhow::bail!("unknown ingest policy {other:?} (drop-oldest|block)"),
        }
    }
}

impl OverflowPolicy {
    /// Canonical CLI/config name.
    pub fn name(self) -> &'static str {
        match self {
            OverflowPolicy::DropOldest => "drop-oldest",
            OverflowPolicy::Block => "block",
        }
    }
}

/// Outcome of one [`IngestQueue::push`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// admitted within capacity
    Accepted,
    /// admitted, but the oldest queued event was evicted to make room
    EvictedOldest,
    /// refused ([`OverflowPolicy::Block`] and the queue is full)
    Refused,
}

/// Bounded FIFO of `(event, arrival_ns)` with watermark backpressure.
#[derive(Debug)]
pub struct IngestQueue {
    buf: VecDeque<(Event, f64)>,
    capacity: usize,
    policy: OverflowPolicy,
    /// latch backpressure at this fill level …
    high: usize,
    /// … release it at this one
    low: usize,
    backpressure: bool,
    dropped: u64,
    peak_len: usize,
}

impl IngestQueue {
    /// Queue with the default watermarks (latch at 80% full, release
    /// at 50%).
    pub fn new(capacity: usize, policy: OverflowPolicy) -> Self {
        Self::with_watermarks(capacity, policy, 0.8, 0.5)
    }

    /// Queue with explicit watermark fractions of `capacity`
    /// (`0 < low ≤ high ≤ 1`).
    pub fn with_watermarks(
        capacity: usize,
        policy: OverflowPolicy,
        high_frac: f64,
        low_frac: f64,
    ) -> Self {
        let capacity = capacity.max(1);
        assert!(
            0.0 < low_frac && low_frac <= high_frac && high_frac <= 1.0,
            "watermarks need 0 < low <= high <= 1"
        );
        let high = ((capacity as f64 * high_frac) as usize).clamp(1, capacity);
        let low = ((capacity as f64 * low_frac) as usize).min(high);
        IngestQueue {
            buf: VecDeque::with_capacity(capacity),
            capacity,
            policy,
            high,
            low,
            backpressure: false,
            dropped: 0,
            peak_len: 0,
        }
    }

    /// Offer one event with its arrival timestamp.
    pub fn push(&mut self, event: Event, arrival_ns: f64) -> PushOutcome {
        let outcome = if self.buf.len() >= self.capacity {
            match self.policy {
                OverflowPolicy::DropOldest => {
                    self.buf.pop_front();
                    self.dropped += 1;
                    self.buf.push_back((event, arrival_ns));
                    PushOutcome::EvictedOldest
                }
                OverflowPolicy::Block => PushOutcome::Refused,
            }
        } else {
            self.buf.push_back((event, arrival_ns));
            PushOutcome::Accepted
        };
        self.peak_len = self.peak_len.max(self.buf.len());
        self.update_backpressure();
        outcome
    }

    /// Pop up to `max` events into the caller's recycled buffers
    /// (cleared first); returns how many were popped.
    pub fn pop_into(&mut self, max: usize, events: &mut Vec<Event>, arrivals: &mut Vec<f64>) -> usize {
        events.clear();
        arrivals.clear();
        let n = max.min(self.buf.len());
        for _ in 0..n {
            let (e, a) = self.buf.pop_front().expect("len checked");
            events.push(e);
            arrivals.push(a);
        }
        self.update_backpressure();
        n
    }

    fn update_backpressure(&mut self) {
        if self.buf.len() >= self.high {
            self.backpressure = true;
        } else if self.buf.len() <= self.low {
            self.backpressure = false;
        }
    }

    /// Is the latched backpressure flag up?  (Latches at the high
    /// watermark, releases at the low one.)
    pub fn backpressured(&self) -> bool {
        self.backpressure
    }

    /// Should the ingest loop stop pulling from the source right now?
    /// Under [`OverflowPolicy::Block`] that is the backpressure flag;
    /// under [`OverflowPolicy::DropOldest`] the queue always accepts.
    pub fn pull_paused(&self) -> bool {
        self.policy == OverflowPolicy::Block && self.backpressure
    }

    /// Events currently queued.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Hard capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The overflow policy.
    pub fn policy(&self) -> OverflowPolicy {
        self.policy
    }

    /// Events evicted by [`OverflowPolicy::DropOldest`] so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// High-water mark of the queue length over the run.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// Queueing delay of the oldest entry at `now_ns` (0 when empty).
    pub fn head_delay_ns(&self, now_ns: f64) -> f64 {
        self.buf
            .front()
            .map(|&(_, a)| (now_ns - a).max(0.0))
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64) -> Event {
        Event::new(seq, seq, 0, &[])
    }

    #[test]
    fn drop_oldest_evicts_in_fifo_order() {
        let mut q = IngestQueue::new(3, OverflowPolicy::DropOldest);
        for i in 0..3 {
            assert_eq!(q.push(ev(i), i as f64), PushOutcome::Accepted);
        }
        assert_eq!(q.push(ev(3), 3.0), PushOutcome::EvictedOldest);
        assert_eq!(q.dropped(), 1);
        let (mut e, mut a) = (Vec::new(), Vec::new());
        assert_eq!(q.pop_into(10, &mut e, &mut a), 3);
        // event 0 was the victim; 1..=3 survive in order
        assert_eq!(e.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn block_refuses_and_never_drops() {
        let mut q = IngestQueue::new(2, OverflowPolicy::Block);
        assert_eq!(q.push(ev(0), 0.0), PushOutcome::Accepted);
        assert_eq!(q.push(ev(1), 1.0), PushOutcome::Accepted);
        assert_eq!(q.push(ev(2), 2.0), PushOutcome::Refused);
        assert_eq!(q.len(), 2);
        assert_eq!(q.dropped(), 0);
    }

    #[test]
    fn watermarks_latch_and_release() {
        // capacity 10: latch at 8, release at 5
        let mut q = IngestQueue::new(10, OverflowPolicy::Block);
        let (mut e, mut a) = (Vec::new(), Vec::new());
        for i in 0..7 {
            q.push(ev(i), 0.0);
        }
        assert!(!q.backpressured(), "below high watermark");
        q.push(ev(7), 0.0);
        assert!(q.backpressured(), "latched at high watermark");
        assert!(q.pull_paused());
        q.pop_into(2, &mut e, &mut a); // len 6: inside the hysteresis band
        assert!(q.backpressured(), "hysteresis holds the latch");
        q.pop_into(1, &mut e, &mut a); // len 5 = low watermark
        assert!(!q.backpressured(), "released at low watermark");
        assert!(!q.pull_paused());
    }

    #[test]
    fn drop_oldest_never_pauses_pulls() {
        let mut q = IngestQueue::new(4, OverflowPolicy::DropOldest);
        for i in 0..20 {
            q.push(ev(i), 0.0);
        }
        assert!(q.backpressured(), "flag still reports pressure");
        assert!(!q.pull_paused(), "but pulling continues");
        assert_eq!(q.len(), 4);
        assert_eq!(q.dropped(), 16);
        assert_eq!(q.peak_len(), 4);
    }

    #[test]
    fn head_delay_measures_oldest_entry() {
        let mut q = IngestQueue::new(4, OverflowPolicy::Block);
        assert_eq!(q.head_delay_ns(100.0), 0.0);
        q.push(ev(0), 10.0);
        q.push(ev(1), 50.0);
        assert!((q.head_delay_ns(100.0) - 90.0).abs() < 1e-12);
    }
}
