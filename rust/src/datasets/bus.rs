//! Dublin-like synthetic public-bus trace (stands in for the paper's
//! 911-bus PLBT dataset).
//!
//! Schema: one event type `bus` with attributes
//! `[bus, stop, delayed, delay_min]`.
//!
//! Buses cycle through per-route stop sequences.  Delays are *bursty and
//! stop-correlated*: each stop carries a congestion level that random
//! incidents push up and time decays, so several buses get delayed at the
//! same stop in close succession — exactly the situation Q4's
//! `any(n, B…)` same-stop pattern detects.

use crate::events::{Event, EventStream, Schema};
use crate::util::Rng;

/// `bus` attribute slots.
pub const A_BUS: usize = 0;
/// stop id slot
pub const A_STOP: usize = 1;
/// delayed flag slot (1.0 = delayed)
pub const A_DELAYED: usize = 2;
/// delay magnitude slot (minutes)
pub const A_DELAY_MIN: usize = 3;

/// Configuration for [`BusGen`].
#[derive(Debug, Clone)]
pub struct BusConfig {
    /// Fleet size (paper: 911).
    pub buses: usize,
    /// Number of distinct stops in the network.
    pub stops: usize,
    /// Stops per route.
    pub route_len: usize,
    /// Probability per event that some stop has a new incident.
    pub incident_p: f64,
    /// Congestion decay factor per event.
    pub decay: f64,
    /// Milliseconds between consecutive bus reports.
    pub tick_ms: u64,
}

impl Default for BusConfig {
    fn default() -> Self {
        BusConfig {
            buses: 911,
            stops: 120,
            route_len: 16,
            incident_p: 0.003,
            decay: 0.9998,
            tick_ms: 3,
        }
    }
}

/// Seeded Dublin-like bus trace generator.
#[derive(Debug, Clone)]
pub struct BusGen {
    schema: Schema,
    cfg: BusConfig,
    rng: Rng,
    /// per-bus route (list of stop ids) and position on it
    routes: Vec<Vec<u32>>,
    route_pos: Vec<usize>,
    /// per-stop congestion in [0, 1)
    congestion: Vec<f64>,
    /// zipf-ish incident propensity per stop (city-center hotspots)
    hotspot: Vec<f64>,
    seq: u64,
    ts_ms: u64,
}

impl BusGen {
    /// New generator with the given seed and config.
    pub fn new(seed: u64, cfg: BusConfig) -> Self {
        let mut schema = Schema::new();
        schema.add_type("bus", &["bus", "stop", "delayed", "delay_min"]);
        let mut rng = Rng::seeded(seed);
        let routes = (0..cfg.buses)
            .map(|_| {
                (0..cfg.route_len)
                    .map(|_| rng.below(cfg.stops as u64) as u32)
                    .collect()
            })
            .collect();
        let route_pos = (0..cfg.buses)
            .map(|_| rng.index(cfg.route_len))
            .collect();
        let mut hotspot: Vec<f64> = (0..cfg.stops)
            .map(|r| 1.0 / ((r + 1) as f64).powf(1.1))
            .collect();
        rng.shuffle(&mut hotspot);
        BusGen {
            schema,
            congestion: vec![0.0; cfg.stops],
            hotspot,
            routes,
            route_pos,
            cfg,
            rng,
            seq: 0,
            ts_ms: 0,
        }
    }

    /// Default-config generator.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(seed, BusConfig::default())
    }
}

impl EventStream for BusGen {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_event(&mut self) -> Option<Event> {
        // world: incidents spike congestion at a random stop, all decay
        if self.rng.chance(self.cfg.incident_p) {
            let s = self.rng.weighted_index(&self.hotspot);
            self.congestion[s] = (self.congestion[s] + self.rng.range_f64(0.4, 0.9)).min(0.95);
        }
        for c in &mut self.congestion {
            *c *= self.cfg.decay;
        }
        // a random bus reports at its next stop
        let bus = self.rng.index(self.cfg.buses);
        self.route_pos[bus] = (self.route_pos[bus] + 1) % self.cfg.route_len;
        let stop = self.routes[bus][self.route_pos[bus]];
        let p_delay = 0.01 + self.congestion[stop as usize];
        let delayed = self.rng.chance(p_delay.min(0.97));
        let delay_min = if delayed {
            self.rng.range_f64(2.0, 25.0)
        } else {
            0.0
        };
        let e = Event::new(
            self.seq,
            self.ts_ms,
            0,
            &[
                bus as f64,
                stop as f64,
                if delayed { 1.0 } else { 0.0 },
                delay_min,
            ],
        );
        self.seq += 1;
        self.ts_ms += self.cfg.tick_ms;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = BusGen::with_seed(1);
        let mut b = BusGen::with_seed(1);
        for _ in 0..500 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn attrs_in_range() {
        let mut g = BusGen::with_seed(2);
        for e in g.take_events(10_000) {
            assert!(e.attr_id(A_BUS) < 911);
            assert!(e.attr_id(A_STOP) < 120);
            let d = e.attr(A_DELAYED);
            assert!(d == 0.0 || d == 1.0);
            if d == 0.0 {
                assert_eq!(e.attr(A_DELAY_MIN), 0.0);
            } else {
                assert!(e.attr(A_DELAY_MIN) >= 2.0);
            }
        }
    }

    #[test]
    fn delays_are_stop_correlated() {
        // delayed events should cluster on stops far above the uniform rate
        let mut g = BusGen::with_seed(3);
        let evs = g.take_events(150_000);
        let mut per_stop = vec![0usize; 120];
        let mut total = 0usize;
        for e in &evs {
            if e.attr(A_DELAYED) == 1.0 {
                per_stop[e.attr_id(A_STOP) as usize] += 1;
                total += 1;
            }
        }
        assert!(total > 500, "delays occur: {total}");
        let max = *per_stop.iter().max().unwrap();
        let uniform = total as f64 / 120.0;
        assert!(
            max as f64 > 3.0 * uniform,
            "bursts concentrate: max={max} uniform={uniform:.1}"
        );
    }

    #[test]
    fn baseline_delay_rate_reasonable() {
        let mut g = BusGen::with_seed(4);
        let evs = g.take_events(50_000);
        let delayed = evs.iter().filter(|e| e.attr(A_DELAYED) == 1.0).count();
        let frac = delayed as f64 / evs.len() as f64;
        assert!((0.01..0.5).contains(&frac), "frac={frac}");
    }
}
