//! Synthetic dataset generators standing in for the paper's three
//! real-world traces, plus CSV replay/export.
//!
//! The paper evaluates on (1) NYSE intraday quotes, (2) the DEBS'13 RTLS
//! soccer positions, and (3) the Dublin public-bus trace.  None of these
//! are redistributable here, so each generator synthesizes a seeded,
//! deterministic stream with the *structure the queries consume* (see
//! DESIGN.md §3 for the substitution argument):
//!
//! * [`stock`] — 500 symbols, geometric random-walk quotes, zipf-ish
//!   symbol frequencies, rising/falling flags (Q1, Q2),
//! * [`soccer`] — 2×11 players + ball, possession and proximity events
//!   (Q3),
//! * [`bus`] — 911 buses over a stop graph with bursty delays (Q4),
//! * [`mixed`] — all three streams interleaved into one trace with a
//!   merged event-type space: the Q1–Q4 multi-query scaling workload.

pub mod bus;
pub mod csv;
pub mod mixed;
pub mod soccer;
pub mod stock;

pub use bus::BusGen;
pub use mixed::{mixed_queries, mixed_trace};
pub use soccer::SoccerGen;
pub use stock::StockGen;

/// Which built-in dataset to generate (CLI/config selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// NYSE-like stock quotes.
    Stock,
    /// RTLS-like soccer positions.
    Soccer,
    /// Dublin-like bus trace.
    Bus,
}

impl DatasetKind {
    /// Canonical selector name (the scorecard ledger keys cells on it).
    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::Stock => "stock",
            DatasetKind::Soccer => "soccer",
            DatasetKind::Bus => "bus",
        }
    }

    /// Attribute slot holding the stream's correlation key (stock
    /// symbol / player id / bus id) — the slot E-BL's type utilities
    /// are keyed on.
    pub fn key_slot(self) -> usize {
        match self {
            DatasetKind::Stock => stock::A_SYMBOL,
            DatasetKind::Soccer => soccer::A_PLAYER,
            DatasetKind::Bus => bus::A_BUS,
        }
    }
}

impl std::str::FromStr for DatasetKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "stock" | "nyse" => Ok(DatasetKind::Stock),
            "soccer" | "rtls" => Ok(DatasetKind::Soccer),
            "bus" | "plbt" => Ok(DatasetKind::Bus),
            other => anyhow::bail!("unknown dataset {other:?}"),
        }
    }
}
