//! CSV export / replay of event streams, so generated traces can be
//! inspected, archived and replayed byte-identically across runs.
//!
//! Format: header `seq,ts_ms,etype,a0,a1,...`, one row per event, with
//! exactly [`MAX_ATTRS`](crate::events::MAX_ATTRS) attribute columns.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::Context;

use crate::events::{Event, EventStream, Schema, VecStream, MAX_ATTRS};

/// Write `events` to a CSV file.
pub fn write_csv(path: &Path, events: &[Event]) -> crate::Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let attr_cols: Vec<String> = (0..MAX_ATTRS).map(|i| format!("a{i}")).collect();
    writeln!(w, "seq,ts_ms,etype,{}", attr_cols.join(","))?;
    for e in events {
        write!(w, "{},{},{}", e.seq, e.ts_ms, e.etype)?;
        for a in &e.attrs {
            write!(w, ",{a}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Is this line the canonical header row written by [`write_csv`]?
pub fn is_csv_header(line: &str) -> bool {
    line.starts_with("seq,ts_ms,etype")
}

/// Parse one strict data row of the [`write_csv`] format: all three
/// integer columns plus exactly [`MAX_ATTRS`] attribute columns must be
/// present and well-formed.  Shared by [`read_csv`] and the socket
/// ingest's CSV wire codec
/// ([`crate::ingest::WireCodec::Csv`]), so file replay and wire replay
/// accept byte-identical rows.
pub fn parse_csv_row(line: &str) -> crate::Result<Event> {
    let mut parts = line.split(',');
    let mut next = |what: &str| {
        parts
            .next()
            .with_context(|| format!("missing {what} column"))
    };
    let seq: u64 = next("seq")?.parse()?;
    let ts_ms: u64 = next("ts_ms")?.parse()?;
    let etype: u16 = next("etype")?.parse()?;
    let mut attrs = [0.0; MAX_ATTRS];
    for (i, slot) in attrs.iter_mut().enumerate() {
        *slot = next(&format!("a{i}"))?.parse()?;
    }
    Ok(Event {
        seq,
        ts_ms,
        etype,
        attrs,
    })
}

/// Read events back from a CSV file written by [`write_csv`].
pub fn read_csv(path: &Path) -> crate::Result<Vec<Event>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut lines = BufReader::new(file).lines();
    let header = lines
        .next()
        .context("empty csv")?
        .context("reading header")?;
    anyhow::ensure!(is_csv_header(&header), "unrecognized csv header: {header}");
    let mut out = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(
            parse_csv_row(&line).with_context(|| format!("line {}", lineno + 2))?,
        );
    }
    Ok(out)
}

/// Materialize `n` events of a stream and wrap them for replay.
pub fn materialize<S: EventStream>(stream: &mut S, n: usize) -> VecStream {
    let schema: Schema = stream.schema().clone();
    VecStream::new(schema, stream.take_events(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::StockGen;

    #[test]
    fn round_trip() {
        let mut g = StockGen::with_seed(11);
        let events = g.take_events(500);
        let dir = std::env::temp_dir().join("pspice_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stock.csv");
        write_csv(&path, &events).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(events, back);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("pspice_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "hello,world\n1,2\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn materialize_snapshots_stream() {
        let mut g = StockGen::with_seed(12);
        let vs = materialize(&mut g, 100);
        assert_eq!(vs.remaining(), 100);
    }
}
