//! NYSE-like synthetic quote stream (stands in for the paper's Google
//! Finance intraday data: 500 symbols over two months).
//!
//! Schema: one event type `quote` with attributes
//! `[symbol, price, rising]` where `rising` is 1.0 if the quote is above
//! the symbol's previous quote (the RE/FE flags of Q1/Q2).
//!
//! Symbols trade at zipf-ish frequencies (a few heavy leaders, a long
//! tail) and prices follow independent geometric random walks, so rising
//! and falling runs occur with realistic persistence but no global trend.

use crate::events::{Event, EventStream, Schema};
use crate::util::Rng;

/// Event-type name used by this generator.
pub const QUOTE: &str = "quote";
/// Attribute slots of `quote`.
pub const A_SYMBOL: usize = 0;
/// price slot
pub const A_PRICE: usize = 1;
/// rising-flag slot (1.0 = rising vs previous quote of the symbol)
pub const A_RISING: usize = 2;
/// percent price move vs the symbol's previous quote
pub const A_MOVE: usize = 3;

/// Configuration for [`StockGen`].
#[derive(Debug, Clone)]
pub struct StockConfig {
    /// Number of distinct symbols (paper: 500).
    pub symbols: usize,
    /// Per-step volatility of the log-price random walk.
    pub volatility: f64,
    /// Zipf exponent for symbol trade frequency.
    pub zipf_s: f64,
    /// Milliseconds between consecutive quotes (source time).
    pub tick_ms: u64,
}

impl Default for StockConfig {
    fn default() -> Self {
        StockConfig {
            symbols: 500,
            volatility: 0.004,
            zipf_s: 1.05,
            tick_ms: 2,
        }
    }
}

/// Seeded NYSE-like quote generator.
#[derive(Debug, Clone)]
pub struct StockGen {
    schema: Schema,
    cfg: StockConfig,
    rng: Rng,
    prices: Vec<f64>,
    weights: Vec<f64>,
    seq: u64,
    ts_ms: u64,
}

impl StockGen {
    /// New generator with the given seed and config.
    pub fn new(seed: u64, cfg: StockConfig) -> Self {
        let mut schema = Schema::new();
        schema.add_type(QUOTE, &["symbol", "price", "rising", "move"]);
        let mut rng = Rng::seeded(seed);
        let prices = (0..cfg.symbols)
            .map(|_| rng.range_f64(20.0, 400.0))
            .collect();
        let weights = (0..cfg.symbols)
            .map(|r| 1.0 / ((r + 1) as f64).powf(cfg.zipf_s))
            .collect();
        StockGen {
            schema,
            cfg,
            rng,
            prices,
            weights,
            seq: 0,
            ts_ms: 0,
        }
    }

    /// Default-config generator.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(seed, StockConfig::default())
    }
}

impl EventStream for StockGen {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_event(&mut self) -> Option<Event> {
        let sym = self.rng.weighted_index(&self.weights);
        let old = self.prices[sym];
        // geometric random walk step
        let step = self.rng.normal_with(0.0, self.cfg.volatility);
        let new = (old * step.exp()).clamp(1.0, 10_000.0);
        self.prices[sym] = new;
        let rising = if new > old { 1.0 } else { 0.0 };
        let move_pct = 100.0 * (new - old) / old;
        let e = Event::new(
            self.seq,
            self.ts_ms,
            0,
            &[sym as f64, new, rising, move_pct],
        );
        self.seq += 1;
        self.ts_ms += self.cfg.tick_ms;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = StockGen::with_seed(1);
        let mut b = StockGen::with_seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn rising_flag_tracks_price() {
        let mut g = StockGen::with_seed(2);
        let mut last: std::collections::HashMap<i64, f64> = Default::default();
        for _ in 0..5_000 {
            let e = g.next_event().unwrap();
            let sym = e.attr_id(A_SYMBOL);
            let price = e.attr(A_PRICE);
            if let Some(&prev) = last.get(&sym) {
                let rising = e.attr(A_RISING) == 1.0;
                assert_eq!(rising, price > prev, "flag must match walk");
            }
            last.insert(sym, price);
        }
    }

    #[test]
    fn leaders_trade_more() {
        let mut g = StockGen::with_seed(3);
        let mut counts = vec![0usize; 500];
        for _ in 0..50_000 {
            counts[g.next_event().unwrap().attr_id(A_SYMBOL) as usize] += 1;
        }
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[490..].iter().sum();
        assert!(head > tail * 5, "head={head} tail={tail}");
    }

    #[test]
    fn seq_and_time_monotone() {
        let mut g = StockGen::with_seed(4);
        let evs = g.take_events(1000);
        assert!(evs.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
        assert!(evs.windows(2).all(|w| w[0].ts_ms <= w[1].ts_ms));
    }

    #[test]
    fn rising_roughly_balanced() {
        let mut g = StockGen::with_seed(5);
        let n = 20_000;
        let rising = (0..n)
            .filter(|_| g.next_event().unwrap().attr(A_RISING) == 1.0)
            .count();
        let frac = rising as f64 / n as f64;
        assert!((0.4..0.6).contains(&frac), "frac={frac}");
    }
}
