//! The mixed Q1–Q4 workload: all three synthetic streams interleaved
//! into ONE totally ordered trace, with the event-type ids remapped
//! into a merged type space so Q1/Q2 (quotes), Q3 (soccer) and Q4
//! (buses) run side by side in one multi-query operator.
//!
//! This is the scaling workload for the sharded runtime: eight queries
//! (Q1 rise/fall, Q2 rise/fall, Q3 at two pattern sizes, Q4 at two
//! window geometries) whose work partitions cleanly across shards.
//!
//! Merged event-type space:
//!
//! | merged etype | source       | original |
//! |---|---|---|
//! | 0 | stock `quote`  | 0 |
//! | 1 | soccer `poss`  | 0 |
//! | 2 | soccer `pos`   | 1 |
//! | 3 | bus `bus`      | 0 |

use crate::events::{Event, EventStream};
use crate::query::{builtin, OpenPolicy, Pattern, Query, StepSpec};

use super::{BusGen, SoccerGen, StockGen};

/// Merged etype of stock `quote` events.
pub const STOCK_BASE: u16 = 0;
/// Merged etype offset of soccer events (`poss` → 1, `pos` → 2).
pub const SOCCER_BASE: u16 = 1;
/// Merged etype of bus events.
pub const BUS_BASE: u16 = 3;

fn shift_step(s: &mut StepSpec, base: u16) {
    s.etype += base;
}

/// Remap every event-type reference in a query by `base`.
fn shift_query(q: &mut Query, base: u16) {
    match &mut q.pattern {
        Pattern::Seq(steps) => {
            for s in steps {
                shift_step(s, base);
            }
        }
        Pattern::Any { spec, .. } => shift_step(spec, base),
        Pattern::SeqAny { head, spec, .. } => {
            for s in head {
                shift_step(s, base);
            }
            shift_step(spec, base);
        }
    }
    if let OpenPolicy::OnMatch(s) = &mut q.open {
        shift_step(s, base);
    }
}

/// The mixed Q1–Q4 query set (eight queries), resolved against the
/// merged event-type space.  `ws_stock` sizes the Q1/Q2 count windows.
pub fn mixed_queries(ws_stock: u64) -> Vec<Query> {
    let mut out = Vec::new();
    for mut q in builtin::q1(ws_stock).queries {
        shift_query(&mut q, STOCK_BASE);
        out.push(q);
    }
    for mut q in builtin::q2(ws_stock + ws_stock / 2).queries {
        shift_query(&mut q, STOCK_BASE);
        out.push(q);
    }
    for mut q in builtin::q3(4, 1_500).queries {
        shift_query(&mut q, SOCCER_BASE);
        out.push(q);
    }
    for mut q in builtin::q3(3, 1_000).queries {
        shift_query(&mut q, SOCCER_BASE);
        out.push(q);
    }
    for mut q in builtin::q4(4, 2_000, 250).queries {
        shift_query(&mut q, BUS_BASE);
        out.push(q);
    }
    for mut q in builtin::q4(5, 3_000, 400).queries {
        shift_query(&mut q, BUS_BASE);
        out.push(q);
    }
    out
}

/// A deterministic merged trace of `n` events: stock, soccer and bus
/// events interleaved round-robin, with globally renumbered sequence
/// numbers and a 1 ms merged tick (so Q3's time windows keep a stable
/// event rate).
pub fn mixed_trace(n: usize, seed: u64) -> Vec<Event> {
    let mut stock = StockGen::with_seed(seed);
    let mut soccer = SoccerGen::with_seed(seed ^ 0x50CC);
    let mut bus = BusGen::with_seed(seed ^ 0xB005);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut e = match i % 3 {
            0 => {
                let mut e = stock.next_event().expect("stock stream is infinite");
                e.etype += STOCK_BASE;
                e
            }
            1 => {
                let mut e = soccer.next_event().expect("soccer stream is infinite");
                e.etype += SOCCER_BASE;
                e
            }
            _ => {
                let mut e = bus.next_event().expect("bus stream is infinite");
                e.etype += BUS_BASE;
                e
            }
        };
        e.seq = i as u64;
        e.ts_ms = i as u64;
        out.push(e);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operator::Operator;

    #[test]
    fn query_families_use_disjoint_etypes() {
        let queries = mixed_queries(4_000);
        assert_eq!(queries.len(), 8);
        let etypes_of = |q: &Query| -> Vec<u16> {
            let mut out = Vec::new();
            let mut push = |s: &StepSpec| out.push(s.etype);
            match &q.pattern {
                Pattern::Seq(steps) => steps.iter().for_each(&mut push),
                Pattern::Any { spec, .. } => push(spec),
                Pattern::SeqAny { head, spec, .. } => {
                    head.iter().for_each(&mut push);
                    push(spec);
                }
            }
            out
        };
        // q1/q2 on quotes (0), q3 on soccer (1/2), q4 on buses (3)
        for q in &queries[..4] {
            assert!(etypes_of(q).iter().all(|&t| t == 0), "{}", q.name);
        }
        for q in &queries[4..6] {
            assert!(etypes_of(q).iter().all(|&t| t == 1 || t == 2), "{}", q.name);
        }
        for q in &queries[6..] {
            assert!(etypes_of(q).iter().all(|&t| t == 3), "{}", q.name);
        }
    }

    #[test]
    fn trace_is_ordered_and_typed() {
        let trace = mixed_trace(3_000, 7);
        assert_eq!(trace.len(), 3_000);
        for (i, e) in trace.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert!(e.etype <= 3);
        }
        // all three families present
        for t in [0u16, 1, 3] {
            assert!(trace.iter().any(|e| e.etype == t), "missing family {t}");
        }
    }

    #[test]
    fn mixed_workload_runs_through_the_operator() {
        let mut op = Operator::new(mixed_queries(2_000));
        let trace = mixed_trace(12_000, 3);
        let mut opened = 0;
        for e in &trace {
            opened += op.process_event(e).opened;
        }
        assert!(opened > 0, "windows must open on the mixed trace");
        assert!(op.pm_count() > 0, "live PMs across the families");
        // determinism
        let mut op2 = Operator::new(mixed_queries(2_000));
        for e in &mixed_trace(12_000, 3) {
            op2.process_event(e);
        }
        assert_eq!(op.pm_count(), op2.pm_count());
    }
}
