//! RTLS-like synthetic soccer stream (stands in for the DEBS'13 grand
//! challenge data: players, balls and referees with position sensors).
//!
//! Schema: two event types —
//!
//! * `poss`  `[player, team, x, y]` — a striker takes ball possession
//!   (opens Q3's windows),
//! * `pos`   `[player, team, x, y, ball_dist]` — a player position sample
//!   with its distance to the current ball possessor.
//!
//! The kinematic model keeps 2×11 players doing noisy pursuit around the
//! pitch; possession alternates between the two designated strikers (one
//! per team, as in the paper's Q3 setup) with occasional turnovers, and
//! defenders of the *other* team drift toward the possessor, so
//! "defend" situations (`ball_dist < radius`) occur at a tunable rate.

use crate::events::{Event, EventStream, Schema};
use crate::util::Rng;

/// Players per team.
pub const TEAM_SIZE: usize = 11;
/// `pos` attribute slots.
pub const A_PLAYER: usize = 0;
/// team slot (0 or 1)
pub const A_TEAM: usize = 1;
/// x slot (m)
pub const A_X: usize = 2;
/// y slot (m)
pub const A_Y: usize = 3;
/// distance (m) to current ball possessor, `pos` only
pub const A_BALL_DIST: usize = 4;

/// Configuration for [`SoccerGen`].
#[derive(Debug, Clone)]
pub struct SoccerConfig {
    /// Sensor sampling interval per player (ms of source time between
    /// consecutive `pos` events overall).
    pub tick_ms: u64,
    /// Probability per tick that possession changes to the other striker.
    pub turnover_p: f64,
    /// How strongly opposing defenders are pulled toward the possessor.
    pub pursuit_gain: f64,
    /// Marking stand-off distance (m): defenders stop pressing once
    /// this close, so only jitter takes them inside the defend radius.
    pub standoff_m: f64,
    /// Position noise (m per tick).
    pub jitter: f64,
    /// Re-announce possession (a `poss` event) every this many full
    /// player sweeps — the RTLS ball sensor reports continuously, and
    /// each report opens a Q3 window like the paper's "each incoming
    /// striker event".
    pub heartbeat_sweeps: u32,
}

impl Default for SoccerConfig {
    fn default() -> Self {
        SoccerConfig {
            tick_ms: 1,
            turnover_p: 0.002,
            pursuit_gain: 0.035,
            standoff_m: 9.0,
            jitter: 0.8,
            heartbeat_sweeps: 2,
        }
    }
}

/// Seeded RTLS-like generator.
#[derive(Debug, Clone)]
pub struct SoccerGen {
    schema: Schema,
    cfg: SoccerConfig,
    rng: Rng,
    /// player positions, index = team*TEAM_SIZE + number
    px: Vec<f64>,
    py: Vec<f64>,
    /// striker player index per team
    strikers: [usize; 2],
    /// current possessing striker (player index)
    possessor: usize,
    seq: u64,
    ts_ms: u64,
    /// round-robin cursor over players for `pos` emission
    cursor: usize,
    /// sweeps since the last possession heartbeat
    sweeps_since_poss: u32,
    /// emit a `poss` event on the next call (possession just changed)
    pending_poss: bool,
}

impl SoccerGen {
    /// New generator with the given seed and config.
    pub fn new(seed: u64, cfg: SoccerConfig) -> Self {
        let mut schema = Schema::new();
        schema.add_type("poss", &["player", "team", "x", "y"]);
        schema.add_type("pos", &["player", "team", "x", "y", "ball_dist"]);
        let mut rng = Rng::seeded(seed);
        let n = 2 * TEAM_SIZE;
        let px = (0..n).map(|_| rng.range_f64(0.0, 105.0)).collect();
        let py = (0..n).map(|_| rng.range_f64(0.0, 68.0)).collect();
        let strikers = [9, TEAM_SIZE + 9]; // "number 9" of each team
        SoccerGen {
            schema,
            cfg,
            rng,
            px,
            py,
            strikers,
            possessor: 9,
            seq: 0,
            ts_ms: 0,
            cursor: 0,
            sweeps_since_poss: 0,
            pending_poss: true, // first event announces initial possession
        }
    }

    /// Default-config generator.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(seed, SoccerConfig::default())
    }

    /// Type id of `poss` events.
    pub fn poss_type(&self) -> u16 {
        0
    }

    /// Type id of `pos` events.
    pub fn pos_type(&self) -> u16 {
        1
    }

    fn team_of(player: usize) -> usize {
        player / TEAM_SIZE
    }

    fn advance_world(&mut self) {
        // possession turnover?
        if self.rng.chance(self.cfg.turnover_p) {
            let cur_team = Self::team_of(self.possessor);
            self.possessor = self.strikers[1 - cur_team];
            self.pending_poss = true;
        }
        // move every player: defenders of the non-possessing team pursue,
        // everyone else drifts
        let (bx, by) = (self.px[self.possessor], self.py[self.possessor]);
        let poss_team = Self::team_of(self.possessor);
        for p in 0..self.px.len() {
            let dx = bx - self.px[p];
            let dy = by - self.py[p];
            let dist = (dx * dx + dy * dy).sqrt().max(1e-6);
            // opposing players mark the possessor: press toward the
            // stand-off ring from outside, back off from inside — an
            // OU-like hover around `standoff_m`, so the defend radius
            // (< standoff) is only crossed by jitter excursions
            let marking = Self::team_of(p) != poss_team && p != self.possessor;
            let (gx, gy) = if marking {
                let pull = (dist - self.cfg.standoff_m) / dist;
                (pull * dx, pull * dy)
            } else {
                (0.0, 0.0)
            };
            self.px[p] += self.cfg.pursuit_gain * gx
                + self.rng.normal_with(0.0, self.cfg.jitter);
            self.py[p] += self.cfg.pursuit_gain * gy
                + self.rng.normal_with(0.0, self.cfg.jitter);
            self.px[p] = self.px[p].clamp(0.0, 105.0);
            self.py[p] = self.py[p].clamp(0.0, 68.0);
        }
    }
}

impl EventStream for SoccerGen {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_event(&mut self) -> Option<Event> {
        if self.pending_poss {
            self.pending_poss = false;
            let p = self.possessor;
            let e = Event::new(
                self.seq,
                self.ts_ms,
                0,
                &[
                    p as f64,
                    Self::team_of(p) as f64,
                    self.px[p],
                    self.py[p],
                ],
            );
            self.seq += 1;
            return Some(e);
        }
        // one world step per full player sweep
        if self.cursor == 0 {
            self.advance_world();
            self.sweeps_since_poss += 1;
            if self.sweeps_since_poss >= self.cfg.heartbeat_sweeps {
                self.sweeps_since_poss = 0;
                self.pending_poss = true;
            }
            if self.pending_poss {
                return self.next_event();
            }
        }
        let p = self.cursor;
        self.cursor = (self.cursor + 1) % self.px.len();
        let (bx, by) = (self.px[self.possessor], self.py[self.possessor]);
        let d = ((self.px[p] - bx).powi(2) + (self.py[p] - by).powi(2)).sqrt();
        let e = Event::new(
            self.seq,
            self.ts_ms,
            1,
            &[
                p as f64,
                Self::team_of(p) as f64,
                self.px[p],
                self.py[p],
                d,
            ],
        );
        self.seq += 1;
        self.ts_ms += self.cfg.tick_ms;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SoccerGen::with_seed(1);
        let mut b = SoccerGen::with_seed(1);
        for _ in 0..500 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn first_event_is_possession() {
        let mut g = SoccerGen::with_seed(2);
        let e = g.next_event().unwrap();
        assert_eq!(e.etype, 0);
        assert_eq!(e.attr_id(A_PLAYER), 9);
    }

    #[test]
    fn positions_stay_on_pitch() {
        let mut g = SoccerGen::with_seed(3);
        for e in g.take_events(20_000) {
            if e.etype == 1 {
                assert!((0.0..=105.0).contains(&e.attr(A_X)));
                assert!((0.0..=68.0).contains(&e.attr(A_Y)));
                assert!(e.attr(A_BALL_DIST) >= 0.0);
            }
        }
    }

    #[test]
    fn possession_changes_over_time() {
        let mut g = SoccerGen::with_seed(4);
        let poss: Vec<i64> = g
            .take_events(200_000)
            .iter()
            .filter(|e| e.etype == 0)
            .map(|e| e.attr_id(A_PLAYER))
            .collect();
        assert!(poss.len() > 3, "turnovers happen: {}", poss.len());
        assert!(poss.contains(&9) && poss.contains(&(TEAM_SIZE as i64 + 9)));
    }

    #[test]
    fn defenders_get_close() {
        let mut g = SoccerGen::with_seed(5);
        let close = g
            .take_events(100_000)
            .iter()
            .filter(|e| {
                e.etype == 1
                    && e.attr(A_BALL_DIST) < 3.0
                    && e.attr_id(A_TEAM) != 0
            })
            .count();
        assert!(close > 10, "pursuit creates defend events: {close}");
    }
}
