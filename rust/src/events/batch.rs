//! The zero-allocation event plane: pooled, reference-counted event
//! batches and word-packed drop masks for the sharded dispatch path.
//!
//! The sharded coordinator used to copy every batch into a fresh
//! `Arc<Vec<Event>>` (and every shed mask into an `Arc<Vec<bool>>`) per
//! dispatch.  This module replaces both with recycled buffers drawn
//! from an [`ArcPool`]: the coordinator leases a buffer whose reference
//! count has drained back to one, refills it in place, and ships clones
//! of the same `Arc` to every shard — steady-state dispatch performs
//! **zero heap allocation**.  The synchronous worker protocol is what
//! makes this sound: workers drop their clone before responding, so by
//! the next lease every pooled buffer is uniquely owned again.
//!
//! [`TypeMask`] is the routing companion: a batch is tagged with the
//! set of event types it contains while it is filled (one OR per
//! event), and each shard owns the union of its queries' type masks —
//! a batch whose occupancy does not intersect a shard's mask cannot
//! advance any PM there (see `CompiledQuery::types`).

use std::sync::Arc;

use super::{Event, EventType};

/// A small set of event types, packed into one `u64` word.
///
/// Types `>= 63` all share the overflow bit 63, which keeps the mask
/// *conservative*: two distinct high types look identical, so routing
/// can only ever err on the side of "relevant" (extra work, never a
/// missed match).  `contains` returning `false` is therefore a proof
/// that no referenced type equals the probed one.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TypeMask(u64);

impl TypeMask {
    /// The empty set.
    pub const EMPTY: TypeMask = TypeMask(0);

    #[inline]
    fn bit(t: EventType) -> u64 {
        1u64 << (t as u64).min(63)
    }

    /// Add one event type.
    #[inline]
    pub fn add(&mut self, t: EventType) {
        self.0 |= Self::bit(t);
    }

    /// Is `t` (conservatively) in the set?
    #[inline]
    pub fn contains(self, t: EventType) -> bool {
        self.0 & Self::bit(t) != 0
    }

    /// Do the two sets share any type?
    #[inline]
    pub fn intersects(self, other: TypeMask) -> bool {
        self.0 & other.0 != 0
    }

    /// Union of the two sets.
    #[inline]
    pub fn union(self, other: TypeMask) -> TypeMask {
        TypeMask(self.0 | other.0)
    }

    /// Is the set empty?
    #[inline]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The occupancy mask of a slice of events.
    pub fn of(events: &[Event]) -> TypeMask {
        let mut m = TypeMask::EMPTY;
        for e in events {
            m.add(e.etype);
        }
        m
    }
}

/// A reusable event batch: the unit the sharded coordinator ships to
/// its workers, tagged with the per-type occupancy mask computed while
/// the buffer was filled.
#[derive(Debug, Default)]
pub struct EventBatch {
    events: Vec<Event>,
    types: TypeMask,
}

impl EventBatch {
    /// Replace the contents with `events` (reusing the buffer's
    /// capacity), tagging the occupancy mask in the same pass — one OR
    /// per event, no second scan.
    pub fn refill(&mut self, events: &[Event]) {
        self.events.clear();
        self.events.reserve(events.len());
        let mut types = TypeMask::EMPTY;
        for e in events {
            types.add(e.etype);
            self.events.push(*e);
        }
        self.types = types;
    }

    /// A freshly allocated (non-pooled) batch — the legacy-dispatch
    /// comparison path and one-off callers.
    pub fn copied(events: &[Event]) -> Self {
        let mut b = EventBatch::default();
        b.refill(events);
        b
    }

    /// The batch's events.
    #[inline]
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Event types present in the batch.
    #[inline]
    pub fn types(&self) -> TypeMask {
        self.types
    }

    /// Number of events.
    #[inline]
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Is the batch empty?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// A word-packed per-event drop mask: bit `i` set means event `i` of
/// the batch was shed by a black-box strategy and gets window
/// bookkeeping only.  Replaces `Vec<bool>`/`Arc<Vec<bool>>` everywhere
/// a [`crate::shedding::Shedder`] hands victims to an operator state —
/// 64 events per word, recyclable through a [`MaskPool`].
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DropMask {
    words: Vec<u64>,
    len: usize,
}

impl DropMask {
    /// Clear the mask and size it for `len` events (all bits unset),
    /// reusing the word buffer's capacity.
    pub fn reset(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
        self.len = len;
    }

    /// Mask of `len` events with every bit taken from `bools`.
    pub fn from_bools(bools: &[bool]) -> Self {
        let mut m = DropMask::default();
        m.reset(bools.len());
        for (i, &b) in bools.iter().enumerate() {
            if b {
                m.mark(i);
            }
        }
        m
    }

    /// Number of events the mask covers.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Does the mask cover zero events?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mark event `i` as dropped.
    #[inline]
    pub fn mark(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Was event `i` dropped?
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// How many events are marked dropped.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Is any event marked dropped?
    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Become a copy of `other`, reusing this mask's word buffer.
    pub fn copy_from(&mut self, other: &DropMask) {
        self.words.clear();
        self.words.extend_from_slice(&other.words);
        self.len = other.len;
    }
}

/// A free list of reference-counted buffers.  [`ArcPool::lease_with`]
/// hands out a clone of a pooled `Arc` whose other clones have all been
/// dropped (refilling it in place first); if every buffer is still in
/// flight, the pool grows by one.  Buffers are never returned
/// explicitly — dropping the last outside clone is what makes a buffer
/// leasable again, so the pool's size is bounded by the peak number of
/// buffers simultaneously in flight (one, for the synchronous shard
/// protocol).
#[derive(Debug, Default)]
pub struct ArcPool<T> {
    free: Vec<Arc<T>>,
}

impl<T: Default> ArcPool<T> {
    /// An empty pool.
    pub fn new() -> Self {
        ArcPool { free: Vec::new() }
    }

    /// Lease a uniquely-owned buffer, refill it via `fill`, and return
    /// a shareable clone.  Zero allocation once the pool is warm.
    pub fn lease_with(&mut self, fill: impl FnOnce(&mut T)) -> Arc<T> {
        let idx = self
            .free
            .iter()
            .position(|a| Arc::strong_count(a) == 1)
            .unwrap_or_else(|| {
                self.free.push(Arc::new(T::default()));
                self.free.len() - 1
            });
        let arc = &mut self.free[idx];
        fill(Arc::get_mut(arc).expect("strong count checked above"));
        Arc::clone(arc)
    }

    /// How many buffers the pool has ever grown to (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Pool of shareable event batches (the coordinator's dispatch plane).
pub type BatchPool = ArcPool<EventBatch>;

/// Pool of shareable drop masks (the shed-mask companion).
pub type MaskPool = ArcPool<DropMask>;

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64, etype: EventType) -> Event {
        Event::new(seq, seq, etype, &[])
    }

    #[test]
    fn type_mask_tracks_membership_and_intersection() {
        let mut m = TypeMask::EMPTY;
        assert!(m.is_empty());
        m.add(0);
        m.add(3);
        assert!(m.contains(0));
        assert!(m.contains(3));
        assert!(!m.contains(1));
        let other = TypeMask::of(&[ev(0, 1), ev(1, 3)]);
        assert!(m.intersects(other));
        assert!(!TypeMask::of(&[ev(0, 1)]).intersects(TypeMask::of(&[ev(0, 2)])));
        assert_eq!(m.union(other), TypeMask::of(&[ev(0, 0), ev(1, 1), ev(2, 3)]));
    }

    #[test]
    fn type_mask_saturates_high_types_conservatively() {
        let mut m = TypeMask::EMPTY;
        m.add(100);
        // distinct high types collide on the overflow bit: conservative
        assert!(m.contains(200));
        assert!(m.contains(63));
        // ... but never claims a low type it does not hold
        assert!(!m.contains(5));
    }

    #[test]
    fn event_batch_refill_reuses_and_retags() {
        let mut b = EventBatch::copied(&[ev(0, 2), ev(1, 2)]);
        assert_eq!(b.len(), 2);
        assert!(b.types().contains(2));
        b.refill(&[ev(2, 5)]);
        assert_eq!(b.len(), 1);
        assert!(!b.is_empty());
        assert!(b.types().contains(5));
        assert!(!b.types().contains(2));
        assert_eq!(b.events()[0].seq, 2);
    }

    #[test]
    fn drop_mask_marks_counts_and_copies() {
        let mut m = DropMask::default();
        m.reset(130); // spans three words
        assert_eq!(m.len(), 130);
        assert!(!m.any());
        m.mark(0);
        m.mark(64);
        m.mark(129);
        assert!(m.get(0) && m.get(64) && m.get(129));
        assert!(!m.get(1));
        assert_eq!(m.count(), 3);
        let mut c = DropMask::default();
        c.copy_from(&m);
        assert_eq!(c, m);
        // reset clears previous bits
        m.reset(10);
        assert!(!m.any());
        assert_eq!(m.len(), 10);
        let from = DropMask::from_bools(&[true, false, true]);
        assert_eq!(from.count(), 2);
        assert!(from.get(0) && !from.get(1) && from.get(2));
    }

    #[test]
    fn arc_pool_recycles_drained_buffers() {
        let mut pool: BatchPool = ArcPool::new();
        let a = pool.lease_with(|b| b.refill(&[ev(0, 1)]));
        assert_eq!(pool.pooled(), 1);
        // `a` still alive: the next lease must grow the pool
        let b = pool.lease_with(|b| b.refill(&[ev(1, 1)]));
        assert_eq!(pool.pooled(), 2);
        drop(a);
        drop(b);
        // both drained: leases now recycle without growth
        let c = pool.lease_with(|b| b.refill(&[ev(2, 7)]));
        drop(c);
        let d = pool.lease_with(|b| b.refill(&[ev(3, 7)]));
        assert_eq!(pool.pooled(), 2);
        assert!(d.types().contains(7));
        assert_eq!(d.events()[0].seq, 3);
    }
}
