//! Primitive events, schemas, and the stream abstraction.

pub mod event;
pub mod schema;
pub mod stream;

pub use event::{Event, EventType, MAX_ATTRS};
pub use schema::Schema;
pub use stream::{EventStream, VecStream};
