//! Primitive events, schemas, the stream abstraction, and the pooled
//! batch/mask plane the sharded runtime dispatches through.

pub mod batch;
pub mod event;
pub mod schema;
pub mod stream;

pub use batch::{ArcPool, BatchPool, DropMask, EventBatch, MaskPool, TypeMask};
pub use event::{Event, EventType, MAX_ATTRS};
pub use schema::Schema;
pub use stream::{EventStream, VecStream};
