//! Schemas: name ↔ slot mapping for event types and their attributes.
//!
//! A schema is shared by a dataset generator, the query DSL (which refers
//! to attributes by name) and the NFA compiler (which resolves names to
//! slots once, so predicate evaluation is pure index arithmetic).

use std::collections::HashMap;

use super::event::EventType;

/// Event-type and attribute naming for one stream.
#[derive(Debug, Default, Clone)]
pub struct Schema {
    type_by_name: HashMap<String, EventType>,
    type_names: Vec<String>,
    /// attribute names per event type, slot order
    attrs: Vec<Vec<String>>,
}

impl Schema {
    /// Empty schema.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an event type with its attribute names (slot order).
    /// Returns the dense type id.
    pub fn add_type(&mut self, name: &str, attr_names: &[&str]) -> EventType {
        assert!(
            !self.type_by_name.contains_key(name),
            "duplicate event type {name}"
        );
        assert!(attr_names.len() <= super::event::MAX_ATTRS);
        let id = self.type_names.len() as EventType;
        self.type_names.push(name.to_string());
        self.type_by_name.insert(name.to_string(), id);
        self.attrs
            .push(attr_names.iter().map(|s| s.to_string()).collect());
        id
    }

    /// Type id by name.
    pub fn type_id(&self, name: &str) -> Option<EventType> {
        self.type_by_name.get(name).copied()
    }

    /// Type name by id.
    pub fn type_name(&self, id: EventType) -> &str {
        &self.type_names[id as usize]
    }

    /// Attribute slot for `(type, attr name)`.
    pub fn attr_slot(&self, etype: EventType, attr: &str) -> Option<usize> {
        self.attrs[etype as usize].iter().position(|a| a == attr)
    }

    /// Attribute names of a type.
    pub fn attr_names(&self, etype: EventType) -> &[String] {
        &self.attrs[etype as usize]
    }

    /// Number of registered types.
    pub fn type_count(&self) -> usize {
        self.type_names.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_resolve() {
        let mut s = Schema::new();
        let q = s.add_type("quote", &["symbol", "price", "rising"]);
        assert_eq!(s.type_id("quote"), Some(q));
        assert_eq!(s.type_name(q), "quote");
        assert_eq!(s.attr_slot(q, "price"), Some(1));
        assert_eq!(s.attr_slot(q, "nope"), None);
        assert_eq!(s.type_count(), 1);
        assert_eq!(s.attr_names(q).len(), 3);
    }

    #[test]
    #[should_panic(expected = "duplicate event type")]
    fn duplicate_type_panics() {
        let mut s = Schema::new();
        s.add_type("a", &[]);
        s.add_type("a", &[]);
    }
}
