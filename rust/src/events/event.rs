//! The primitive event: a fixed-size, `Copy`-able record so the operator
//! hot path never allocates per event.

/// Dense event-type id (per schema).
pub type EventType = u16;

/// Maximum number of attributes an event can carry.  Chosen to cover the
/// widest built-in schema (soccer positions) with room to spare.
pub const MAX_ATTRS: usize = 6;

/// A primitive event.  Attribute meaning is defined by the stream's
/// [`super::Schema`]; identifiers (symbol, bus id, stop id, player id) are
/// stored as exactly-representable small integers in `f64` slots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Global sequence number (total order over the stream).
    pub seq: u64,
    /// Event timestamp in milliseconds (source time).
    pub ts_ms: u64,
    /// Event type id within the schema.
    pub etype: EventType,
    /// Attribute values, `attrs[..schema.attr_count(etype)]` are valid.
    pub attrs: [f64; MAX_ATTRS],
}

impl Event {
    /// Build an event; unspecified attribute slots are zero.
    pub fn new(seq: u64, ts_ms: u64, etype: EventType, attrs: &[f64]) -> Self {
        assert!(attrs.len() <= MAX_ATTRS, "too many attributes");
        let mut a = [0.0; MAX_ATTRS];
        a[..attrs.len()].copy_from_slice(attrs);
        Event {
            seq,
            ts_ms,
            etype,
            attrs: a,
        }
    }

    /// Attribute by slot index.
    #[inline]
    pub fn attr(&self, slot: usize) -> f64 {
        self.attrs[slot]
    }

    /// Attribute interpreted as an integer id.
    #[inline]
    pub fn attr_id(&self, slot: usize) -> i64 {
        self.attrs[slot] as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let e = Event::new(7, 1000, 2, &[3.0, 1.5]);
        assert_eq!(e.seq, 7);
        assert_eq!(e.etype, 2);
        assert_eq!(e.attr(0), 3.0);
        assert_eq!(e.attr_id(0), 3);
        assert_eq!(e.attr(1), 1.5);
        assert_eq!(e.attr(5), 0.0);
    }

    #[test]
    #[should_panic(expected = "too many attributes")]
    fn too_many_attrs_panics() {
        Event::new(0, 0, 0, &[0.0; MAX_ATTRS + 1]);
    }

    #[test]
    fn event_is_copy_and_small() {
        // hot-path contract: events are copied into windows without heap work
        fn takes_copy<T: Copy>(_t: T) {}
        takes_copy(Event::new(0, 0, 0, &[]));
        assert!(std::mem::size_of::<Event>() <= 72);
    }
}
