//! The primitive event: a fixed-size, `Copy`-able record so the operator
//! hot path never allocates per event.

/// Dense event-type id (per schema).
pub type EventType = u16;

/// Maximum number of attributes an event can carry.  Chosen to cover the
/// widest built-in schema (soccer positions) with room to spare.
pub const MAX_ATTRS: usize = 6;

/// A primitive event.  Attribute meaning is defined by the stream's
/// [`super::Schema`]; identifiers (symbol, bus id, stop id, player id) are
/// stored as exactly-representable small integers in `f64` slots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Global sequence number (total order over the stream).
    pub seq: u64,
    /// Event timestamp in milliseconds (source time).
    pub ts_ms: u64,
    /// Event type id within the schema.
    pub etype: EventType,
    /// Attribute values, `attrs[..schema.attr_count(etype)]` are valid.
    pub attrs: [f64; MAX_ATTRS],
}

impl Event {
    /// Build an event; unspecified attribute slots are zero.
    pub fn new(seq: u64, ts_ms: u64, etype: EventType, attrs: &[f64]) -> Self {
        assert!(attrs.len() <= MAX_ATTRS, "too many attributes");
        let mut a = [0.0; MAX_ATTRS];
        a[..attrs.len()].copy_from_slice(attrs);
        Event {
            seq,
            ts_ms,
            etype,
            attrs: a,
        }
    }

    /// Attribute by slot index.
    #[inline]
    pub fn attr(&self, slot: usize) -> f64 {
        self.attrs[slot]
    }

    /// Attribute interpreted as an integer id.
    #[inline]
    pub fn attr_id(&self, slot: usize) -> i64 {
        self.attrs[slot] as i64
    }

    /// Parse one CSV row in the archive format
    /// (`seq,ts_ms,etype,a0,a1,...`; see [`crate::datasets::csv`]).
    /// Trailing attribute columns may be omitted (they default to 0),
    /// which is what the line-oriented ingest sources (file tail, TCP
    /// socket) accept on the wire.
    pub fn parse_csv(line: &str) -> crate::Result<Event> {
        let mut parts = line.trim().split(',');
        let mut next = |what: &str| {
            parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("event line missing {what}: {line:?}"))
        };
        let seq: u64 = next("seq")?.trim().parse()?;
        let ts_ms: u64 = next("ts_ms")?.trim().parse()?;
        let etype: EventType = next("etype")?.trim().parse()?;
        let mut attrs = [0.0; MAX_ATTRS];
        for (i, slot) in attrs.iter_mut().enumerate() {
            match parts.next() {
                Some(v) => {
                    *slot = v.trim().parse().map_err(|_| {
                        anyhow::anyhow!("event line has bad a{i}: {line:?}")
                    })?
                }
                None => break,
            }
        }
        Ok(Event {
            seq,
            ts_ms,
            etype,
            attrs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let e = Event::new(7, 1000, 2, &[3.0, 1.5]);
        assert_eq!(e.seq, 7);
        assert_eq!(e.etype, 2);
        assert_eq!(e.attr(0), 3.0);
        assert_eq!(e.attr_id(0), 3);
        assert_eq!(e.attr(1), 1.5);
        assert_eq!(e.attr(5), 0.0);
    }

    #[test]
    #[should_panic(expected = "too many attributes")]
    fn too_many_attrs_panics() {
        Event::new(0, 0, 0, &[0.0; MAX_ATTRS + 1]);
    }

    #[test]
    fn parse_csv_round_trips_and_tolerates_short_rows() {
        let e = Event::new(42, 1234, 3, &[7.0, 1.5]);
        let row = format!(
            "{},{},{},{}",
            e.seq,
            e.ts_ms,
            e.etype,
            e.attrs.map(|a| a.to_string()).join(",")
        );
        assert_eq!(Event::parse_csv(&row).unwrap(), e);
        // wire format: trailing attribute columns are optional
        let short = Event::parse_csv("42,1234,3,7").unwrap();
        assert_eq!(short.seq, 42);
        assert_eq!(short.attr(0), 7.0);
        assert_eq!(short.attr(1), 0.0);
        assert!(Event::parse_csv("not,a,row").is_err());
        assert!(Event::parse_csv("1,2").is_err());
    }

    #[test]
    fn event_is_copy_and_small() {
        // hot-path contract: events are copied into windows without heap work
        fn takes_copy<T: Copy>(_t: T) {}
        takes_copy(Event::new(0, 0, 0, &[]));
        assert!(std::mem::size_of::<Event>() <= 72);
    }
}
