//! Event stream abstraction: a pull-based, totally ordered source of
//! primitive events with its schema attached.

use super::{Event, Schema};

/// A finite or infinite ordered source of events.
pub trait EventStream {
    /// The stream's schema (shared with queries over it).
    fn schema(&self) -> &Schema;

    /// Next event in global order, `None` when exhausted.
    fn next_event(&mut self) -> Option<Event>;

    /// Drain up to `n` events into a vector (convenience for harnesses).
    fn take_events(&mut self, n: usize) -> Vec<Event> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.next_event() {
                Some(e) => out.push(e),
                None => break,
            }
        }
        out
    }
}

/// An in-memory stream over a pre-materialized event vector (used for
/// replays, ground-truth runs and tests).
#[derive(Debug, Clone)]
pub struct VecStream {
    schema: Schema,
    events: Vec<Event>,
    pos: usize,
}

impl VecStream {
    /// Wrap a vector of events with its schema.
    pub fn new(schema: Schema, events: Vec<Event>) -> Self {
        VecStream {
            schema,
            events,
            pos: 0,
        }
    }

    /// Number of events remaining.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.pos
    }

    /// Reset to the beginning (replay).
    pub fn rewind(&mut self) {
        self.pos = 0;
    }

    /// Immutable view of all events.
    pub fn events(&self) -> &[Event] {
        &self.events
    }
}

impl EventStream for VecStream {
    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn next_event(&mut self) -> Option<Event> {
        let e = self.events.get(self.pos).copied();
        if e.is_some() {
            self.pos += 1;
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream3() -> VecStream {
        let mut s = Schema::new();
        s.add_type("t", &["v"]);
        let evs = (0..3)
            .map(|i| Event::new(i, i * 10, 0, &[i as f64]))
            .collect();
        VecStream::new(s, evs)
    }

    #[test]
    fn drains_in_order() {
        let mut st = stream3();
        assert_eq!(st.remaining(), 3);
        let got = st.take_events(10);
        assert_eq!(got.len(), 3);
        assert!(got.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(st.next_event().is_none());
    }

    #[test]
    fn rewind_replays() {
        let mut st = stream3();
        st.take_events(3);
        st.rewind();
        assert_eq!(st.remaining(), 3);
        assert_eq!(st.next_event().unwrap().seq, 0);
    }
}
