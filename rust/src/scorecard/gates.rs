//! Release-over-release regression gates.
//!
//! Two gate families:
//!
//! * **trend gates** — every primary metric of every grid cell is
//!   compared against the baseline ledger entry (same manifest hash,
//!   same smoke flag).  Direction-aware: latency and FN% may not grow,
//!   throughput-at-SLO may not shrink, by more than the configured
//!   percentage (default 5%, per-metric overrides in `[scorecard]`).
//!   Each relative limit carries a small *absolute* tolerance so a
//!   baseline near zero (e.g. `fn_percent = 0` for shedder `none`)
//!   doesn't turn an epsilon wobble into an infinite relative
//!   regression.
//! * **bench gates** — the acceptance checks the perf benches already
//!   compute (`alloc_gate`, `decide_speedup`) are folded in from their
//!   `BENCH_*.json` files so one CI job owns all pass/fail perf
//!   decisions.
//!
//! A violation names its cell (`shedder/dataset`, or `bench`) and
//! metric — the scoreboard's error message is actionable, not "perf
//! got worse somewhere".

use std::fmt;
use std::path::Path;

use anyhow::Context;

use crate::config::ScorecardConfig;

use super::json::Json;
use super::ledger::entry_cell_mean;
use super::metrics::{CellMetrics, PRIMARY_METRICS};

/// Absolute slack on the `p95_ms` gate (virtual ms).
pub const P95_TOL_MS: f64 = 1e-3;
/// Absolute slack on the `fn_percent` gate (percentage points).
pub const FN_TOL_PCT: f64 = 0.5;
/// Absolute slack on the `throughput_at_slo_eps` gate (events/s).
pub const THR_TOL_EPS: f64 = 1.0;

/// Schema tag the bench emitter stamps into `BENCH_*.json`.
pub const BENCH_SCHEMA: &str = "pspice-bench-v1";

/// One failed gate, naming exactly what regressed.
#[derive(Debug, Clone, PartialEq)]
pub struct GateViolation {
    /// `"shedder/dataset"` for trend gates, `"bench"` for bench gates
    pub cell: String,
    /// metric name (`p95_ms`, `fn_percent`, `throughput_at_slo_eps`,
    /// `alloc_gate`, `decide_speedup`)
    pub metric: String,
    /// baseline value (or the bench gate's required value)
    pub prev: f64,
    /// this run's value
    pub cur: f64,
    /// relative limit that was exceeded (0 for exact bench gates)
    pub limit_pct: f64,
}

impl fmt::Display for GateViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {}: baseline {:.6} -> current {:.6} (limit {}%)",
            self.cell, self.metric, self.prev, self.cur, self.limit_pct
        )
    }
}

/// `(higher_is_better, absolute_tolerance)` for a primary metric.
fn direction(metric: &str) -> (bool, f64) {
    match metric {
        "p95_ms" => (false, P95_TOL_MS),
        "fn_percent" => (false, FN_TOL_PCT),
        "throughput_at_slo_eps" => (true, THR_TOL_EPS),
        other => panic!("no gate direction for metric {other:?}"),
    }
}

/// Compare this run's cells against the baseline ledger entry.  No
/// baseline (first run, or the manifest changed) passes vacuously —
/// the appended entry *becomes* the baseline.
pub fn evaluate(
    baseline: Option<&Json>,
    cells: &[CellMetrics],
    sc: &ScorecardConfig,
) -> Vec<GateViolation> {
    let Some(base) = baseline else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for cell in cells {
        let key = cell.key();
        for metric in PRIMARY_METRICS {
            // a cell absent from the baseline can't regress against it
            let Some(prev) = entry_cell_mean(base, &key, metric) else {
                continue;
            };
            let cur = cell.ci(metric).mean;
            let limit = sc.limit_pct_for(metric);
            let (higher_better, tol) = direction(metric);
            let violated = if higher_better {
                cur < prev * (1.0 - limit / 100.0) - tol
            } else {
                cur > prev * (1.0 + limit / 100.0) + tol
            };
            if violated {
                out.push(GateViolation {
                    cell: key.clone(),
                    metric: metric.to_string(),
                    prev,
                    cur,
                    limit_pct: limit,
                });
            }
        }
    }
    out
}

/// Fold one `BENCH_*.json` file into the scoreboard: returns the
/// `(name, value)` summaries recorded in the ledger entry plus any
/// bench-gate violations.
///
/// Gates mirror the benches' own acceptance semantics: `alloc_gate`
/// (from `sharded_throughput`) must report 1.0 — the steady-state hot
/// path performed zero heap allocations; `decide_speedup` (from
/// `shed_overhead`) must be ≥ 2.0 at the full-scale configuration
/// (n ≥ 50 000 partial matches) — smoke-scale speedups are recorded
/// but informational, exactly as the bench itself treats them.
pub fn fold_bench_file(
    path: &Path,
) -> crate::Result<(Vec<(String, f64)>, Vec<GateViolation>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench results {}", path.display()))?;
    let j = Json::parse(&text)
        .with_context(|| format!("parsing bench results {}", path.display()))?;
    anyhow::ensure!(
        j.get("schema").and_then(Json::as_str) == Some(BENCH_SCHEMA),
        "{}: missing \"schema\": \"{BENCH_SCHEMA}\" marker (re-run the bench \
         to stamp it; pre-scorecard files are not gateable)",
        path.display()
    );
    let mut summary = Vec::new();
    let mut violations = Vec::new();

    if let Some(section) = j.get("sharded_throughput") {
        for e in section.items() {
            if e.get("name").and_then(Json::as_str) == Some("alloc_gate") {
                let v = e.get("mean_s").and_then(Json::as_f64).unwrap_or(0.0);
                summary.push(("alloc_gate".to_string(), v));
                if v != 1.0 {
                    violations.push(GateViolation {
                        cell: "bench".to_string(),
                        metric: "alloc_gate".to_string(),
                        prev: 1.0,
                        cur: v,
                        limit_pct: 0.0,
                    });
                }
            }
        }
    }

    if let Some(section) = j.get("shed_overhead") {
        // the bench emits one derived speedup per PM-count rung; gate
        // the largest rung only
        let mut best: Option<(u64, f64)> = None;
        for e in section.items() {
            let Some(name) = e.get("name").and_then(Json::as_str) else {
                continue;
            };
            let n = name
                .strip_prefix("derived.decide_speedup(n=")
                .and_then(|r| r.strip_suffix(')'))
                .and_then(|d| d.parse::<u64>().ok());
            if let (Some(n), Some(v)) = (n, e.get("mean_s").and_then(Json::as_f64)) {
                match best {
                    Some((bn, _)) if bn >= n => {}
                    _ => best = Some((n, v)),
                }
            }
        }
        if let Some((n, v)) = best {
            summary.push(("decide_speedup".to_string(), v));
            if n >= 50_000 && v < 2.0 {
                violations.push(GateViolation {
                    cell: "bench".to_string(),
                    metric: "decide_speedup".to_string(),
                    prev: 2.0,
                    cur: v,
                    limit_pct: 0.0,
                });
            }
        }
    }

    Ok((summary, violations))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, ScorecardConfig};
    use crate::scorecard::ledger::LedgerEntry;
    use crate::scorecard::manifest::RunManifest;
    use crate::scorecard::metrics::RepMetrics;

    fn cell(p95: f64, fnp: f64, thr: f64) -> CellMetrics {
        CellMetrics {
            dataset: "bus".into(),
            query: "q4".into(),
            shedder: "pspice".into(),
            reps: vec![RepMetrics {
                seed: 42,
                p50_ms: 0.01,
                p95_ms: p95,
                p99_ms: p95 * 2.0,
                fn_percent: fnp,
                false_positives: 0.0,
                throughput_at_slo_eps: thr,
                dropped_pms_failure: 0.0,
                recovered_pms: 0.0,
                replayed_events: 0.0,
                hangs_detected: 0.0,
                capacity_ns: 2_000.0,
                wall_events_per_sec: 1e6,
            }],
        }
    }

    fn baseline_entry(cells: Vec<CellMetrics>) -> Json {
        let entry = LedgerEntry {
            manifest: RunManifest {
                smoke: true,
                commit: "base".into(),
                seeds: vec![42],
                sc: ScorecardConfig::default(),
                cells: vec![ExperimentConfig::default()],
            },
            cells,
            blessed: false,
            bench: Vec::new(),
        };
        Json::parse(&entry.to_line()).unwrap()
    }

    #[test]
    fn injected_regression_fails_with_named_metric() {
        let sc = ScorecardConfig::default(); // 5%
        let base = baseline_entry(vec![cell(0.40, 10.0, 100_000.0)]);

        // identical run: clean
        assert!(evaluate(Some(&base), &[cell(0.40, 10.0, 100_000.0)], &sc).is_empty());
        // no baseline: vacuous pass
        assert!(evaluate(None, &[cell(9.9, 99.0, 1.0)], &sc).is_empty());
        // improvement in every direction: clean
        assert!(evaluate(Some(&base), &[cell(0.30, 8.0, 120_000.0)], &sc).is_empty());
        // within limit + tolerance: clean (4% worse p95)
        assert!(evaluate(Some(&base), &[cell(0.416, 10.0, 100_000.0)], &sc).is_empty());

        // >5% p95 regression: named violation
        let v = evaluate(Some(&base), &[cell(0.50, 10.0, 100_000.0)], &sc);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].cell, "pspice/bus");
        assert_eq!(v[0].metric, "p95_ms");
        assert!(v[0].to_string().contains("pspice/bus p95_ms"), "{}", v[0]);

        // >5% throughput drop: named violation (direction-aware)
        let v = evaluate(Some(&base), &[cell(0.40, 10.0, 90_000.0)], &sc);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].metric, "throughput_at_slo_eps");

        // all three at once
        let v = evaluate(Some(&base), &[cell(1.0, 50.0, 1_000.0)], &sc);
        assert_eq!(v.len(), 3);

        // a cell missing from the baseline can't regress
        let mut stranger = cell(9.0, 90.0, 1.0);
        stranger.shedder = "e-bl".into();
        assert!(evaluate(Some(&base), &[stranger], &sc).is_empty());
    }

    #[test]
    fn absolute_tolerance_absorbs_near_zero_baselines() {
        let sc = ScorecardConfig::default();
        // shedder `none` has fn_percent == 0: an epsilon wobble is an
        // infinite relative regression but must NOT trip the gate
        let base = baseline_entry(vec![cell(0.40, 0.0, 100_000.0)]);
        assert!(evaluate(Some(&base), &[cell(0.40, 0.4, 100_000.0)], &sc).is_empty());
        // ... but a real jump past the slack still does
        let v = evaluate(Some(&base), &[cell(0.40, 1.0, 100_000.0)], &sc);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].metric, "fn_percent");
    }

    #[test]
    fn per_metric_override_beats_default() {
        let sc = ScorecardConfig {
            gate_p95_ms_pct: Some(50.0),
            ..ScorecardConfig::default()
        };
        let base = baseline_entry(vec![cell(0.40, 10.0, 100_000.0)]);
        // 25% worse p95 passes under the 50% override...
        assert!(evaluate(Some(&base), &[cell(0.50, 10.0, 100_000.0)], &sc).is_empty());
        // ...while fn_percent still gates at the 5% default
        let v = evaluate(Some(&base), &[cell(0.50, 12.0, 100_000.0)], &sc);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].metric, "fn_percent");
    }

    #[test]
    fn bench_folding_gates_and_summarizes() {
        let dir = std::env::temp_dir().join("pspice_gates_test");
        std::fs::create_dir_all(&dir).unwrap();

        let good = dir.join("good.json");
        std::fs::write(
            &good,
            "{\n  \"schema\": \"pspice-bench-v1\",\n  \"sharded_throughput\": \
             [{\"name\": \"alloc_gate\", \"mean_s\": 1, \"stddev_s\": 0, \"items\": 0, \"items_per_s\": 0}],\n  \
             \"shed_overhead\": [{\"name\": \"derived.decide_speedup(n=2000)\", \"mean_s\": 1.1, \"stddev_s\": 0, \"items\": 0, \"items_per_s\": 0}, \
             {\"name\": \"derived.decide_speedup(n=200000)\", \"mean_s\": 3.4, \"stddev_s\": 0, \"items\": 0, \"items_per_s\": 0}]\n}\n",
        )
        .unwrap();
        let (summary, violations) = fold_bench_file(&good).unwrap();
        assert!(violations.is_empty(), "{violations:?}");
        assert!(summary.contains(&("alloc_gate".to_string(), 1.0)));
        // largest rung wins
        assert!(summary.contains(&("decide_speedup".to_string(), 3.4)));

        let bad = dir.join("bad.json");
        std::fs::write(
            &bad,
            "{\n  \"schema\": \"pspice-bench-v1\",\n  \"sharded_throughput\": \
             [{\"name\": \"alloc_gate\", \"mean_s\": 0, \"stddev_s\": 0, \"items\": 7, \"items_per_s\": 0}],\n  \
             \"shed_overhead\": [{\"name\": \"derived.decide_speedup(n=200000)\", \"mean_s\": 1.2, \"stddev_s\": 0, \"items\": 0, \"items_per_s\": 0}]\n}\n",
        )
        .unwrap();
        let (_, violations) = fold_bench_file(&bad).unwrap();
        assert_eq!(violations.len(), 2);
        assert_eq!(violations[0].metric, "alloc_gate");
        assert_eq!(violations[1].metric, "decide_speedup");

        // smoke-scale speedup below 2x is informational, not a gate
        let smoke = dir.join("smoke.json");
        std::fs::write(
            &smoke,
            "{\n  \"schema\": \"pspice-bench-v1\",\n  \
             \"shed_overhead\": [{\"name\": \"derived.decide_speedup(n=2000)\", \"mean_s\": 1.2, \"stddev_s\": 0, \"items\": 0, \"items_per_s\": 0}]\n}\n",
        )
        .unwrap();
        let (summary, violations) = fold_bench_file(&smoke).unwrap();
        assert!(violations.is_empty());
        assert!(summary.contains(&("decide_speedup".to_string(), 1.2)));

        // unstamped (pre-scorecard) files are rejected loudly
        let unstamped = dir.join("unstamped.json");
        std::fs::write(&unstamped, "{\n  \"shed_overhead\": []\n}\n").unwrap();
        assert!(fold_bench_file(&unstamped).is_err());
        assert!(fold_bench_file(&dir.join("missing.json")).is_err());
    }
}
