//! The committed trend ledger: `SCORECARD.jsonl` at the repo root, one
//! JSON object per line, one line per release (plus `--smoke` lines
//! from CI).  Appending is the scoreboard's job; this module owns the
//! line format, parsing, baseline selection, and the append itself.
//!
//! Baseline selection is by **manifest hash**: the newest earlier entry
//! with the same `smoke` flag and the same `manifest_hash` is the
//! comparison point for the regression gates.  A hash miss (first run,
//! or the grid/config changed) means there is nothing comparable — the
//! gates pass vacuously and the new entry becomes the baseline for the
//! next release.  That keeps "we changed the experiment" from
//! masquerading as "the code regressed".

use std::io::Write;
use std::path::Path;

use super::json::{esc, num, Json};
use super::metrics::{CellMetrics, ALL_METRICS};
use super::manifest::{RunManifest, SCHEMA};

/// One scoreboard run, serialized as a single `SCORECARD.jsonl` line.
#[derive(Debug, Clone)]
pub struct LedgerEntry {
    /// run identity (hash, seeds, commit, grid)
    pub manifest: RunManifest,
    /// per-cell aggregates
    pub cells: Vec<CellMetrics>,
    /// true when gate violations were deliberately accepted with
    /// `--bless` (see EXPERIMENTS.md note #5)
    pub blessed: bool,
    /// bench-gate summaries folded in from `BENCH_*.json` files
    /// (name, value) — recorded for the trend, gated separately
    pub bench: Vec<(String, f64)>,
}

impl LedgerEntry {
    /// Serialize as one JSONL line (no trailing newline).  Contains no
    /// timestamps — identical runs produce identical lines, which is
    /// what lets CI diff the committed ledger against a fresh run.
    pub fn to_line(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str(&format!(
            "{{\"schema\": \"{}\", \"smoke\": {}, \"commit\": \"{}\", \
             \"manifest_hash\": \"{}\", \"seeds\": [{}], \"blessed\": {}, \
             \"max_regression_pct\": {}, \"cells\": [",
            SCHEMA,
            self.manifest.smoke,
            esc(&self.manifest.commit),
            self.manifest.hash(),
            self.manifest
                .seeds
                .iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(", "),
            self.blessed,
            num(self.manifest.sc.max_regression_pct),
        ));
        for (i, cell) in self.cells.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"dataset\": \"{}\", \"query\": \"{}\", \"shedder\": \"{}\", \
                 \"metrics\": {{",
                esc(&cell.dataset),
                esc(&cell.query),
                esc(&cell.shedder),
            ));
            for (j, m) in ALL_METRICS.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                let ci = cell.ci(m);
                s.push_str(&format!(
                    "\"{}\": {{\"mean\": {}, \"stddev\": {}, \"ci95\": {}, \"n\": {}}}",
                    m,
                    num(ci.mean),
                    num(ci.stddev),
                    num(ci.ci95),
                    ci.n
                ));
            }
            s.push_str("}}");
        }
        s.push_str("], \"bench\": {");
        for (i, (name, v)) in self.bench.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\": {}", esc(name), num(*v)));
        }
        s.push_str("}}");
        s
    }
}

/// The parsed ledger (oldest first, same order as the file).
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    /// parsed entry objects
    pub entries: Vec<Json>,
}

impl Ledger {
    /// Read and parse `path`.  A missing file is an empty ledger; a
    /// malformed line is an error (the ledger is committed — corruption
    /// should fail loudly, not silently drop history).
    pub fn read(path: &Path) -> crate::Result<Ledger> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Ledger::default())
            }
            Err(e) => return Err(e.into()),
        };
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{} line {}: {e}", path.display(), i + 1))?;
            anyhow::ensure!(
                j.get("schema").and_then(Json::as_str) == Some(SCHEMA),
                "{} line {}: unknown or missing schema tag",
                path.display(),
                i + 1
            );
            entries.push(j);
        }
        Ok(Ledger { entries })
    }

    /// The newest entry with this `smoke` flag and `manifest_hash` —
    /// the regression-gate baseline (None = nothing comparable).
    pub fn baseline(&self, smoke: bool, manifest_hash: &str) -> Option<&Json> {
        self.entries.iter().rev().find(|e| {
            e.get("smoke").and_then(Json::as_bool) == Some(smoke)
                && e.get("manifest_hash").and_then(Json::as_str) == Some(manifest_hash)
        })
    }

    /// Append one line to the ledger file (created if missing).
    pub fn append_line(path: &Path, line: &str) -> crate::Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        writeln!(f, "{line}")?;
        Ok(())
    }
}

/// The mean of `metric` for cell `key` ("shedder/dataset") inside a
/// parsed ledger entry.
pub fn entry_cell_mean(entry: &Json, key: &str, metric: &str) -> Option<f64> {
    for cell in entry.get("cells")?.items() {
        let shedder = cell.get("shedder").and_then(Json::as_str)?;
        let dataset = cell.get("dataset").and_then(Json::as_str)?;
        if format!("{shedder}/{dataset}") == key {
            return cell.get("metrics")?.get(metric)?.get("mean")?.as_f64();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, ScorecardConfig};
    use crate::scorecard::metrics::RepMetrics;

    fn entry(p95: f64, smoke: bool) -> LedgerEntry {
        LedgerEntry {
            manifest: RunManifest {
                smoke,
                commit: "abc123".into(),
                seeds: vec![42, 43],
                sc: ScorecardConfig::default(),
                cells: vec![ExperimentConfig::default()],
            },
            cells: vec![CellMetrics {
                dataset: "bus".into(),
                query: "q4".into(),
                shedder: "pspice".into(),
                reps: vec![RepMetrics {
                    seed: 42,
                    p50_ms: 0.01,
                    p95_ms: p95,
                    p99_ms: 0.09,
                    fn_percent: 12.5,
                    false_positives: 0.0,
                    throughput_at_slo_eps: 500_000.0,
                    dropped_pms_failure: 0.0,
                    recovered_pms: 0.0,
                    replayed_events: 0.0,
                    hangs_detected: 0.0,
                    capacity_ns: 2_000.0,
                    wall_events_per_sec: 1e6,
                }],
            }],
            blessed: false,
            bench: vec![("alloc_gate".into(), 1.0)],
        }
    }

    #[test]
    fn line_round_trips_and_baseline_matches_by_hash() {
        let e = entry(0.04, true);
        let line = e.to_line();
        assert_eq!(line, entry(0.04, true).to_line(), "deterministic line");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(
            entry_cell_mean(&j, "pspice/bus", "p95_ms"),
            Some(0.04)
        );
        assert_eq!(entry_cell_mean(&j, "pspice/bus", "fn_percent"), Some(12.5));
        assert_eq!(entry_cell_mean(&j, "e-bl/bus", "p95_ms"), None);
        assert_eq!(
            j.get("bench").unwrap().get("alloc_gate").and_then(Json::as_f64),
            Some(1.0)
        );

        let dir = std::env::temp_dir().join("pspice_ledger_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SCORECARD.jsonl");
        let _ = std::fs::remove_file(&path);
        Ledger::append_line(&path, &line).unwrap();
        Ledger::append_line(&path, &entry(0.05, false).to_line()).unwrap();
        let ledger = Ledger::read(&path).unwrap();
        assert_eq!(ledger.entries.len(), 2);
        let hash = e.manifest.hash();
        // smoke flag participates in baseline selection
        let base = ledger.baseline(true, &hash).unwrap();
        assert_eq!(entry_cell_mean(base, "pspice/bus", "p95_ms"), Some(0.04));
        // the full entry hashes differently (smoke is hashed), so the
        // smoke baseline is NOT comparable to it
        assert!(ledger.baseline(false, &hash).is_none());
        assert!(ledger.baseline(true, "fnv1a:0000000000000000").is_none());
        // missing file = empty ledger; garbage = loud error
        assert!(Ledger::read(&dir.join("missing.jsonl")).unwrap().entries.is_empty());
        std::fs::write(dir.join("bad.jsonl"), "not json\n").unwrap();
        assert!(Ledger::read(&dir.join("bad.jsonl")).is_err());
        std::fs::write(dir.join("wrong.jsonl"), "{\"schema\": \"other\"}\n").unwrap();
        assert!(Ledger::read(&dir.join("wrong.jsonl")).is_err());
    }
}
