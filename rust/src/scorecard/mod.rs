//! The gated evaluation subsystem ("scorecard"): run manifests, QoR and
//! latency metrics with confidence intervals, a committed trend ledger,
//! and release-over-release regression gates.
//!
//! The problem this solves: the repo's evaluation claims (pSPICE beats
//! the baselines at equal drop rates; the sharded runtime holds its
//! speedup; the hot path stays allocation-free) were each checked by a
//! bespoke script or a human reading bench output.  The scorecard makes
//! the whole protocol one command with one pass/fail answer:
//!
//! ```text
//! cargo run --release -- scoreboard [--smoke]
//! ```
//!
//! * [`manifest`] — [`manifest::RunManifest`] pins *everything* a run
//!   consumed (seeds, resolved configs, dataset identities, gate
//!   settings) under a content hash: same hash ⇒ same inputs ⇒ (under
//!   the sim clock) bit-identical primary metrics.
//! * [`metrics`] — p50/p95/p99 latency, throughput-at-SLO, and QoR
//!   (FN%/FP vs each run's own shedder-`none` ground truth), aggregated
//!   with 95% confidence intervals over repeated seeds.
//! * [`ledger`] — `SCORECARD.jsonl` at the repo root: one JSON line per
//!   release, committed, so the metric trend travels with the history.
//! * [`gates`] — "no more than 5% worse than the baseline entry on any
//!   primary metric" (per-metric overrides in `[scorecard]`), plus the
//!   perf benches' own acceptance checks folded in from `BENCH_*.json`.
//! * [`board`] — the driver tying it together and regenerating figures.
//! * [`json`] — the minimal JSON reader both the ledger and the bench
//!   folding parse with (no `serde_json` in the offline crate set).
//!
//! See EXPERIMENTS.md note #5 for metric definitions, the ground-truth
//! QoR methodology, gate semantics, and how to bless an intentional
//! regression.

pub mod board;
pub mod gates;
pub mod json;
pub mod ledger;
pub mod manifest;
pub mod metrics;

pub use board::{grid, run_cells, run_scoreboard, ScoreboardOpts};
pub use gates::{GateViolation, BENCH_SCHEMA};
pub use ledger::{Ledger, LedgerEntry};
pub use manifest::{cfg_canonical, RunManifest, SCHEMA};
pub use metrics::{CellMetrics, Ci, RepMetrics, ALL_METRICS, PRIMARY_METRICS};
