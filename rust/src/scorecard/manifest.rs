//! Run manifests: the full resolved inputs of a scoreboard run —
//! seeds, grid configurations, gate settings, dataset identities,
//! shard/batch/clock settings — plus a content hash over a canonical
//! serialization, so two runs with the same hash provably consumed the
//! same inputs (and, under the sim clock, provably produce the same
//! primary metrics — pinned by `tests/scorecard.rs`).
//!
//! The hash deliberately covers *inputs only*: the git commit and any
//! wall-clock facts are recorded alongside but excluded, so the ledger
//! can compare entries across releases ("same experiment, different
//! code") — the whole point of a trend gate.

use crate::config::{ExperimentConfig, ScorecardConfig};

use super::json::{esc, num};

/// Ledger/manifest schema tag (bump on breaking layout changes).
pub const SCHEMA: &str = "pspice-scorecard-v1";

/// The resolved identity of one scoreboard run.
#[derive(Debug, Clone)]
pub struct RunManifest {
    /// smoke (CI-sized) or full grid
    pub smoke: bool,
    /// git commit the run was built from (recorded, NOT hashed)
    pub commit: String,
    /// dataset seeds, one run per seed per cell
    pub seeds: Vec<u64>,
    /// gate/repetition settings
    pub sc: ScorecardConfig,
    /// fully resolved per-cell configurations (seed = first of `seeds`)
    pub cells: Vec<ExperimentConfig>,
}

/// Canonical one-line serialization of one experiment configuration:
/// every field that influences the run, in a fixed order, floats in
/// shortest round-trip form.  The manifest hash and the determinism
/// tests both key off this — extend it whenever `ExperimentConfig`
/// grows a field that changes results.
pub fn cfg_canonical(cfg: &ExperimentConfig) -> String {
    format!(
        "query={};window={};pattern_n={};slide={};dataset={};seed={};events={};\
         warmup={};rate={};lb_ms={};shedder={};model={};weights={:?};\
         cost_factors={:?};retrain_every={};drift_threshold={};shards={};\
         batch={};overload={};source={};codec={};ingest_capacity={};\
         ingest_policy={};duration_ms={};checkpoint_every={};journal_cap={};\
         worker_deadline_ms={};faults={}",
        cfg.query,
        cfg.window,
        cfg.pattern_n,
        cfg.slide,
        cfg.dataset.name(),
        cfg.seed,
        cfg.events,
        cfg.warmup,
        cfg.rate,
        cfg.lb_ms,
        cfg.shedder.name(),
        cfg.model.name(),
        cfg.weights,
        cfg.cost_factors,
        cfg.retrain_every,
        cfg.drift_threshold,
        cfg.shards,
        cfg.batch,
        cfg.overload.name(),
        cfg.source.name(),
        cfg.codec.name(),
        cfg.ingest_capacity,
        cfg.ingest_policy.name(),
        cfg.duration_ms,
        cfg.checkpoint_every,
        cfg.journal_cap,
        cfg.worker_deadline_ms,
        // the fault spec is comma-separated by construction, so it can
        // never smuggle a field separator into this line
        cfg.faults,
    )
}

/// 64-bit FNV-1a over `bytes` — tiny, dependency-free, and stable
/// across platforms/releases, which is all a content fingerprint needs
/// (this is an identity check, not a security boundary).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl RunManifest {
    /// The canonical input serialization the content hash covers:
    /// schema, smoke flag, seeds, gate settings, and every cell config
    /// — but never the commit or anything wall-clock.
    pub fn canonical(&self) -> String {
        let mut s = format!(
            "schema={};smoke={};seeds={:?};reps={};base_seed={};\
             max_regression_pct={};gate_p95_ms_pct={:?};\
             gate_fn_percent_pct={:?};gate_throughput_pct={:?}\n",
            SCHEMA,
            self.smoke,
            self.seeds,
            self.sc.reps,
            self.sc.base_seed,
            self.sc.max_regression_pct,
            self.sc.gate_p95_ms_pct,
            self.sc.gate_fn_percent_pct,
            self.sc.gate_throughput_pct,
        );
        for cfg in &self.cells {
            s.push_str(&cfg_canonical(cfg));
            s.push('\n');
        }
        s
    }

    /// The content hash (`fnv1a:<16 hex digits>`).
    pub fn hash(&self) -> String {
        format!("fnv1a:{:016x}", fnv1a(self.canonical().as_bytes()))
    }

    /// Full manifest as pretty JSON (the artifact written next to the
    /// figures; the ledger line carries only the hash + seeds +
    /// commit).
    pub fn to_json(&self) -> String {
        let cells: Vec<String> = self
            .cells
            .iter()
            .map(|c| format!("    \"{}\"", esc(&cfg_canonical(c))))
            .collect();
        let seeds: Vec<String> = self.seeds.iter().map(|s| s.to_string()).collect();
        format!(
            "{{\n  \"schema\": \"{}\",\n  \"smoke\": {},\n  \"commit\": \"{}\",\n  \
             \"manifest_hash\": \"{}\",\n  \"seeds\": [{}],\n  \
             \"max_regression_pct\": {},\n  \"cells\": [\n{}\n  ]\n}}\n",
            SCHEMA,
            self.smoke,
            esc(&self.commit),
            self.hash(),
            seeds.join(", "),
            num(self.sc.max_regression_pct),
            cells.join(",\n"),
        )
    }
}

/// Best-effort git commit identity: `git rev-parse HEAD`, then the
/// `GITHUB_SHA` CI variable, then `"unknown"`.  Recorded in the ledger
/// for humans; never part of the content hash.
pub fn git_commit() -> String {
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
    {
        if out.status.success() {
            let s = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
    }
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    "unknown".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> RunManifest {
        RunManifest {
            smoke: true,
            commit: "deadbeef".into(),
            seeds: vec![42, 43],
            sc: ScorecardConfig::default(),
            cells: vec![ExperimentConfig::default()],
        }
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hash_covers_inputs_not_commit() {
        let m = manifest();
        let h = m.hash();
        assert_eq!(h, manifest().hash(), "identical inputs, identical hash");
        let mut other_commit = manifest();
        other_commit.commit = "cafebabe".into();
        assert_eq!(h, other_commit.hash(), "commit must not perturb the hash");
        let mut other_seed = manifest();
        other_seed.cells[0].seed = 7;
        assert_ne!(h, other_seed.hash(), "a config change must change the hash");
        let mut other_smoke = manifest();
        other_smoke.smoke = false;
        assert_ne!(h, other_smoke.hash());
        assert!(h.starts_with("fnv1a:"), "{h}");
        assert_eq!(h.len(), "fnv1a:".len() + 16);
    }

    #[test]
    fn manifest_json_parses_back() {
        let m = manifest();
        let j = super::super::json::Json::parse(&m.to_json()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(j.get("manifest_hash").unwrap().as_str(), Some(m.hash().as_str()));
        assert_eq!(j.get("cells").unwrap().items().len(), 1);
        assert_eq!(j.get("smoke").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn cfg_canonical_tracks_every_live_field() {
        // a coarse tripwire: if someone adds a result-shaping config
        // field without extending cfg_canonical, the semicolon count
        // here goes stale and this test points at the contract
        let line = cfg_canonical(&ExperimentConfig::default());
        assert_eq!(line.matches(';').count(), 27, "{line}");
        assert!(line.contains("codec=lines"));
        assert!(line.contains("shedder=pspice"));
        assert!(line.ends_with("faults="), "empty plan serializes empty");
    }
}
