//! A minimal JSON reader/writer (the vendored crate set has no
//! `serde_json`; see DESIGN.md §3 for the same story as `toml_lite`).
//!
//! Reads the whole of what the repo's own tooling emits — ledger lines
//! in `SCORECARD.jsonl`, `BENCH_*.json` bench results — and nothing
//! more exotic: objects, arrays, strings with the common escapes,
//! numbers, booleans, null.  Writing goes through [`esc`] and [`num`]
//! so emitted lines parse back exactly.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (f64 is exact for every value this repo emits)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (sorted map: deterministic iteration for re-emission)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse `text` as a single JSON value (trailing whitespace ok).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(
            pos == bytes.len(),
            "trailing garbage at byte {pos} of json"
        );
        Ok(v)
    }

    /// Object member lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number value (None for non-numbers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String value (None for non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Bool value (None for non-booleans).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items (empty slice for non-arrays).
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v.as_slice(),
            _ => &[],
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> crate::Result<()> {
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == c,
        "expected {:?} at byte {} of json",
        c as char,
        *pos
    );
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of json");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> crate::Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "bad literal at byte {} of json",
        *pos
    );
    *pos += lit.len();
    Ok(v)
}

fn parse_obj(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    expect(b, pos, b'{')?;
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object in json");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            c => anyhow::bail!("expected ',' or '}}', got {:?} in json", c as char),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    expect(b, pos, b'[')?;
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array in json");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            c => anyhow::bail!("expected ',' or ']', got {:?} in json", c as char),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> crate::Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape in json");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "short \\u escape in json");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("unknown escape \\{:?} in json", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 scalar, not a byte
                let s = std::str::from_utf8(&b[*pos..])?;
                let ch = s.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
    anyhow::bail!("unterminated string in json")
}

fn parse_num(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let v: f64 = s
        .parse()
        .map_err(|e| anyhow::anyhow!("bad number {s:?} at byte {start} of json: {e}"))?;
    Ok(Json::Num(v))
}

/// Escape `s` for embedding in a JSON string literal.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format a float so it parses back bit-identically (Rust's shortest
/// round-trip `Display`); non-finite values become `null`.
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_shapes_we_emit() {
        let j = Json::parse(
            r#"{"schema": "pspice-bench-v1", "xs": [1, 2.5, -3e-2], "ok": true, "none": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("pspice-bench-v1"));
        let xs: Vec<f64> = j.get("xs").unwrap().items().iter().filter_map(|v| v.as_f64()).collect();
        assert_eq!(xs, vec![1.0, 2.5, -0.03]);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("none"), Some(&Json::Null));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn strings_round_trip_through_esc() {
        let s = "quote\" slash\\ tab\t newline\n unicode é";
        let j = Json::parse(&format!("\"{}\"", esc(s))).unwrap();
        assert_eq!(j.as_str(), Some(s));
    }

    #[test]
    fn floats_round_trip_through_num() {
        for v in [0.1 + 0.2, 1.0 / 3.0, 1e-12, 123456789.123456, 0.0] {
            let j = Json::parse(&num(v)).unwrap();
            assert_eq!(j.as_f64().unwrap().to_bits(), v.to_bits());
        }
        assert_eq!(num(f64::NAN), "null");
    }
}
