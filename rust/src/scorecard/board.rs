//! The scoreboard driver: `cargo run --release -- scoreboard
//! [--smoke]` in one command runs the full strategy × dataset grid
//! over repeated seeds, aggregates the metrics layer, evaluates the
//! regression gates against the committed ledger, appends the new
//! entry, and regenerates figures — the whole evaluation protocol, no
//! manual steps to forget.
//!
//! The grid covers every strategy (`none` / `pspice` / `pspice--` /
//! `pm-bl` / `e-bl`) on each of the three datasets at that dataset's
//! canonical query (bus→q4, soccer→q3, stock→q1).  `--smoke` shrinks
//! the traces to CI scale; smoke and full runs hash differently and
//! never gate against each other.

use std::path::PathBuf;

use anyhow::Context;

use crate::config::{ExperimentConfig, ScorecardConfig};
use crate::datasets::DatasetKind;
use crate::harness::figures::{self, FigureOpts};
use crate::harness::run_experiment;
use crate::shedding::ALL_SHEDDER_KINDS;

use super::gates;
use super::ledger::{Ledger, LedgerEntry};
use super::manifest::{git_commit, RunManifest};
use super::metrics::{CellMetrics, RepMetrics, PRIMARY_METRICS};

/// Scoreboard invocation options (CLI flags resolve into this).
#[derive(Debug, Clone)]
pub struct ScoreboardOpts {
    /// CI-sized traces (12k events) instead of full scale (60k)
    pub smoke: bool,
    /// optional TOML with a `[scorecard]` section (reps, gate limits)
    pub config_path: Option<PathBuf>,
    /// the trend ledger to gate against and append to
    pub ledger_path: PathBuf,
    /// where the manifest artifact and figure CSVs go
    pub out_dir: PathBuf,
    /// `BENCH_*.json` files whose acceptance gates fold into this run
    pub bench_json: Vec<PathBuf>,
    /// append despite gate violations, marking the entry blessed
    pub bless: bool,
}

impl Default for ScoreboardOpts {
    fn default() -> Self {
        ScoreboardOpts {
            smoke: false,
            config_path: None,
            ledger_path: PathBuf::from("SCORECARD.jsonl"),
            out_dir: PathBuf::from("results/scorecard"),
            bench_json: Vec::new(),
            bless: false,
        }
    }
}

/// The canonical per-dataset cell configuration.  Window/pattern/LB
/// choices follow the proven figure-driver configurations
/// ([`crate::harness::figures`]); smoke runs shrink the trace and
/// loosen nothing else.
fn dataset_cfg(dataset: DatasetKind, smoke: bool) -> ExperimentConfig {
    let (query, window, pattern_n, slide) = match dataset {
        DatasetKind::Bus => ("q4", 2_000, 4, 250),
        DatasetKind::Soccer => ("q3", 1_500, 4, 500),
        DatasetKind::Stock => ("q1", if smoke { 2_000 } else { 5_000 }, 0, 500),
    };
    let lb_ms = match dataset {
        // q4/q3 latencies sit well under a ms at smoke scale; stock's
        // q1 runs a bigger window and needs the figure-driver bound
        DatasetKind::Bus | DatasetKind::Soccer if smoke => 0.05,
        _ => 0.5,
    };
    ExperimentConfig {
        query: query.into(),
        window,
        pattern_n,
        slide,
        dataset,
        events: if smoke { 12_000 } else { 60_000 },
        warmup: if smoke { 12_000 } else { 60_000 },
        rate: if smoke { 1.4 } else { 1.2 },
        lb_ms,
        ..ExperimentConfig::default()
    }
}

/// The full evaluation grid: every strategy on every dataset (15
/// cells), in canonical order (datasets outer, strategies inner).
pub fn grid(smoke: bool) -> Vec<ExperimentConfig> {
    let mut cells = Vec::new();
    for dataset in [DatasetKind::Bus, DatasetKind::Soccer, DatasetKind::Stock] {
        for shedder in ALL_SHEDDER_KINDS {
            let mut cfg = dataset_cfg(dataset, smoke);
            cfg.shedder = shedder;
            cells.push(cfg);
        }
    }
    cells
}

/// Run every cell once per seed and aggregate (also the entry point
/// the determinism tests drive with a reduced grid).
pub fn run_cells(
    cfgs: &[ExperimentConfig],
    seeds: &[u64],
) -> crate::Result<Vec<CellMetrics>> {
    let mut cells = Vec::with_capacity(cfgs.len());
    for cfg in cfgs {
        let mut reps = Vec::with_capacity(seeds.len());
        for &seed in seeds {
            let mut c = cfg.clone();
            c.seed = seed;
            let r = run_experiment(&c)
                .with_context(|| format!("cell {}/{} seed {seed}", cfg.shedder.name(), cfg.dataset.name()))?;
            reps.push(RepMetrics::from_result(&c, &r));
        }
        let cell = CellMetrics {
            dataset: cfg.dataset.name().to_string(),
            query: cfg.query.clone(),
            shedder: cfg.shedder.name().to_string(),
            reps,
        };
        let p95 = cell.ci("p95_ms");
        let fnp = cell.ci("fn_percent");
        let thr = cell.ci("throughput_at_slo_eps");
        println!(
            "[scoreboard] {:<16} p95={:.4}±{:.4}ms  fn={:.2}±{:.2}%  thr@slo={:.0}±{:.0} ev/s  (n={})",
            cell.key(),
            p95.mean,
            p95.ci95,
            fnp.mean,
            fnp.ci95,
            thr.mean,
            thr.ci95,
            p95.n
        );
        cells.push(cell);
    }
    Ok(cells)
}

/// One-command evaluation: grid → metrics → gates → ledger → figures.
/// Fails (and does NOT append) when a gate is violated, naming every
/// offending cell/metric; `--bless` records the regression instead.
pub fn run_scoreboard(opts: &ScoreboardOpts) -> crate::Result<()> {
    let sc = match &opts.config_path {
        Some(p) => ScorecardConfig::from_file_or_default(p)?,
        None => ScorecardConfig::default(),
    };
    let seeds: Vec<u64> = (0..sc.reps as u64).map(|r| sc.base_seed + r).collect();
    let cfgs = grid(opts.smoke);
    let manifest = RunManifest {
        smoke: opts.smoke,
        commit: git_commit(),
        seeds: seeds.clone(),
        sc: sc.clone(),
        cells: cfgs.clone(),
    };
    let hash = manifest.hash();
    println!(
        "[scoreboard] {} grid: {} cells x {} seeds, manifest {hash}",
        if opts.smoke { "smoke" } else { "full" },
        cfgs.len(),
        seeds.len()
    );

    let cells = run_cells(&cfgs, &seeds)?;

    // fold the perf benches' own acceptance checks into this run's
    // gate set (and into the ledger entry, for the trend)
    let mut bench = Vec::new();
    let mut violations = Vec::new();
    for p in &opts.bench_json {
        let (summary, v) = gates::fold_bench_file(p)?;
        bench.extend(summary);
        violations.extend(v);
    }

    let ledger = Ledger::read(&opts.ledger_path)?;
    let baseline = ledger.baseline(opts.smoke, &hash);
    if baseline.is_none() {
        println!(
            "[scoreboard] no comparable baseline in {} (hash {hash}) — this \
             run establishes one; trend gates pass vacuously",
            opts.ledger_path.display()
        );
    }
    violations.extend(gates::evaluate(baseline, &cells, &sc));

    // artifacts: pinned manifest + regenerated figures next to it
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(opts.out_dir.join("manifest.json"), manifest.to_json())?;
    let fig = FigureOpts {
        scale: if opts.smoke { 0.02 } else { 0.2 },
        out_dir: opts.out_dir.clone(),
    };
    figures::fig9b(&fig)?;
    if !opts.smoke {
        figures::fig7(&fig)?;
        figures::fig8(&fig)?;
    }

    let blessed = opts.bless && !violations.is_empty();
    if !violations.is_empty() {
        for v in &violations {
            eprintln!("[scoreboard] GATE VIOLATION: {v}");
        }
        if !opts.bless {
            let names: Vec<String> = violations
                .iter()
                .map(|v| format!("{} {}", v.cell, v.metric))
                .collect();
            anyhow::bail!(
                "scoreboard: {} regression gate(s) failed ({}); rerun with \
                 --bless to record an intentional regression",
                violations.len(),
                names.join(", ")
            );
        }
        eprintln!("[scoreboard] --bless: recording the regression as intentional");
    }

    let entry = LedgerEntry { manifest, cells, blessed, bench };
    Ledger::append_line(&opts.ledger_path, &entry.to_line())?;
    println!(
        "[scoreboard] appended entry {hash} to {} ({} primary metrics gated per cell)",
        opts.ledger_path.display(),
        PRIMARY_METRICS.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shedding::ShedderKind;

    #[test]
    fn grid_covers_every_strategy_on_every_dataset() {
        for smoke in [true, false] {
            let g = grid(smoke);
            assert_eq!(g.len(), 15, "5 strategies x 3 datasets");
            for kind in ALL_SHEDDER_KINDS {
                assert_eq!(g.iter().filter(|c| c.shedder == kind).count(), 3);
            }
            for (dataset, query) in [
                (DatasetKind::Bus, "q4"),
                (DatasetKind::Soccer, "q3"),
                (DatasetKind::Stock, "q1"),
            ] {
                let ds: Vec<_> = g.iter().filter(|c| c.dataset == dataset).collect();
                assert_eq!(ds.len(), 5);
                assert!(ds.iter().all(|c| c.query == query));
            }
            // smoke shrinks the trace, not the grid
            let events = g[0].events;
            assert_eq!(events, if smoke { 12_000 } else { 60_000 });
        }
        // smoke and full must hash differently end to end
        let smoke_grid = grid(true);
        let full_grid = grid(false);
        assert_ne!(
            super::super::manifest::cfg_canonical(&smoke_grid[0]),
            super::super::manifest::cfg_canonical(&full_grid[0])
        );
        assert!(smoke_grid.iter().any(|c| c.shedder == ShedderKind::None));
    }
}
