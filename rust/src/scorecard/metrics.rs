//! The scoreboard's metrics layer: per-repetition primary metrics
//! distilled from an [`ExperimentResult`], and per-cell aggregates with
//! 95% confidence intervals over the repeated seeds.
//!
//! Two metric classes, by design:
//!
//! * **primary** (gated, hashed into determinism tests) — p50/p95/p99
//!   latency, QoR (weighted FN% against the run's own shedder-none
//!   ground truth, false positives), and throughput-at-SLO.  All are
//!   functions of *virtual* time and the seeded trace, so under the sim
//!   clock two runs of the same manifest produce bit-identical values.
//! * **informational** (recorded, never gated) — wall-clock events/s,
//!   which varies with the host and would make every gate flaky.
//!
//! Throughput-at-SLO is the offered load actually served within the
//! latency bound: `offered_eps × (1 − violation_rate)`, with
//! `offered_eps = rate × 10⁹ / capacity_ns` (the virtual arrival rate
//! the experiment drives).  It is continuous — a strategy that holds
//! the bound on 99% of events scores 99% of the offered rate — and
//! deterministic, unlike a wall-clock throughput measurement.

use crate::config::ExperimentConfig;
use crate::harness::ExperimentResult;

/// Mean ± spread of one metric over the repetition seeds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ci {
    /// sample mean
    pub mean: f64,
    /// sample standard deviation (n−1 denominator; 0 for n = 1)
    pub stddev: f64,
    /// 95% confidence half-width: `1.96 · stddev / √n`
    pub ci95: f64,
    /// sample count
    pub n: usize,
}

impl Ci {
    /// Aggregate `xs` (empty input → all-zero CI).
    pub fn from_samples(xs: &[f64]) -> Ci {
        let n = xs.len();
        if n == 0 {
            return Ci { mean: 0.0, stddev: 0.0, ci95: 0.0, n: 0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let stddev = if n > 1 {
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
            var.sqrt()
        } else {
            0.0
        };
        let ci95 = 1.96 * stddev / (n as f64).sqrt();
        Ci { mean, stddev, ci95, n }
    }
}

/// The distilled metrics of one repetition (one seed, one cell).
#[derive(Debug, Clone, Copy)]
pub struct RepMetrics {
    /// dataset seed of this repetition
    pub seed: u64,
    /// latency quantiles over the measurement phase (virtual ms)
    pub p50_ms: f64,
    /// 95th percentile latency (virtual ms)
    pub p95_ms: f64,
    /// 99th percentile latency (virtual ms)
    pub p99_ms: f64,
    /// weighted false-negative % vs this seed's shedder-none truth run
    pub fn_percent: f64,
    /// detected-but-untrue complex events
    pub false_positives: f64,
    /// offered load served within the latency bound (virtual events/s)
    pub throughput_at_slo_eps: f64,
    /// PMs lost to crashed shard workers and accounted as involuntary
    /// shed (0 on healthy runs; deterministic under a seeded
    /// [`crate::runtime::FaultPlan`], so chaos entries trend it —
    /// recorded, never gated, because healthy baselines sit at 0)
    pub dropped_pms_failure: f64,
    /// PMs restored by checkpointed recovery instead of being lost
    /// (recorded, never gated: healthy baselines sit at 0)
    pub recovered_pms: f64,
    /// journaled events replayed into respawned workers (recorded,
    /// never gated)
    pub replayed_events: f64,
    /// worker hangs detected by the dispatch deadline (recorded, never
    /// gated)
    pub hangs_detected: f64,
    /// measured capacity (virtual ns/event) — context, not gated
    pub capacity_ns: f64,
    /// host-dependent wall throughput — informational ONLY
    pub wall_events_per_sec: f64,
}

impl RepMetrics {
    /// Distill one experiment run.
    pub fn from_result(cfg: &ExperimentConfig, r: &ExperimentResult) -> RepMetrics {
        let offered_eps = if r.capacity_ns > 0.0 {
            cfg.rate * 1e9 / r.capacity_ns
        } else {
            0.0
        };
        RepMetrics {
            seed: cfg.seed,
            p50_ms: r.latency.quantile(0.50) / 1e6,
            p95_ms: r.latency.quantile(0.95) / 1e6,
            p99_ms: r.latency.quantile(0.99) / 1e6,
            fn_percent: r.fn_percent,
            false_positives: r.false_positives as f64,
            throughput_at_slo_eps: offered_eps * (1.0 - r.latency.violation_rate()),
            dropped_pms_failure: r.dropped_pms_failure as f64,
            recovered_pms: r.recovered_pms as f64,
            replayed_events: r.replayed_events as f64,
            hangs_detected: r.hangs_detected as f64,
            capacity_ns: r.capacity_ns,
            wall_events_per_sec: r.wall_events_per_sec,
        }
    }
}

/// The primary (gated) metric names, in canonical ledger order.
pub const PRIMARY_METRICS: [&str; 3] = ["p95_ms", "fn_percent", "throughput_at_slo_eps"];

/// All ledger metric names, primary first (`wall_events_per_sec` is
/// informational — present in entries, never gated, never part of the
/// determinism contract).
pub const ALL_METRICS: [&str; 11] = [
    "p95_ms",
    "fn_percent",
    "throughput_at_slo_eps",
    "p50_ms",
    "p99_ms",
    "false_positives",
    "dropped_pms_failure",
    "recovered_pms",
    "replayed_events",
    "hangs_detected",
    "wall_events_per_sec",
];

/// One grid cell (strategy × dataset) with its repetitions.
#[derive(Debug, Clone)]
pub struct CellMetrics {
    /// dataset selector name ("bus" / "soccer" / "stock")
    pub dataset: String,
    /// query the dataset maps to ("q4" / "q3" / "q1")
    pub query: String,
    /// strategy name ("none" / "pspice" / ...)
    pub shedder: String,
    /// one entry per repetition seed
    pub reps: Vec<RepMetrics>,
}

impl CellMetrics {
    /// `"<shedder>/<dataset>"` — how gates and error messages name the
    /// cell.
    pub fn key(&self) -> String {
        format!("{}/{}", self.shedder, self.dataset)
    }

    /// Per-repetition samples of a named metric.
    pub fn samples(&self, metric: &str) -> Vec<f64> {
        self.reps
            .iter()
            .map(|r| match metric {
                "p50_ms" => r.p50_ms,
                "p95_ms" => r.p95_ms,
                "p99_ms" => r.p99_ms,
                "fn_percent" => r.fn_percent,
                "false_positives" => r.false_positives,
                "throughput_at_slo_eps" => r.throughput_at_slo_eps,
                "dropped_pms_failure" => r.dropped_pms_failure,
                "recovered_pms" => r.recovered_pms,
                "replayed_events" => r.replayed_events,
                "hangs_detected" => r.hangs_detected,
                "capacity_ns" => r.capacity_ns,
                "wall_events_per_sec" => r.wall_events_per_sec,
                other => panic!("unknown metric {other:?}"),
            })
            .collect()
    }

    /// Aggregate one named metric over the repetitions.
    pub fn ci(&self, metric: &str) -> Ci {
        Ci::from_samples(&self.samples(metric))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci_matches_hand_computation() {
        let ci = Ci::from_samples(&[2.0, 4.0, 6.0]);
        assert!((ci.mean - 4.0).abs() < 1e-12);
        assert!((ci.stddev - 2.0).abs() < 1e-12, "n-1 denominator");
        assert!((ci.ci95 - 1.96 * 2.0 / 3f64.sqrt()).abs() < 1e-12);
        assert_eq!(ci.n, 3);
        // degenerate cases
        let one = Ci::from_samples(&[5.0]);
        assert_eq!(one.stddev, 0.0);
        assert_eq!(one.ci95, 0.0);
        assert_eq!(Ci::from_samples(&[]).n, 0);
    }

    #[test]
    fn cell_aggregates_named_metrics() {
        let rep = |seed, p95, fnp| RepMetrics {
            seed,
            p50_ms: 0.1,
            p95_ms: p95,
            p99_ms: 0.9,
            fn_percent: fnp,
            false_positives: 0.0,
            throughput_at_slo_eps: 1000.0,
            dropped_pms_failure: 0.0,
            recovered_pms: 0.0,
            replayed_events: 0.0,
            hangs_detected: 0.0,
            capacity_ns: 2000.0,
            wall_events_per_sec: 1e6,
        };
        let cell = CellMetrics {
            dataset: "bus".into(),
            query: "q4".into(),
            shedder: "pspice".into(),
            reps: vec![rep(1, 0.4, 10.0), rep(2, 0.6, 20.0)],
        };
        assert_eq!(cell.key(), "pspice/bus");
        assert!((cell.ci("p95_ms").mean - 0.5).abs() < 1e-12);
        assert!((cell.ci("fn_percent").mean - 15.0).abs() < 1e-12);
        assert_eq!(cell.ci("p95_ms").n, 2);
        for m in ALL_METRICS {
            let _ = cell.ci(m); // every ledger metric must resolve
        }
    }
}
