//! A small Tesla-like text DSL for defining queries, so examples and
//! config files can ship patterns without recompiling.
//!
//! Grammar (whitespace-insensitive):
//!
//! ```text
//! query q4 weight 1.0 {
//!   window count 2000
//!   open every 500
//!   select skip-till-next
//!   any 5 of bus where delayed == 1 && stop == key(0) bind key(0) = stop
//!     distinct bus
//! }
//!
//! query q1 weight 2.0 {
//!   window count 5000
//!   open on quote where symbol in [0,1,2,3]
//!   seq (
//!     quote where symbol == 0 && rising == 1 ;
//!     quote where symbol == 1 && rising == 1
//!   )
//! }
//! ```
//!
//! `seq (...; any N of <step> distinct <attr>)` gives the Q3 shape.
//! Attribute names resolve against the stream's [`Schema`]; `key(i)`
//! refers to PM correlation keys.
//!
//! The parser is a hand-rolled recursive descent over a cursor — like
//! the rest of the offline stand-ins (`toml_lite`, `cli`), it avoids
//! pulling a parser-combinator crate into the vendored set.

use crate::events::Schema;

use super::ast::*;

/// Cursor over the query text.
struct Cursor<'a> {
    src: &'a str,
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(src: &'a str) -> Self {
        Cursor { src, pos: 0 }
    }

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    fn skip_ws(&mut self) {
        let rest = self.rest();
        let trimmed = rest.trim_start();
        self.pos += rest.len() - trimmed.len();
    }

    fn err(&self, what: &str) -> anyhow::Error {
        let around: String = self.rest().chars().take(24).collect();
        anyhow::anyhow!("expected {what} at ...{around:?}")
    }

    /// Eat a symbol token (no word-boundary requirement).
    fn eat_sym(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.rest().starts_with(tok) {
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn expect_sym(&mut self, tok: &str) -> crate::Result<()> {
        if self.eat_sym(tok) {
            Ok(())
        } else {
            Err(self.err(tok))
        }
    }

    /// Eat an alphabetic keyword (must end at a word boundary).
    fn eat_kw(&mut self, kw: &str) -> bool {
        self.skip_ws();
        let rest = self.rest();
        if !rest.starts_with(kw) {
            return false;
        }
        let boundary = match rest[kw.len()..].chars().next() {
            Some(c) => !(c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            None => true,
        };
        if boundary {
            self.pos += kw.len();
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> crate::Result<()> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.err(kw))
        }
    }

    /// `[A-Za-z_][A-Za-z0-9_-]*`
    fn ident(&mut self) -> crate::Result<&'a str> {
        self.skip_ws();
        let rest = self.rest();
        let mut chars = rest.char_indices();
        match chars.next() {
            Some((_, c)) if c.is_ascii_alphabetic() || c == '_' => {}
            _ => return Err(self.err("identifier")),
        }
        let mut end = rest.len();
        for (i, c) in chars {
            if !(c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                end = i;
                break;
            }
        }
        self.pos += end;
        Ok(&rest[..end])
    }

    /// A float literal: `[+-]? digits [. digits] [eE [+-] digits]`.
    fn number(&mut self) -> crate::Result<f64> {
        self.skip_ws();
        let rest = self.rest();
        let bytes = rest.as_bytes();
        let mut i = 0;
        if i < bytes.len() && (bytes[i] == b'+' || bytes[i] == b'-') {
            i += 1;
        }
        let int_start = i;
        while i < bytes.len() && bytes[i].is_ascii_digit() {
            i += 1;
        }
        if i < bytes.len() && bytes[i] == b'.' {
            i += 1;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
        }
        if i == int_start {
            return Err(self.err("number"));
        }
        if i < bytes.len() && (bytes[i] == b'e' || bytes[i] == b'E') {
            let mut j = i + 1;
            if j < bytes.len() && (bytes[j] == b'+' || bytes[j] == b'-') {
                j += 1;
            }
            let exp_start = j;
            while j < bytes.len() && bytes[j].is_ascii_digit() {
                j += 1;
            }
            if j > exp_start {
                i = j;
            }
        }
        let text = &rest[..i];
        let v = text
            .parse::<f64>()
            .map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
        self.pos += i;
        Ok(v)
    }
}

fn cmp_op(c: &mut Cursor) -> crate::Result<CmpOp> {
    for (tok, op) in [
        ("==", CmpOp::Eq),
        ("!=", CmpOp::Ne),
        ("<=", CmpOp::Le),
        (">=", CmpOp::Ge),
        ("<", CmpOp::Lt),
        (">", CmpOp::Gt),
    ] {
        if c.eat_sym(tok) {
            return Ok(op);
        }
    }
    Err(c.err("comparison operator"))
}

/// `key ( <i> )` — returns the key index if present.
fn key_ref(c: &mut Cursor) -> crate::Result<Option<usize>> {
    if !c.eat_kw("key") {
        return Ok(None);
    }
    c.expect_sym("(")?;
    let k = c.number()?;
    c.expect_sym(")")?;
    Ok(Some(k as usize))
}

/// one predicate: `attr op rhs` or `attr in [v, v, ...]`
fn predicate(c: &mut Cursor, schema: &Schema, etype: u16) -> crate::Result<Predicate> {
    let attr = c.ident()?;
    let slot = schema
        .attr_slot(etype, attr)
        .ok_or_else(|| anyhow::anyhow!("unknown attribute {attr:?} for this event type"))?;
    if c.eat_kw("in") {
        c.expect_sym("[")?;
        let mut values = vec![c.number()?];
        while c.eat_sym(",") {
            values.push(c.number()?);
        }
        c.expect_sym("]")?;
        return Ok(Predicate::AttrIn { slot, values });
    }
    let op = cmp_op(c)?;
    if let Some(key) = key_ref(c)? {
        Ok(Predicate::KeyCmp { slot, op, key })
    } else {
        let value = c.number()?;
        Ok(Predicate::AttrCmp { slot, op, value })
    }
}

/// a step: `etype [where p && p && ...] [bind key(i) = attr]`
fn step(c: &mut Cursor, schema: &Schema) -> crate::Result<StepSpec> {
    let tname = c.ident()?;
    let etype = schema
        .type_id(tname)
        .ok_or_else(|| anyhow::anyhow!("unknown event type {tname:?}"))?;
    let mut preds = Vec::new();
    if c.eat_kw("where") {
        preds.push(predicate(c, schema, etype)?);
        while c.eat_sym("&&") {
            preds.push(predicate(c, schema, etype)?);
        }
    }
    let bind_key = if c.eat_kw("bind") {
        let key = key_ref(c)?
            .ok_or_else(|| c.err("key(i) after bind"))?;
        c.expect_sym("=")?;
        let attr = c.ident()?;
        let slot = schema
            .attr_slot(etype, attr)
            .ok_or_else(|| anyhow::anyhow!("unknown bind attribute {attr:?}"))?;
        Some((key, slot))
    } else {
        None
    };
    Ok(StepSpec {
        etype,
        preds,
        bind_key,
    })
}

/// `any N of <step> distinct <attr>` (the `any` keyword is already consumed)
fn any_clause(c: &mut Cursor, schema: &Schema) -> crate::Result<(usize, StepSpec, usize)> {
    let n = c.number()?;
    c.expect_kw("of")?;
    let spec = step(c, schema)?;
    c.expect_kw("distinct")?;
    let attr = c.ident()?;
    let slot = schema
        .attr_slot(spec.etype, attr)
        .ok_or_else(|| anyhow::anyhow!("unknown distinct attribute {attr:?}"))?;
    Ok((n as usize, spec, slot))
}

fn pattern(c: &mut Cursor, schema: &Schema) -> crate::Result<Pattern> {
    // any-only pattern
    if c.eat_kw("any") {
        let (n, spec, distinct_slot) = any_clause(c, schema)?;
        return Ok(Pattern::Any {
            n,
            spec,
            distinct_slot,
        });
    }
    // seq ( step ; step ; ... [; any n of step distinct attr] )
    c.expect_kw("seq")?;
    c.expect_sym("(")?;
    let mut head = Vec::new();
    let mut any_tail = None;
    loop {
        if c.eat_kw("any") {
            any_tail = Some(any_clause(c, schema)?);
        } else {
            head.push(step(c, schema)?);
        }
        if !c.eat_sym(";") {
            break;
        }
    }
    c.expect_sym(")")?;
    Ok(match any_tail {
        Some((n, spec, distinct_slot)) => Pattern::SeqAny {
            head,
            n,
            spec,
            distinct_slot,
        },
        None => Pattern::Seq(head),
    })
}

fn window_spec(c: &mut Cursor) -> crate::Result<WindowSpec> {
    c.expect_kw("window")?;
    if c.eat_kw("count") {
        Ok(WindowSpec::Count(c.number()? as u64))
    } else if c.eat_kw("time_ms") {
        Ok(WindowSpec::TimeMs(c.number()? as u64))
    } else {
        Err(c.err("count or time_ms"))
    }
}

fn open_policy(c: &mut Cursor, schema: &Schema) -> crate::Result<OpenPolicy> {
    c.expect_kw("open")?;
    if c.eat_kw("every") {
        return Ok(OpenPolicy::EveryK(c.number()? as u64));
    }
    c.expect_kw("on")?;
    Ok(OpenPolicy::OnMatch(step(c, schema)?))
}

fn selection(c: &mut Cursor) -> crate::Result<Selection> {
    if c.eat_kw("skip-till-next") {
        Ok(Selection::SkipTillNext)
    } else if c.eat_kw("skip-till-any") {
        Ok(Selection::SkipTillAny)
    } else {
        Err(c.err("skip-till-next or skip-till-any"))
    }
}

fn query_body(c: &mut Cursor, schema: &Schema) -> crate::Result<Query> {
    c.expect_kw("query")?;
    let name = c.ident()?;
    let weight = if c.eat_kw("weight") { c.number()? } else { 1.0 };
    c.expect_sym("{")?;
    let window = window_spec(c)?;
    let open = open_policy(c, schema)?;
    let sel = if c.eat_kw("select") {
        selection(c)?
    } else {
        Selection::SkipTillNext
    };
    let pat = pattern(c, schema)?;
    c.expect_sym("}")?;
    Ok(Query {
        name: name.to_string(),
        weight,
        pattern: pat,
        window,
        open,
        selection: sel,
    })
}

/// Parse one `query <name> weight <w> { ... }` definition against a
/// schema.  Returns the resolved [`Query`].
pub fn parse_query(input: &str, schema: &Schema) -> crate::Result<Query> {
    let mut c = Cursor::new(input);
    let q = query_body(&mut c, schema)
        .map_err(|e| anyhow::anyhow!("query parse error: {e:#}"))?;
    anyhow::ensure!(
        c.rest().trim().is_empty(),
        "trailing input after query: {:?}",
        c.rest().trim()
    );
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::schema_for;

    #[test]
    fn parses_seq_query() {
        let schema = schema_for("q1");
        let q = parse_query(
            "query mini weight 2.0 {
               window count 100
               open on quote where symbol in [0, 1]
               select skip-till-next
               seq (
                 quote where symbol == 0 && rising == 1 ;
                 quote where symbol == 1 && rising == 1
               )
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.name, "mini");
        assert_eq!(q.weight, 2.0);
        assert_eq!(q.state_count(), 3);
        assert_eq!(q.window, WindowSpec::Count(100));
        assert!(matches!(q.open, OpenPolicy::OnMatch(_)));
    }

    #[test]
    fn parses_any_query_with_keys() {
        let schema = schema_for("q4");
        let q = parse_query(
            "query busq {
               window count 2000
               open every 500
               any 5 of bus where delayed == 1 && stop == key(0) bind key(0) = stop
                 distinct bus
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.weight, 1.0);
        match &q.pattern {
            Pattern::Any {
                n,
                spec,
                distinct_slot,
            } => {
                assert_eq!(*n, 5);
                assert_eq!(*distinct_slot, crate::datasets::bus::A_BUS);
                assert_eq!(spec.bind_key, Some((0, crate::datasets::bus::A_STOP)));
                assert!(spec
                    .preds
                    .iter()
                    .any(|p| matches!(p, Predicate::KeyCmp { .. })));
            }
            other => panic!("wrong pattern {other:?}"),
        }
    }

    #[test]
    fn parses_seq_any_query() {
        let schema = schema_for("q3");
        let q = parse_query(
            "query defend {
               window time_ms 1500
               open on poss where player in [9, 20] bind key(0) = team
               seq (
                 poss where player in [9, 20] bind key(0) = team ;
                 any 3 of pos where ball_dist < 3.0 && team != key(0) distinct player
               )
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.state_count(), 5);
        assert!(matches!(q.pattern, Pattern::SeqAny { .. }));
    }

    #[test]
    fn rejects_unknown_attribute() {
        let schema = schema_for("q1");
        let r = parse_query(
            "query bad { window count 10 open every 5 seq ( quote where nope == 1 ) }",
            &schema,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let schema = schema_for("q1");
        let r = parse_query(
            "query ok { window count 10 open every 5 seq ( quote ) } extra",
            &schema,
        );
        assert!(r.is_err());
    }

    #[test]
    fn selection_defaults_to_skip_till_next() {
        let schema = schema_for("q1");
        let q = parse_query(
            "query s { window count 10 open every 5 seq ( quote ) }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.selection, Selection::SkipTillNext);
    }
}
