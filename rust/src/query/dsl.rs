//! A small Tesla-like text DSL for defining queries, so examples and
//! config files can ship patterns without recompiling.
//!
//! Grammar (whitespace-insensitive):
//!
//! ```text
//! query q4 weight 1.0 {
//!   window count 2000
//!   open every 500
//!   select skip-till-next
//!   any 5 of bus where delayed == 1 && stop == key(0) bind key(0) = stop
//!     distinct bus
//! }
//!
//! query q1 weight 2.0 {
//!   window count 5000
//!   open on quote where symbol in [0,1,2,3]
//!   seq (
//!     quote where symbol == 0 && rising == 1 ;
//!     quote where symbol == 1 && rising == 1
//!   )
//! }
//! ```
//!
//! `seq (...; any N of <step> distinct <attr>)` gives the Q3 shape.
//! Attribute names resolve against the stream's [`Schema`]; `key(i)`
//! refers to PM correlation keys.

use nom::{
    branch::alt,
    bytes::complete::{tag, take_while1},
    character::complete::{char, multispace0},
    combinator::{map, opt, recognize, value},
    multi::{many0, separated_list1},
    number::complete::double,
    sequence::{delimited, pair, preceded, tuple},
    IResult,
};

use crate::events::Schema;

use super::ast::*;

fn ident(i: &str) -> IResult<&str, &str> {
    recognize(pair(
        take_while1(|c: char| c.is_ascii_alphabetic() || c == '_'),
        many0(take_while1(|c: char| {
            c.is_ascii_alphanumeric() || c == '_' || c == '-'
        })),
    ))(i)
}

fn ws<'a, F, O>(inner: F) -> impl FnMut(&'a str) -> IResult<&'a str, O>
where
    F: FnMut(&'a str) -> IResult<&'a str, O>,
{
    delimited(multispace0, inner, multispace0)
}

fn cmp_op(i: &str) -> IResult<&str, CmpOp> {
    alt((
        value(CmpOp::Eq, tag("==")),
        value(CmpOp::Ne, tag("!=")),
        value(CmpOp::Le, tag("<=")),
        value(CmpOp::Ge, tag(">=")),
        value(CmpOp::Lt, tag("<")),
        value(CmpOp::Gt, tag(">")),
    ))(i)
}

/// right-hand side of a comparison: number or `key(i)`
enum Rhs {
    Const(f64),
    Key(usize),
}

fn rhs(i: &str) -> IResult<&str, Rhs> {
    alt((
        map(
            preceded(tag("key"), delimited(char('('), ws(double), char(')'))),
            |k| Rhs::Key(k as usize),
        ),
        map(double, Rhs::Const),
    ))(i)
}

/// one predicate: `attr op rhs` or `attr in [v, v, ...]`
fn predicate<'a>(
    i: &'a str,
    schema: &Schema,
    etype: u16,
) -> IResult<&'a str, Predicate> {
    let (i, attr) = ws(ident)(i)?;
    let slot = match schema.attr_slot(etype, attr) {
        Some(s) => s,
        None => {
            return Err(nom::Err::Failure(nom::error::Error::new(
                i,
                nom::error::ErrorKind::Verify,
            )))
        }
    };
    if let (i2, Some(_)) = opt(ws(tag("in")))(i)? {
        let (i3, values) = delimited(
            ws(char('[')),
            separated_list1(ws(char(',')), double),
            ws(char(']')),
        )(i2)?;
        return Ok((i3, Predicate::AttrIn { slot, values }));
    }
    let (i, op) = ws(cmp_op)(i)?;
    let (i, r) = ws(|x| rhs(x))(i)?;
    Ok((
        i,
        match r {
            Rhs::Const(value) => Predicate::AttrCmp { slot, op, value },
            Rhs::Key(key) => Predicate::KeyCmp { slot, op, key },
        },
    ))
}

/// a step: `etype [where p && p && ...] [bind key(i) = attr]`
fn step<'a>(i: &'a str, schema: &Schema) -> IResult<&'a str, StepSpec> {
    let (i, tname) = ws(ident)(i)?;
    let etype = match schema.type_id(tname) {
        Some(t) => t,
        None => {
            return Err(nom::Err::Failure(nom::error::Error::new(
                i,
                nom::error::ErrorKind::Verify,
            )))
        }
    };
    let (i, preds) = opt(preceded(
        ws(tag("where")),
        separated_list1(ws(tag("&&")), |x| predicate(x, schema, etype)),
    ))(i)?;
    let (i, bind) = opt(preceded(
        ws(tag("bind")),
        tuple((
            preceded(tag("key"), delimited(char('('), ws(double), char(')'))),
            preceded(ws(char('=')), ws(ident)),
        )),
    ))(i)?;
    let bind_key = match bind {
        None => None,
        Some((k, attr)) => {
            let slot = schema.attr_slot(etype, attr).ok_or_else(|| {
                nom::Err::Failure(nom::error::Error::new(
                    i,
                    nom::error::ErrorKind::Verify,
                ))
            })?;
            Some((k as usize, slot))
        }
    };
    Ok((
        i,
        StepSpec {
            etype,
            preds: preds.unwrap_or_default(),
            bind_key,
        },
    ))
}

/// `any N of <step> distinct <attr>`
fn any_clause<'a>(
    i: &'a str,
    schema: &Schema,
) -> IResult<&'a str, (usize, StepSpec, usize)> {
    let (i, _) = ws(tag("any"))(i)?;
    let (i, n) = ws(double)(i)?;
    let (i, _) = ws(tag("of"))(i)?;
    let (i, spec) = step(i, schema)?;
    let (i, _) = ws(tag("distinct"))(i)?;
    let (i, attr) = ws(ident)(i)?;
    let slot = schema.attr_slot(spec.etype, attr).ok_or_else(|| {
        nom::Err::Failure(nom::error::Error::new(i, nom::error::ErrorKind::Verify))
    })?;
    Ok((i, (n as usize, spec, slot)))
}

fn pattern<'a>(i: &'a str, schema: &Schema) -> IResult<&'a str, Pattern> {
    // any-only pattern
    if let Ok((i2, (n, spec, slot))) = any_clause(i, schema) {
        return Ok((
            i2,
            Pattern::Any {
                n,
                spec,
                distinct_slot: slot,
            },
        ));
    }
    // seq ( step ; step ; ... [; any n of step distinct attr] )
    let (i, _) = ws(tag("seq"))(i)?;
    let (mut i, _) = ws(char('('))(i)?;
    let mut head = Vec::new();
    let mut any_tail = None;
    loop {
        if let Ok((i2, a)) = any_clause(i, schema) {
            any_tail = Some(a);
            i = i2;
        } else {
            let (i2, s) = step(i, schema)?;
            head.push(s);
            i = i2;
        }
        let (i2, sep) = opt(ws(char(';')))(i)?;
        i = i2;
        if sep.is_none() {
            break;
        }
    }
    let (i, _) = ws(char(')'))(i)?;
    let p = match any_tail {
        Some((n, spec, distinct_slot)) => Pattern::SeqAny {
            head,
            n,
            spec,
            distinct_slot,
        },
        None => Pattern::Seq(head),
    };
    Ok((i, p))
}

fn window_spec(i: &str) -> IResult<&str, WindowSpec> {
    let (i, _) = ws(tag("window"))(i)?;
    alt((
        map(preceded(ws(tag("count")), ws(double)), |n| {
            WindowSpec::Count(n as u64)
        }),
        map(preceded(ws(tag("time_ms")), ws(double)), |n| {
            WindowSpec::TimeMs(n as u64)
        }),
    ))(i)
}

fn open_policy<'a>(i: &'a str, schema: &Schema) -> IResult<&'a str, OpenPolicy> {
    let (i, _) = ws(tag("open"))(i)?;
    if let Ok((i2, k)) = preceded(ws(tag("every")), ws(double))(i) {
        return Ok((i2, OpenPolicy::EveryK(k as u64)));
    }
    let (i, _) = ws(tag("on"))(i)?;
    let (i, s) = step(i, schema)?;
    Ok((i, OpenPolicy::OnMatch(s)))
}

fn selection(i: &str) -> IResult<&str, Selection> {
    preceded(
        ws(tag("select")),
        alt((
            value(Selection::SkipTillNext, ws(tag("skip-till-next"))),
            value(Selection::SkipTillAny, ws(tag("skip-till-any"))),
        )),
    )(i)
}

/// Parse one `query <name> weight <w> { ... }` definition against a
/// schema.  Returns the resolved [`Query`].
pub fn parse_query(input: &str, schema: &Schema) -> crate::Result<Query> {
    fn parse<'a>(i: &'a str, schema: &Schema) -> IResult<&'a str, Query> {
        let i = i.trim();
        let (i, _) = ws(tag("query"))(i)?;
        let (i, name) = ws(ident)(i)?;
        let (i, weight) = opt(preceded(ws(tag("weight")), ws(double)))(i)?;
        let (i, _) = ws(char('{'))(i)?;
        let (i, window) = window_spec(i)?;
        let (i, open) = open_policy(i, schema)?;
        let (i, sel) = opt(|x| selection(x))(i)?;
        let (i, pat) = pattern(i, schema)?;
        let (i, _) = ws(char('}'))(i)?;
        Ok((
            i,
            Query {
                name: name.to_string(),
                weight: weight.unwrap_or(1.0),
                pattern: pat,
                window,
                open,
                selection: sel.unwrap_or(Selection::SkipTillNext),
            },
        ))
    }
    match parse(input, schema) {
        Ok((rest, q)) => {
            anyhow::ensure!(
                rest.trim().is_empty(),
                "trailing input after query: {rest:?}"
            );
            Ok(q)
        }
        Err(e) => anyhow::bail!("query parse error: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::schema_for;

    #[test]
    fn parses_seq_query() {
        let schema = schema_for("q1");
        let q = parse_query(
            "query mini weight 2.0 {
               window count 100
               open on quote where symbol in [0, 1]
               select skip-till-next
               seq (
                 quote where symbol == 0 && rising == 1 ;
                 quote where symbol == 1 && rising == 1
               )
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.name, "mini");
        assert_eq!(q.weight, 2.0);
        assert_eq!(q.state_count(), 3);
        assert_eq!(q.window, WindowSpec::Count(100));
        assert!(matches!(q.open, OpenPolicy::OnMatch(_)));
    }

    #[test]
    fn parses_any_query_with_keys() {
        let schema = schema_for("q4");
        let q = parse_query(
            "query busq {
               window count 2000
               open every 500
               any 5 of bus where delayed == 1 && stop == key(0) bind key(0) = stop
                 distinct bus
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.weight, 1.0);
        match &q.pattern {
            Pattern::Any {
                n,
                spec,
                distinct_slot,
            } => {
                assert_eq!(*n, 5);
                assert_eq!(*distinct_slot, crate::datasets::bus::A_BUS);
                assert_eq!(spec.bind_key, Some((0, crate::datasets::bus::A_STOP)));
                assert!(spec
                    .preds
                    .iter()
                    .any(|p| matches!(p, Predicate::KeyCmp { .. })));
            }
            other => panic!("wrong pattern {other:?}"),
        }
    }

    #[test]
    fn parses_seq_any_query() {
        let schema = schema_for("q3");
        let q = parse_query(
            "query defend {
               window time_ms 1500
               open on poss where player in [9, 20] bind key(0) = team
               seq (
                 poss where player in [9, 20] bind key(0) = team ;
                 any 3 of pos where ball_dist < 3.0 && team != key(0) distinct player
               )
             }",
            &schema,
        )
        .unwrap();
        assert_eq!(q.state_count(), 5);
        assert!(matches!(q.pattern, Pattern::SeqAny { .. }));
    }

    #[test]
    fn rejects_unknown_attribute() {
        let schema = schema_for("q1");
        let r = parse_query(
            "query bad { window count 10 open every 5 seq ( quote where nope == 1 ) }",
            &schema,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let schema = schema_for("q1");
        let r = parse_query(
            "query ok { window count 10 open every 5 seq ( quote ) } extra",
            &schema,
        );
        assert!(r.is_err());
    }
}
