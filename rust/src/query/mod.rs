//! Pattern queries: AST, a Tesla-like text DSL, and the paper's four
//! built-in queries Q1–Q4.

pub mod ast;
pub mod builtin;
pub mod dsl;

pub use ast::{
    CmpOp, OpenPolicy, Pattern, Predicate, Query, Selection, StepSpec, WindowSpec,
};
pub use builtin::{q1, q2, q3, q4, BuiltinQuery};
pub use dsl::parse_query;
