//! Query AST: what a CEP pattern over one event stream looks like after
//! name resolution (attribute names → slots, event-type names → ids).
//!
//! The operators cover the paper's evaluation set (§IV-A): *sequence*
//! (Q1), *sequence with repetition* (Q2), *sequence with any* (Q3) and
//! *any* (Q4), all under skip-till-next/any-match selection, over
//! count- and time-based sliding windows with logical open predicates.

use crate::events::EventType;

/// Comparison operator in a predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
}

impl CmpOp {
    /// Apply the comparison.
    #[inline]
    pub fn eval(self, a: f64, b: f64) -> bool {
        match self {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
        }
    }
}

/// A predicate over one event (and, optionally, the PM's captured keys).
#[derive(Debug, Clone, PartialEq)]
pub enum Predicate {
    /// `e.attrs[slot] op value`
    AttrCmp {
        /// attribute slot
        slot: usize,
        /// comparison
        op: CmpOp,
        /// constant
        value: f64,
    },
    /// `e.attrs[slot] ∈ values`
    AttrIn {
        /// attribute slot
        slot: usize,
        /// allowed values
        values: Vec<f64>,
    },
    /// `e.attrs[slot] op pm.keys[key]` — correlation with a captured key
    /// (e.g. Q4's "same stop as the first delayed bus", Q3's "other
    /// team than the striker").  Evaluates to **true** while the key is
    /// still unbound (the binding step itself defines it).
    KeyCmp {
        /// attribute slot on the incoming event
        slot: usize,
        /// comparison
        op: CmpOp,
        /// PM key index (see [`StepSpec::bind_key`])
        key: usize,
    },
}

/// One step of a pattern: the event type it consumes, its predicates, and
/// optional key capture.
#[derive(Debug, Clone, PartialEq)]
pub struct StepSpec {
    /// Event type this step consumes.
    pub etype: EventType,
    /// All predicates must hold.
    pub preds: Vec<Predicate>,
    /// If set, capture `e.attrs[slot]` into `pm.keys[key]` when this step
    /// matches: `(key, slot)`.
    pub bind_key: Option<(usize, usize)>,
}

impl StepSpec {
    /// Step with no predicates.
    pub fn any_of_type(etype: EventType) -> Self {
        StepSpec {
            etype,
            preds: Vec::new(),
            bind_key: None,
        }
    }
}

/// Pattern shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Pattern {
    /// `seq(s1; s2; …; sk)` — ordered steps (repetition allowed by
    /// repeating a spec, as in Q2).
    Seq(Vec<StepSpec>),
    /// `any(n, spec)` — n matches of `spec` with pairwise-distinct values
    /// of `distinct_slot` (e.g. n distinct buses), in any order.
    Any {
        /// how many distinct matches complete the pattern
        n: usize,
        /// the step all matches must satisfy
        spec: StepSpec,
        /// slot whose value must be pairwise distinct
        distinct_slot: usize,
    },
    /// `seq(head…; any(n, spec))` — Q3's shape: a head sequence followed
    /// by an any-group.
    SeqAny {
        /// ordered head steps
        head: Vec<StepSpec>,
        /// any-group size
        n: usize,
        /// any-group step
        spec: StepSpec,
        /// distinctness slot for the any-group
        distinct_slot: usize,
    },
}

impl Pattern {
    /// Number of Markov states m = (#steps to complete) + 1, including
    /// the initial state (paper: `|S_q|`, e.g. 4 for `seq(A;B;C)`).
    pub fn state_count(&self) -> usize {
        match self {
            Pattern::Seq(steps) => steps.len() + 1,
            Pattern::Any { n, .. } => n + 1,
            Pattern::SeqAny { head, n, .. } => head.len() + n + 1,
        }
    }
}

/// Window extent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowSpec {
    /// Count-based: the window spans `ws` events from its opening event.
    Count(u64),
    /// Time-based: the window spans `ws_ms` of source time.
    TimeMs(u64),
}

/// When new windows open.
#[derive(Debug, Clone, PartialEq)]
pub enum OpenPolicy {
    /// A new window opens on every event matching the predicate
    /// (Q1/Q2: each leading-symbol event; Q3: each striker possession).
    OnMatch(StepSpec),
    /// A new window opens every `k` events (Q4: slide = 500).
    EveryK(u64),
}

/// Event-selection strategy (paper §IV-A: skip-till-next/any-match).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Skip-till-next-match: non-matching events are skipped; the first
    /// matching event advances the PM (single state-machine instance).
    SkipTillNext,
    /// Skip-till-any-match: a matching event both advances a branch and
    /// leaves the original PM open (bounded branching; see
    /// [`crate::operator::CostModel`] for the branch cap).
    SkipTillAny,
}

/// A complete, name-resolved query.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    /// Display name (e.g. "q1").
    pub name: String,
    /// Importance weight `w_q` (paper §II-B).
    pub weight: f64,
    /// The pattern.
    pub pattern: Pattern,
    /// Window extent.
    pub window: WindowSpec,
    /// Window opening policy.
    pub open: OpenPolicy,
    /// Selection strategy.
    pub selection: Selection,
}

impl Query {
    /// Markov state count for this query (incl. initial state).
    pub fn state_count(&self) -> usize {
        self.pattern.state_count()
    }

    /// Event types this query can react to: every step's type plus the
    /// `OnMatch` open-predicate type.  An event outside this set can
    /// neither advance a PM nor open an `OnMatch` window (an `EveryK`
    /// policy opens on position, not type, and is handled separately by
    /// the operator's skim path), which is what makes type-routed
    /// dispatch exact.
    pub fn type_mask(&self) -> crate::events::TypeMask {
        let mut m = crate::events::TypeMask::EMPTY;
        match &self.pattern {
            Pattern::Seq(steps) => {
                for s in steps {
                    m.add(s.etype);
                }
            }
            Pattern::Any { spec, .. } => m.add(spec.etype),
            Pattern::SeqAny { head, spec, .. } => {
                for s in head {
                    m.add(s.etype);
                }
                m.add(spec.etype);
            }
        }
        if let OpenPolicy::OnMatch(spec) = &self.open {
            m.add(spec.etype);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmp_ops() {
        assert!(CmpOp::Eq.eval(1.0, 1.0));
        assert!(CmpOp::Ne.eval(1.0, 2.0));
        assert!(CmpOp::Lt.eval(1.0, 2.0));
        assert!(CmpOp::Le.eval(2.0, 2.0));
        assert!(CmpOp::Gt.eval(3.0, 2.0));
        assert!(CmpOp::Ge.eval(2.0, 2.0));
        assert!(!CmpOp::Lt.eval(2.0, 2.0));
    }

    #[test]
    fn state_counts_match_paper() {
        // paper's example: seq(A;B;C) has 4 states incl. initial
        let s = StepSpec::any_of_type(0);
        assert_eq!(Pattern::Seq(vec![s.clone(), s.clone(), s.clone()]).state_count(), 4);
        assert_eq!(
            Pattern::Any {
                n: 3,
                spec: s.clone(),
                distinct_slot: 0
            }
            .state_count(),
            4
        );
        assert_eq!(
            Pattern::SeqAny {
                head: vec![s.clone()],
                n: 2,
                spec: s,
                distinct_slot: 0
            }
            .state_count(),
            4
        );
    }
}
