//! The paper's four evaluation queries (§IV-A), parameterized exactly as
//! the evaluation sweeps them (window size for Q1/Q2, pattern size n for
//! Q3/Q4).
//!
//! Q1/Q2 come in rising *and* falling variants like the paper
//! ("rising **or** falling quotes"); the builders return both as a
//! two-query set for the multi-query operator, each with weight 1.

use crate::datasets::{bus, soccer, stock};
use crate::events::Schema;

use super::ast::*;

/// A named bundle of queries plus the schema they are resolved against.
#[derive(Debug, Clone)]
pub struct BuiltinQuery {
    /// "q1" .. "q4"
    pub name: &'static str,
    /// the member queries (Q1/Q2 have a rising and a falling variant)
    pub queries: Vec<Query>,
}

/// Number of leading symbols whose quotes open Q1/Q2 windows (paper: "4
/// important companies as leading stock companies").
pub const LEADERS: usize = 4;
/// Symbols used in the Q1/Q2 patterns ("10 certain stock symbols").
/// Mid-tail zipf ranks: each appears rarely enough per window that the
/// match probability sweeps the paper's 6%–89% range as `ws` grows
/// (see DESIGN.md §3 calibration note).
pub const PATTERN_RANKS: [usize; 10] = [30, 31, 32, 33, 34, 35, 36, 37, 38, 39];
/// Defend distance (m) for Q3.
pub const DEFEND_DIST: f64 = 3.0;

fn quote_step(symbol: usize, rising: bool) -> StepSpec {
    StepSpec {
        etype: 0,
        preds: vec![
            Predicate::AttrCmp {
                slot: stock::A_SYMBOL,
                op: CmpOp::Eq,
                value: symbol as f64,
            },
            Predicate::AttrCmp {
                slot: stock::A_RISING,
                op: CmpOp::Eq,
                value: if rising { 1.0 } else { 0.0 },
            },
        ],
        bind_key: None,
    }
}

fn leader_open_step() -> StepSpec {
    StepSpec {
        etype: 0,
        preds: vec![Predicate::AttrIn {
            slot: stock::A_SYMBOL,
            values: (0..LEADERS).map(|s| s as f64).collect(),
        }],
        bind_key: None,
    }
}

fn stock_seq_query(name: &str, order: &[usize], rising: bool, ws: u64) -> Query {
    Query {
        name: format!("{name}_{}", if rising { "rise" } else { "fall" }),
        weight: 1.0,
        pattern: Pattern::Seq(order.iter().map(|&s| quote_step(s, rising)).collect()),
        window: WindowSpec::Count(ws),
        open: OpenPolicy::OnMatch(leader_open_step()),
        selection: Selection::SkipTillNext,
    }
}

/// Q1 — *sequence*: `seq(RE_1; …; RE_10)` (and the falling twin) within
/// `ws` events; windows open on each leading-symbol quote.
pub fn q1(ws: u64) -> BuiltinQuery {
    let order: Vec<usize> = PATTERN_RANKS.to_vec();
    BuiltinQuery {
        name: "q1",
        queries: vec![
            stock_seq_query("q1", &order, true, ws),
            stock_seq_query("q1", &order, false, ws),
        ],
    }
}

/// Q2 — *sequence with repetition*:
/// `seq(RE1;RE1;RE2;RE3;RE2;RE4;RE2;RE5;RE6;RE7;RE2;RE8;RE9;RE10)`
/// (paper's exact repetition order) and the falling twin.
pub fn q2(ws: u64) -> BuiltinQuery {
    // the paper's repetition order over the same 10 symbols
    let r = PATTERN_RANKS;
    let order = [
        r[0], r[0], r[1], r[2], r[1], r[3], r[1], r[4], r[5], r[6], r[1], r[7],
        r[8], r[9],
    ];
    BuiltinQuery {
        name: "q2",
        queries: vec![
            stock_seq_query("q2", &order, true, ws),
            stock_seq_query("q2", &order, false, ws),
        ],
    }
}

/// Q3 — *sequence with any*: `seq(STR; any(n, DF_1…DF_n))` — a striker
/// possession followed by `n` distinct opposing players defending
/// (within [`DEFEND_DIST`] of the ball) inside a time window of
/// `ws_ms` milliseconds.
pub fn q3(n: usize, ws_ms: u64) -> BuiltinQuery {
    let strikers = [9.0, (soccer::TEAM_SIZE + 9) as f64];
    // head: the striker possession event itself; bind the striker's team
    // so the any-group can require the *other* team.
    let head = StepSpec {
        etype: 0, // "poss"
        preds: vec![Predicate::AttrIn {
            slot: soccer::A_PLAYER,
            values: strikers.to_vec(),
        }],
        bind_key: Some((0, soccer::A_TEAM)),
    };
    let defend = StepSpec {
        etype: 1, // "pos"
        preds: vec![
            Predicate::AttrCmp {
                slot: soccer::A_BALL_DIST,
                op: CmpOp::Lt,
                value: DEFEND_DIST,
            },
            Predicate::KeyCmp {
                slot: soccer::A_TEAM,
                op: CmpOp::Ne,
                key: 0,
            },
        ],
        bind_key: None,
    };
    BuiltinQuery {
        name: "q3",
        queries: vec![Query {
            name: format!("q3_n{n}"),
            weight: 1.0,
            pattern: Pattern::SeqAny {
                head: vec![head.clone()],
                n,
                spec: defend,
                distinct_slot: soccer::A_PLAYER,
            },
            window: WindowSpec::TimeMs(ws_ms),
            open: OpenPolicy::OnMatch(head),
            selection: Selection::SkipTillNext,
        }],
    }
}

/// Q4 — *any*: `any(n, B_1…B_n)` — `n` distinct buses delayed at the
/// *same stop* within a count window of `ws` events, sliding every
/// `slide` events (paper: 500).
pub fn q4(n: usize, ws: u64, slide: u64) -> BuiltinQuery {
    let delayed = StepSpec {
        etype: 0,
        preds: vec![
            Predicate::AttrCmp {
                slot: bus::A_DELAYED,
                op: CmpOp::Eq,
                value: 1.0,
            },
            // same stop as the PM's first delayed bus; trivially true
            // before key 0 is bound (first match binds it)
            Predicate::KeyCmp {
                slot: bus::A_STOP,
                op: CmpOp::Eq,
                key: 0,
            },
        ],
        bind_key: Some((0, bus::A_STOP)),
    };
    BuiltinQuery {
        name: "q4",
        queries: vec![Query {
            name: format!("q4_n{n}"),
            weight: 1.0,
            pattern: Pattern::Any {
                n,
                spec: delayed,
                distinct_slot: bus::A_BUS,
            },
            window: WindowSpec::Count(ws),
            open: OpenPolicy::EveryK(slide),
            selection: Selection::SkipTillNext,
        }],
    }
}

/// Schema a built-in query set is resolved against.
pub fn schema_for(name: &str) -> Schema {
    match name {
        "q1" | "q2" => {
            let mut s = Schema::new();
            s.add_type("quote", &["symbol", "price", "rising", "move"]);
            s
        }
        "q3" => {
            let mut s = Schema::new();
            s.add_type("poss", &["player", "team", "x", "y"]);
            s.add_type("pos", &["player", "team", "x", "y", "ball_dist"]);
            s
        }
        "q4" => {
            let mut s = Schema::new();
            s.add_type("bus", &["bus", "stop", "delayed", "delay_min"]);
            s
        }
        other => panic!("unknown builtin query {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q1_shape() {
        let b = q1(5000);
        assert_eq!(b.queries.len(), 2);
        // 10 steps + initial state = 11 Markov states
        assert_eq!(b.queries[0].state_count(), 11);
        assert_eq!(b.queries[0].window, WindowSpec::Count(5000));
    }

    #[test]
    fn q2_shape() {
        let b = q2(8000);
        assert_eq!(b.queries[0].state_count(), 15); // 14 steps + initial
        match &b.queries[0].pattern {
            Pattern::Seq(steps) => assert_eq!(steps.len(), 14),
            _ => panic!("q2 must be a sequence"),
        }
    }

    #[test]
    fn q3_shape() {
        let b = q3(4, 1500);
        assert_eq!(b.queries[0].state_count(), 6); // 1 head + 4 any + initial
        assert_eq!(b.queries[0].window, WindowSpec::TimeMs(1500));
    }

    #[test]
    fn q4_shape() {
        let b = q4(5, 500 * 4, 500);
        assert_eq!(b.queries[0].state_count(), 6);
        assert_eq!(b.queries[0].open, OpenPolicy::EveryK(500));
    }
}
