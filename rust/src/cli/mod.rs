//! Command-line interface for the `pspice` binary (hand-rolled; the
//! offline crate set has no `clap` — see DESIGN.md §3).
//!
//! ```text
//! pspice run --config <file.toml> [--shedder S] [--rate R]
//! pspice run --query q1 --window 5000 --shedder pspice --rate 1.4
//! pspice fig5 --query q1 [--scale 0.2]     # and fig6/fig7/fig8/fig9a/fig9b
//! pspice gen-data --dataset stock --events 100000 --out trace.csv
//! pspice calibrate --query q1              # capacity + regression report
//! ```

use std::collections::HashMap;

use crate::config::ExperimentConfig;
use crate::harness::figures::{self, FigureOpts};

/// Parsed `--key value` flags (+ positional subcommand).
pub struct Flags {
    /// subcommand
    pub cmd: String,
    values: HashMap<String, String>,
}

impl Flags {
    /// Parse raw args (after the binary name).  A flag followed by
    /// another `--flag` (or by nothing) is a boolean switch and reads
    /// as `"true"` — so `scoreboard --smoke` and `realtime --wall true`
    /// both work.
    pub fn parse(args: &[String]) -> crate::Result<Flags> {
        anyhow::ensure!(!args.is_empty(), "{}", usage());
        let cmd = args[0].clone();
        let mut values = HashMap::new();
        let mut i = 1;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {:?}", args[i]))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                values.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                values.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Flags { cmd, values })
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Parsed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }
}

/// CLI usage text.
pub fn usage() -> &'static str {
    "usage: pspice <command> [--flag value ...]\n\
     commands:\n\
       run        run one experiment (--config file | --query q1..q4) \n\
                  [--shedder none|pspice|pspice--|pm-bl|e-bl] [--rate 1.2]\n\
                  [--window N] [--pattern-n N] [--events N] [--warmup N]\n\
                  [--lb-ms F] [--seed N] [--shards N] [--batch N]\n\
                  [--model markov|freq]\n\
                  [--retrain-every N] [--drift-threshold F]\n\
                  [--faults kill:S@D,delay:S@D:MS,poison:S@D,hang:S@D,\n\
                  shedkill:S@D] (chaos, shards>1)\n\
                  [--checkpoint-every N] [--journal-cap N] (snapshot+replay\n\
                  recovery) [--deadline-ms F] (worker hang detection)\n\
       realtime   run against the ingest plane (same flags as run, plus)\n\
                  [--source trace|tail|socket|burst|flashcrowd|oscillate]\n\
                  [--overload predicted|measured] [--duration-ms F]\n\
                  [--ingest-capacity N] [--ingest-policy drop-oldest|block]\n\
                  [--wall true|false] [--path file.csv] [--addr host:port]\n\
                  [--codec lines|csv] [--out result.json]\n\
                  (SIGINT finishes the in-flight batch and still emits\n\
                  the result, with \"interrupted\": true)\n\
       fig5       --query q1|q2|q3|q4 [--scale F]   match-probability sweep\n\
       fig6       --query q1|q3 [--scale F]         event-rate sweep\n\
       fig7       [--scale F]                       latency-bound trace\n\
       fig8       [--scale F]                       pSPICE vs pSPICE--\n\
       fig9a      [--scale F]                       shedding overhead\n\
       fig9b      [--scale F]                       model build overhead\n\
       scoreboard run the gated evaluation grid and append the trend ledger\n\
                  [--smoke] [--config file.toml] [--ledger SCORECARD.jsonl]\n\
                  [--out-dir results/scorecard] [--bench-json f1.json,f2.json]\n\
                  [--bless]\n\
       calibrate  --query q1..q4                    capacity + regressions\n\
       gen-data   --dataset stock|soccer|bus --events N --out file.csv\n\
       query-dsl  --file query.dsl --query q1..q4   parse a DSL query"
}

fn cfg_from_flags(flags: &Flags) -> crate::Result<ExperimentConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(q) = flags.get("query") {
        cfg.query = q.to_string();
        // pick the dataset that matches the query family
        cfg.dataset = match q {
            "q1" | "q2" | "q1+q2" => crate::datasets::DatasetKind::Stock,
            "q3" => crate::datasets::DatasetKind::Soccer,
            "q4" => crate::datasets::DatasetKind::Bus,
            _ => cfg.dataset,
        };
        if q == "q3" {
            cfg.window = 1_500;
        }
        if q == "q4" {
            cfg.window = 2_000;
        }
    }
    cfg.window = flags.get_parse("window", cfg.window)?;
    cfg.pattern_n = flags.get_parse("pattern-n", cfg.pattern_n)?;
    cfg.slide = flags.get_parse("slide", cfg.slide)?;
    cfg.seed = flags.get_parse("seed", cfg.seed)?;
    cfg.events = flags.get_parse("events", cfg.events)?;
    cfg.warmup = flags.get_parse("warmup", cfg.warmup)?;
    cfg.rate = flags.get_parse("rate", cfg.rate)?;
    cfg.lb_ms = flags.get_parse("lb-ms", cfg.lb_ms)?;
    cfg.shards = flags.get_parse("shards", cfg.shards)?;
    cfg.batch = flags.get_parse("batch", cfg.batch)?;
    cfg.retrain_every = flags.get_parse("retrain-every", cfg.retrain_every)?;
    cfg.drift_threshold = flags.get_parse("drift-threshold", cfg.drift_threshold)?;
    anyhow::ensure!(cfg.shards >= 1, "--shards must be at least 1");
    anyhow::ensure!(cfg.batch >= 1, "--batch must be at least 1");
    if let Some(s) = flags.get("shedder") {
        cfg.shedder = s.parse()?;
    }
    if let Some(m) = flags.get("model") {
        cfg.model = m.parse()?;
    }
    // real-time plane
    if let Some(o) = flags.get("overload") {
        cfg.overload = o.parse()?;
    }
    if let Some(s) = flags.get("source") {
        cfg.source = s.parse()?;
    }
    if let Some(c) = flags.get("codec") {
        cfg.codec = c.parse()?;
    }
    cfg.ingest_capacity = flags.get_parse("ingest-capacity", cfg.ingest_capacity)?;
    if let Some(p) = flags.get("ingest-policy") {
        cfg.ingest_policy = p.parse()?;
    }
    cfg.duration_ms = flags.get_parse("duration-ms", cfg.duration_ms)?;
    anyhow::ensure!(cfg.ingest_capacity >= 1, "--ingest-capacity must be at least 1");
    if let Some(spec) = flags.get("faults") {
        // validate here so a typo dies before the warm-up phases run
        crate::runtime::FaultPlan::parse(spec)?;
        cfg.faults = spec.to_string();
    }
    cfg.checkpoint_every = flags.get_parse("checkpoint-every", cfg.checkpoint_every)?;
    cfg.journal_cap = flags.get_parse("journal-cap", cfg.journal_cap)?;
    cfg.worker_deadline_ms = flags.get_parse("deadline-ms", cfg.worker_deadline_ms)?;
    anyhow::ensure!(cfg.journal_cap >= 1, "--journal-cap must be at least 1");
    Ok(cfg)
}

fn scoreboard_opts(flags: &Flags) -> crate::Result<crate::scorecard::ScoreboardOpts> {
    let mut opts = crate::scorecard::ScoreboardOpts {
        smoke: flags.get_parse("smoke", false)?,
        bless: flags.get_parse("bless", false)?,
        ..Default::default()
    };
    opts.config_path = flags.get("config").map(std::path::PathBuf::from);
    if let Some(p) = flags.get("ledger") {
        opts.ledger_path = std::path::PathBuf::from(p);
    }
    if let Some(p) = flags.get("out-dir") {
        opts.out_dir = std::path::PathBuf::from(p);
    }
    if let Some(list) = flags.get("bench-json") {
        opts.bench_json = list
            .split(',')
            .filter(|p| !p.is_empty())
            .map(std::path::PathBuf::from)
            .collect();
    }
    Ok(opts)
}

fn figure_opts(flags: &Flags) -> crate::Result<FigureOpts> {
    Ok(FigureOpts {
        scale: flags.get_parse("scale", 1.0)?,
        out_dir: flags
            .get("out-dir")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("results")),
    })
}

/// Entry point used by `main`.
pub fn run(args: Vec<String>) -> crate::Result<()> {
    let flags = Flags::parse(&args)?;
    match flags.cmd.as_str() {
        "run" => {
            let cfg = cfg_from_flags(&flags)?;
            let r = crate::harness::run_experiment(&cfg)?;
            println!(
                "experiment: query={} shedder={} shards={}",
                r.query, r.shedder, r.shards
            );
            println!("  engine            : {}", r.engine);
            println!("  capacity          : {:.0} ns/event", r.capacity_ns);
            println!("  match probability : {:.1}%", r.match_probability * 100.0);
            println!("  ground truth CEs  : {}", r.truth_total);
            println!("  false negatives   : {:.2}%", r.fn_percent);
            println!("  false positives   : {}", r.false_positives);
            println!(
                "  dropped           : {} PMs, {} events",
                r.dropped_pms, r.dropped_events
            );
            if r.recoveries > 0 {
                println!(
                    "  failures          : {} shard respawns, {} PMs lost (counted as shed)",
                    r.recoveries, r.dropped_pms_failure
                );
            }
            if r.recovered_pms > 0 || r.hangs_detected > 0 {
                println!(
                    "  recovery          : {} PMs restored ({} events replayed), {} hangs detected",
                    r.recovered_pms, r.replayed_events, r.hangs_detected
                );
            }
            println!(
                "  latency           : mean={:.3}ms max={:.3}ms violations={:.2}%",
                r.latency.stats.mean() / 1e6,
                r.latency.stats.max() / 1e6,
                r.latency.violation_rate() * 100.0
            );
            println!("  shed overhead     : {:.3}%", r.shed_overhead * 100.0);
            println!("  model build       : {:.4}s ({} retrains)", r.model_build_secs, r.retrains);
            println!(
                "  wall throughput   : {:.0} events/s",
                r.wall_events_per_sec
            );
            Ok(())
        }
        "realtime" => {
            let cfg = cfg_from_flags(&flags)?;
            let wall: bool = flags.get_parse("wall", false)?;
            // tail/socket need a host attachment built here; everything
            // else the harness builds from the config
            let external: Option<Box<dyn crate::ingest::Source>> = match cfg.source {
                crate::ingest::SourceKind::Tail => {
                    let path = flags
                        .get("path")
                        .ok_or_else(|| anyhow::anyhow!("--source tail needs --path"))?;
                    Some(Box::new(crate::ingest::FileTailSource::from_start(
                        std::path::Path::new(path),
                    )?))
                }
                crate::ingest::SourceKind::Socket => {
                    let addr = flags
                        .get("addr")
                        .ok_or_else(|| anyhow::anyhow!("--source socket needs --addr"))?;
                    let src = crate::ingest::SocketSource::bind_with(addr, cfg.codec)?;
                    eprintln!("listening on {} ({})", src.local_addr()?, cfg.codec.name());
                    Some(Box::new(src))
                }
                _ => None,
            };
            // Ctrl-C finishes the in-flight batch and still emits the
            // result block + JSON below, exiting 0 (a second Ctrl-C
            // force-kills); see util::interrupt
            let stop = crate::util::interrupt::install();
            let r = crate::harness::run_realtime_experiment_with_stop(
                &cfg,
                external,
                wall,
                Some(stop),
            )?;
            println!(
                "realtime: query={} shedder={} source={} overload={} clock={}{}",
                r.query,
                r.shedder,
                r.source,
                r.overload,
                if r.wall { "wall" } else { "virtual" },
                if r.interrupted { " (interrupted)" } else { "" }
            );
            println!("  capacity          : {:.0} ns/event", r.capacity_ns);
            println!(
                "  events            : {} processed, {} queue-dropped",
                r.events_processed(),
                r.queue_dropped
            );
            println!("  complex events    : {}", r.completions);
            println!(
                "  latency           : mean={:.3}ms p95={:.3}ms max={:.3}ms (LB {:.3}ms)",
                r.latency.stats.mean() / 1e6,
                r.latency.p95_ns() / 1e6,
                r.latency.stats.max() / 1e6,
                r.lb_ms
            );
            println!(
                "  violations        : {:.2}%",
                r.latency.violation_rate() * 100.0
            );
            println!(
                "  shed              : {} PMs, {} events, {:.3}% overhead",
                r.dropped_pms,
                r.dropped_events,
                r.shed_overhead * 100.0
            );
            if r.recoveries > 0 {
                println!(
                    "  failures          : {} shard respawns, {} PMs lost (counted as shed)",
                    r.recoveries, r.dropped_pms_failure
                );
            }
            if r.recovered_pms > 0 || r.hangs_detected > 0 {
                println!(
                    "  recovery          : {} PMs restored ({} events replayed), {} hangs detected",
                    r.recovered_pms, r.replayed_events, r.hangs_detected
                );
            }
            println!(
                "  wall throughput   : {:.0} events/s over {:.2}s",
                r.wall_events_per_sec, r.real_elapsed_secs
            );
            if let Some(out) = flags.get("out") {
                r.write_json(std::path::Path::new(out))?;
                println!("  wrote {out}");
            }
            Ok(())
        }
        "fig5" => figures::fig5(
            flags.get("query").unwrap_or("q1"),
            &figure_opts(&flags)?,
        ),
        "fig6" => figures::fig6(
            flags.get("query").unwrap_or("q1"),
            &figure_opts(&flags)?,
        ),
        "fig7" => figures::fig7(&figure_opts(&flags)?),
        "fig8" => figures::fig8(&figure_opts(&flags)?),
        "fig9a" => figures::fig9a(&figure_opts(&flags)?),
        "fig9b" => figures::fig9b(&figure_opts(&flags)?),
        "scoreboard" => {
            let opts = scoreboard_opts(&flags)?;
            crate::scorecard::run_scoreboard(&opts)
        }
        "calibrate" => {
            let cfg = cfg_from_flags(&flags)?;
            let queries = crate::harness::experiment::build_queries(&cfg)?;
            let trace = crate::harness::experiment::build_trace(&cfg);
            let mut op = crate::operator::Operator::new(queries);
            let mut cost = 0.0;
            for e in &trace {
                cost += op.process_event(e).cost_ns;
            }
            println!(
                "query={} events={} capacity={:.0} ns/event peak_pms={} match_p={:.2}%",
                cfg.query,
                trace.len(),
                cost / trace.len() as f64,
                op.pm_count(),
                op.match_probability() * 100.0
            );
            Ok(())
        }
        "gen-data" => {
            let dataset: crate::datasets::DatasetKind =
                flags.get("dataset").unwrap_or("stock").parse()?;
            let events: usize = flags.get_parse("events", 100_000usize)?;
            let out = flags
                .get("out")
                .ok_or_else(|| anyhow::anyhow!("gen-data needs --out"))?;
            let seed: u64 = flags.get_parse("seed", 42u64)?;
            use crate::events::EventStream;
            let evs = match dataset {
                crate::datasets::DatasetKind::Stock => {
                    crate::datasets::StockGen::with_seed(seed).take_events(events)
                }
                crate::datasets::DatasetKind::Soccer => {
                    crate::datasets::SoccerGen::with_seed(seed).take_events(events)
                }
                crate::datasets::DatasetKind::Bus => {
                    crate::datasets::BusGen::with_seed(seed).take_events(events)
                }
            };
            crate::datasets::csv::write_csv(std::path::Path::new(out), &evs)?;
            println!("wrote {} events to {out}", evs.len());
            Ok(())
        }
        "query-dsl" => {
            let file = flags
                .get("file")
                .ok_or_else(|| anyhow::anyhow!("query-dsl needs --file"))?;
            let schema_of = flags.get("query").unwrap_or("q1");
            let schema = crate::query::builtin::schema_for(schema_of);
            let text = std::fs::read_to_string(file)?;
            let q = crate::query::parse_query(&text, &schema)?;
            println!("parsed query {:?}: {} states, window {:?}", q.name, q.state_count(), q.window);
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{}", usage()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let f = Flags::parse(&s(&["run", "--query", "q3", "--rate", "1.6"])).unwrap();
        assert_eq!(f.cmd, "run");
        assert_eq!(f.get("query"), Some("q3"));
        assert_eq!(f.get_parse("rate", 0.0).unwrap(), 1.6);
        assert_eq!(f.get_parse("missing", 7u64).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(Flags::parse(&s(&[])).is_err());
        assert!(Flags::parse(&s(&["run", "query", "q1"])).is_err());
    }

    #[test]
    fn valueless_flags_read_as_true() {
        // a trailing flag is a boolean switch
        let f = Flags::parse(&s(&["scoreboard", "--smoke"])).unwrap();
        assert_eq!(f.get("smoke"), Some("true"));
        assert!(f.get_parse("smoke", false).unwrap());
        // ... and so is one followed by another flag
        let f = Flags::parse(&s(&["scoreboard", "--smoke", "--ledger", "L.jsonl"])).unwrap();
        assert_eq!(f.get("smoke"), Some("true"));
        assert_eq!(f.get("ledger"), Some("L.jsonl"));
        // explicit values still win
        let f = Flags::parse(&s(&["realtime", "--wall", "false"])).unwrap();
        assert!(!f.get_parse("wall", true).unwrap());
    }

    #[test]
    fn scoreboard_flags_resolve_to_opts() {
        let f = Flags::parse(&s(&[
            "scoreboard",
            "--smoke",
            "--bench-json",
            "a.json,b.json",
            "--out-dir",
            "tmp/sc",
            "--bless",
        ]))
        .unwrap();
        let opts = scoreboard_opts(&f).unwrap();
        assert!(opts.smoke);
        assert!(opts.bless);
        assert_eq!(opts.out_dir, std::path::PathBuf::from("tmp/sc"));
        assert_eq!(
            opts.bench_json,
            vec![
                std::path::PathBuf::from("a.json"),
                std::path::PathBuf::from("b.json")
            ]
        );
        // defaults: repo-root ledger, no bench files, full scale
        let f = Flags::parse(&s(&["scoreboard"])).unwrap();
        let opts = scoreboard_opts(&f).unwrap();
        assert!(!opts.smoke);
        assert_eq!(opts.ledger_path, std::path::PathBuf::from("SCORECARD.jsonl"));
        assert!(opts.bench_json.is_empty());
    }

    #[test]
    fn cfg_from_flags_applies_query_defaults() {
        let f = Flags::parse(&s(&["run", "--query", "q3"])).unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.dataset, crate::datasets::DatasetKind::Soccer);
        assert_eq!(cfg.window, 1_500);
    }

    #[test]
    fn shards_and_batch_flags_parse() {
        let f = Flags::parse(&s(&["run", "--shards", "4", "--batch", "128"])).unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.batch, 128);
        // defaults stay single-threaded
        let f = Flags::parse(&s(&["run", "--query", "q1"])).unwrap();
        assert_eq!(cfg_from_flags(&f).unwrap().shards, 1);
        // zero is rejected
        let f = Flags::parse(&s(&["run", "--shards", "0"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
    }

    #[test]
    fn model_flag_parses() {
        let f = Flags::parse(&s(&["run", "--model", "freq"])).unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.model, crate::model::ModelKind::Freq);
        // default stays the Markov model
        let f = Flags::parse(&s(&["run", "--query", "q1"])).unwrap();
        assert_eq!(
            cfg_from_flags(&f).unwrap().model,
            crate::model::ModelKind::Markov
        );
        // unknown backends are rejected
        let f = Flags::parse(&s(&["run", "--model", "magic"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
    }

    #[test]
    fn retrain_flags_parse() {
        let f = Flags::parse(&s(&[
            "run",
            "--retrain-every",
            "5000",
            "--drift-threshold",
            "0.02",
        ]))
        .unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.retrain_every, 5_000);
        assert!((cfg.drift_threshold - 0.02).abs() < 1e-12);
    }

    #[test]
    fn realtime_flags_parse() {
        let f = Flags::parse(&s(&[
            "realtime",
            "--source",
            "burst",
            "--overload",
            "measured",
            "--ingest-capacity",
            "1024",
            "--ingest-policy",
            "block",
            "--duration-ms",
            "50",
            "--codec",
            "csv",
        ]))
        .unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.source, crate::ingest::SourceKind::Burst);
        assert_eq!(cfg.codec, crate::ingest::WireCodec::Csv);
        assert_eq!(cfg.overload, crate::shedding::OverloadKind::Measured);
        assert_eq!(cfg.ingest_capacity, 1024);
        assert_eq!(cfg.ingest_policy, crate::ingest::OverflowPolicy::Block);
        assert!((cfg.duration_ms - 50.0).abs() < 1e-12);
        // defaults are the batch-identical trace plane
        let f = Flags::parse(&s(&["realtime", "--query", "q4"])).unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.source, crate::ingest::SourceKind::Trace);
        assert_eq!(cfg.overload, crate::shedding::OverloadKind::Predicted);
        // bad selectors are rejected
        let f = Flags::parse(&s(&["realtime", "--source", "warp"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
        let f = Flags::parse(&s(&["realtime", "--ingest-capacity", "0"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
    }

    #[test]
    fn faults_flag_parses_and_validates() {
        let f = Flags::parse(&s(&[
            "run",
            "--shards",
            "2",
            "--faults",
            "kill:0@10,delay:1@5:2.5",
        ]))
        .unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.faults, "kill:0@10,delay:1@5:2.5");
        // default carries no plan
        let f = Flags::parse(&s(&["run", "--query", "q1"])).unwrap();
        assert_eq!(cfg_from_flags(&f).unwrap().faults, "");
        // a malformed spec dies at flag parsing, before any phase runs
        let f = Flags::parse(&s(&["run", "--faults", "explode:0@1"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
    }

    #[test]
    fn recovery_flags_parse() {
        let f = Flags::parse(&s(&[
            "run",
            "--shards",
            "4",
            "--checkpoint-every",
            "16",
            "--journal-cap",
            "20000",
            "--deadline-ms",
            "250",
        ]))
        .unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.checkpoint_every, 16);
        assert_eq!(cfg.journal_cap, 20_000);
        assert!((cfg.worker_deadline_ms - 250.0).abs() < 1e-12);
        // defaults: checkpointing off, no explicit deadline
        let f = Flags::parse(&s(&["run", "--query", "q1"])).unwrap();
        let cfg = cfg_from_flags(&f).unwrap();
        assert_eq!(cfg.checkpoint_every, 0);
        assert_eq!(cfg.worker_deadline_ms, 0.0);
        // a zero journal cap is rejected
        let f = Flags::parse(&s(&["run", "--journal-cap", "0"])).unwrap();
        assert!(cfg_from_flags(&f).is_err());
        // the new fault kinds go through the same eager validation
        let f = Flags::parse(&s(&["run", "--faults", "hang:0@3,shedkill:1@4"])).unwrap();
        assert_eq!(cfg_from_flags(&f).unwrap().faults, "hang:0@3,shedkill:1@4");
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(s(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_works() {
        run(s(&["help"])).unwrap();
    }
}
