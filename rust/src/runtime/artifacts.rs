//! Artifact manifest + shape padding.
//!
//! `python/compile/aot.py` emits a set of `(B, M, N)` shape variants and
//! a `manifest.txt`.  At runtime we pick the smallest variant that fits
//! the live query set and *pad* the problem into it:
//!
//! * **pattern padding** — unused batch slots get the identity chain
//!   (absorbing everywhere, zero reward): their outputs are ignored;
//! * **state padding** — an `m`-state chain embeds into `M ≥ m` states
//!   by keeping states `0..m-1` in place, moving the final state to
//!   index `M-1` (the artifact's absorbing slot, since the compiled
//!   graph fixes `c_0 = e_{M-1}`), and making the `m-1..M-1` filler
//!   states absorbing self-loops with zero reward.
//!
//! The embedding is exact: filler states are unreachable from live
//! states, and the permutation is undone on read-back.  The
//! `padding_soundness` integration test checks this against the rust
//! oracle for every variant.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::linalg::Mat;

/// One compiled shape variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Variant {
    /// batch capacity (patterns)
    pub batch: usize,
    /// state capacity
    pub m: usize,
    /// bin capacity
    pub nbins: usize,
    /// artifact file name (relative to the manifest)
    pub file: String,
}

impl Variant {
    /// Total output elements — the cost proxy used to pick the smallest
    /// fitting variant.
    pub fn size(&self) -> usize {
        2 * self.batch * self.m * self.nbins
    }
}

/// Parsed `manifest.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// directory holding the artifacts
    pub dir: PathBuf,
    /// available variants
    pub variants: Vec<Variant>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut variants = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() == 4, "manifest line {}: {line:?}", no + 1);
            variants.push(Variant {
                batch: parts[0].parse()?,
                m: parts[1].parse()?,
                nbins: parts[2].parse()?,
                file: parts[3].to_string(),
            });
        }
        anyhow::ensure!(!variants.is_empty(), "empty artifact manifest");
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    /// Default artifact directory: `$PSPICE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PSPICE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest variant fitting `batch` patterns × `m` states × `nbins`.
    pub fn select(&self, batch: usize, m: usize, nbins: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.batch >= batch && v.m >= m && v.nbins >= nbins)
            .min_by_key(|v| v.size())
    }
}

/// State-index embedding for an `m`-state chain inside `cap` states:
/// live non-final states keep their index, the final state moves to
/// `cap-1`.
#[inline]
pub fn pad_index(i: usize, m: usize, cap: usize) -> usize {
    if i == m - 1 {
        cap - 1
    } else {
        i
    }
}

/// Embed `(T, r)` (m states) into `cap`-state padded row-major f32
/// buffers laid out for the artifact.
pub fn pad_chain(t: &Mat, r: &[f64], cap: usize, t_out: &mut [f32], r_out: &mut [f32]) {
    let m = t.rows();
    assert!(cap >= m);
    assert_eq!(t_out.len(), cap * cap);
    assert_eq!(r_out.len(), cap);
    t_out.fill(0.0);
    r_out.fill(0.0);
    // filler + final states: absorbing self-loops
    for i in 0..cap {
        t_out[i * cap + i] = 1.0;
    }
    for i in 0..m {
        let pi = pad_index(i, m, cap);
        if i < m - 1 {
            t_out[pi * cap + pi] = 0.0; // live row fully rewritten below
        }
        for j in 0..m {
            let pj = pad_index(j, m, cap);
            if i < m - 1 {
                t_out[pi * cap + pj] = t[(i, j)] as f32;
            }
        }
        r_out[pi] = if i < m - 1 { r[i] as f32 } else { 0.0 };
    }
}

/// The identity chain used for unused batch slots.
pub fn identity_chain(cap: usize, t_out: &mut [f32], r_out: &mut [f32]) {
    t_out.fill(0.0);
    r_out.fill(0.0);
    for i in 0..cap {
        t_out[i * cap + i] = 1.0;
    }
}

/// Undo the state permutation when reading a padded row back: value of
/// original state `i` lives at padded index [`pad_index`]`(i)`.
pub fn unpad_row(padded: &[f32], m: usize, cap: usize) -> Vec<f64> {
    (0..m)
        .map(|i| padded[pad_index(i, m, cap)] as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::markov;

    #[test]
    fn select_picks_smallest_fitting() {
        let man = ArtifactManifest {
            dir: PathBuf::from("."),
            variants: vec![
                Variant {
                    batch: 2,
                    m: 8,
                    nbins: 128,
                    file: "a".into(),
                },
                Variant {
                    batch: 4,
                    m: 16,
                    nbins: 256,
                    file: "b".into(),
                },
                Variant {
                    batch: 8,
                    m: 32,
                    nbins: 512,
                    file: "c".into(),
                },
            ],
        };
        assert_eq!(man.select(1, 5, 100).unwrap().file, "a");
        assert_eq!(man.select(2, 11, 256).unwrap().file, "b");
        assert_eq!(man.select(2, 15, 300).unwrap().file, "c");
        assert!(man.select(9, 8, 10).is_none());
        assert!(man.select(1, 40, 10).is_none());
    }

    #[test]
    fn pad_chain_preserves_recurrence() {
        // 3-state chain embedded in 8 states must produce identical
        // completion/tau at the live indices
        let t = Mat::from_rows(3, 3, &[0.6, 0.4, 0.0, 0.0, 0.5, 0.5, 0.0, 0.0, 1.0]);
        let r = vec![1.0, 3.0, 0.0];
        let cap = 8;
        let mut tp = vec![0.0f32; cap * cap];
        let mut rp = vec![0.0f32; cap];
        pad_chain(&t, &r, cap, &mut tp, &mut rp);
        // run the rust oracle on the padded chain
        let tpad = Mat::from_rows(
            cap,
            cap,
            &tp.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        let rpad: Vec<f64> = rp.iter().map(|&x| x as f64).collect();
        assert!(tpad.is_row_stochastic(1e-6));
        let direct = markov::build_tables(&t, &r, 20);
        let padded = markov::build_tables(&tpad, &rpad, 20);
        for j in 0..20 {
            for i in 0..3 {
                let pi = pad_index(i, 3, cap);
                assert!(
                    (direct.completion[j][i] - padded.completion[j][pi]).abs() < 1e-6,
                    "c mismatch j={j} i={i}"
                );
                assert!(
                    (direct.remaining_time[j][i] - padded.remaining_time[j][pi]).abs()
                        < 1e-6,
                    "tau mismatch j={j} i={i}"
                );
            }
        }
    }

    #[test]
    fn unpad_row_round_trips() {
        let padded: Vec<f32> = (0..8).map(|x| x as f32).collect();
        // m=3 in cap=8: states 0,1 at 0,1; final at 7
        assert_eq!(unpad_row(&padded, 3, 8), vec![0.0, 1.0, 7.0]);
    }

    #[test]
    fn identity_chain_is_stochastic() {
        let mut t = vec![0.0f32; 16];
        let mut r = vec![1.0f32; 4];
        identity_chain(4, &mut t, &mut r);
        assert_eq!(r, vec![0.0; 4]);
        let m = Mat::from_rows(4, 4, &t.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!(m.is_row_stochastic(1e-9));
    }

    #[test]
    fn manifest_parses_real_format() {
        let dir = std::env::temp_dir().join("pspice_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "2 8 128 utility_B2_M8_N128.hlo.txt\n4 16 256 utility_B4_M16_N256.hlo.txt\n",
        )
        .unwrap();
        let man = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(man.variants.len(), 2);
        assert_eq!(man.variants[1].m, 16);
    }
}
