//! PJRT engine: loads the AOT HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes the utility computation with padded
//! inputs (see [`super::artifacts`] for the padding scheme).
//!
//! One compiled executable per shape variant, compiled lazily on first
//! use and cached for the lifetime of the engine — compilation never
//! happens on the per-build hot path after warm-up.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Context;

use crate::linalg::markov::MarkovTables;
use crate::linalg::Mat;

use super::artifacts::{identity_chain, pad_chain, unpad_row, ArtifactManifest, Variant};
use super::engine::{BatchTables, ModelEngine};

/// The PJRT-backed model engine.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    /// compiled executables keyed by artifact file name
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create the engine from an artifact directory (reads the manifest,
    /// creates the CPU client; compilation is lazy).
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine {
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    fn executable(&mut self, v: &Variant) -> crate::Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(&v.file) {
            let path = self.manifest.dir.join(&v.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", v.file))?;
            log::info!("compiled artifact {} (B={} M={} N={})", v.file, v.batch, v.m, v.nbins);
            self.compiled.insert(v.file.clone(), exe);
        }
        Ok(&self.compiled[&v.file])
    }

    /// Execute one batch against a specific variant.  `chains` length
    /// must be ≤ `v.batch` and every matrix must fit `v.m`.
    fn run_variant(
        &mut self,
        v: &Variant,
        chains: &[(Mat, Vec<f64>)],
        nbins: usize,
    ) -> crate::Result<BatchTables> {
        let (cap_b, cap_m, cap_n) = (v.batch, v.m, v.nbins);
        // pack padded inputs
        let mut t_buf = vec![0.0f32; cap_b * cap_m * cap_m];
        let mut r_buf = vec![0.0f32; cap_b * cap_m];
        for b in 0..cap_b {
            let t_slot = &mut t_buf[b * cap_m * cap_m..(b + 1) * cap_m * cap_m];
            let r_slot = &mut r_buf[b * cap_m..(b + 1) * cap_m];
            match chains.get(b) {
                Some((t, r)) => pad_chain(t, r, cap_m, t_slot, r_slot),
                None => identity_chain(cap_m, t_slot, r_slot),
            }
        }
        let t_lit = xla::Literal::vec1(&t_buf).reshape(&[
            cap_b as i64,
            cap_m as i64,
            cap_m as i64,
        ])?;
        let r_lit = xla::Literal::vec1(&r_buf).reshape(&[cap_b as i64, cap_m as i64])?;

        let v_file = v.clone();
        let exe = self.executable(&v_file)?;
        let result = exe.execute::<xla::Literal>(&[t_lit, r_lit])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (C, TAU), each (N, B, M)
        let (c_lit, tau_lit) = result.to_tuple2()?;
        let c: Vec<f32> = c_lit.to_vec()?;
        let tau: Vec<f32> = tau_lit.to_vec()?;
        anyhow::ensure!(
            c.len() == cap_n * cap_b * cap_m,
            "unexpected artifact output size {} != {}",
            c.len(),
            cap_n * cap_b * cap_m
        );

        // unpack per pattern, truncating bins to the requested count
        let mut out = Vec::with_capacity(chains.len());
        for (b, (t, _)) in chains.iter().enumerate() {
            let m = t.rows();
            let mut completion = Vec::with_capacity(nbins);
            let mut remaining_time = Vec::with_capacity(nbins);
            for j in 0..nbins {
                let base = j * cap_b * cap_m + b * cap_m;
                completion.push(unpad_row(&c[base..base + cap_m], m, cap_m));
                remaining_time.push(unpad_row(&tau[base..base + cap_m], m, cap_m));
            }
            out.push(MarkovTables {
                completion,
                remaining_time,
            });
        }
        Ok(out)
    }
}

impl ModelEngine for PjrtEngine {
    fn build_tables(
        &mut self,
        chains: &[(Mat, Vec<f64>)],
        nbins: usize,
    ) -> crate::Result<BatchTables> {
        anyhow::ensure!(!chains.is_empty(), "no chains to build");
        let max_m = chains.iter().map(|(t, _)| t.rows()).max().expect("nonempty");
        let variant = self
            .manifest
            .select(chains.len(), max_m, nbins)
            .with_context(|| {
                format!(
                    "no artifact variant fits B={} m={} nbins={nbins}",
                    chains.len(),
                    max_m
                )
            })?
            .clone();
        self.run_variant(&variant, chains, nbins)
    }

    fn name(&self) -> &'static str {
        "pjrt-aot"
    }
}

// NOTE: differential tests PJRT-vs-fallback live in
// `rust/tests/hlo_differential.rs` (they need built artifacts).
