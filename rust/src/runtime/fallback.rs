//! Pure-rust model engine: the exact recurrence the AOT artifact
//! computes, looped per pattern.  Keeps the system fully functional
//! without artifacts and provides the differential baseline for the
//! PJRT path.

use crate::linalg::markov;
use crate::linalg::Mat;

use super::engine::{BatchTables, ModelEngine};

/// The rust twin of `python/compile/model.py::build_tables`.
#[derive(Debug, Default, Clone, Copy)]
pub struct FallbackEngine;

impl ModelEngine for FallbackEngine {
    fn build_tables(
        &mut self,
        chains: &[(Mat, Vec<f64>)],
        nbins: usize,
    ) -> crate::Result<BatchTables> {
        Ok(chains
            .iter()
            .map(|(t, r)| markov::build_tables(t, r, nbins))
            .collect())
    }

    fn name(&self) -> &'static str {
        "rust-fallback"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_oracle_by_construction() {
        let t = Mat::from_rows(2, 2, &[0.9, 0.1, 0.0, 1.0]);
        let r = vec![2.0, 0.0];
        let mut e = FallbackEngine;
        let out = e.build_tables(&[(t.clone(), r.clone())], 8).unwrap();
        let direct = markov::build_tables(&t, &r, 8);
        assert_eq!(out[0].completion, direct.completion);
        assert_eq!(out[0].remaining_time, direct.remaining_time);
    }
}
