//! The sharded operator runtime: partitions the compiled query set
//! across N worker shards, dispatches event batches to every shard over
//! bounded channels, and merges completions deterministically so a
//! sharded run emits the *identical* complex-event set as the
//! single-threaded [`Operator`](crate::operator::Operator).
//!
//! ## Why sharding by query is exact
//!
//! The multi-query operator treats queries independently: each query
//! owns its windows, PMs, observations and cost accounting, and every
//! query sees every event.  Partitioning queries across shards therefore
//! changes *where* each query's state lives, never *what* it computes —
//! per-query state evolution is bit-identical to the unsharded run, and
//! completions only need a deterministic merge by
//! `(completed_seq, query, window_open_seq, key_bits)`.
//!
//! ## Shard-aware shedding (paper Alg. 2 across shards)
//!
//! The overload detector stays global: it sees the *total* `n_pm` and
//! the batch latency, and computes one global drop amount ρ.  Victim
//! selection preserves "drop the ρ globally lowest-utility PMs": every
//! shard returns its lowest-utility `(query, window, state)` **cell
//! summaries** covering ρ PMs (sorted by the sharding-invariant
//! [`crate::operator::cell_cmp`] order), the coordinator k-way merges
//! the cells, and each shard then drops exactly the per-cell takes
//! chosen from its list — worker-channel traffic is O(cells), not
//! O(n_pm).  A 1-shard and an N-shard run with the same drop decisions
//! select the same victims.
//!
//! ## The zero-allocation event plane (PR 4)
//!
//! Dispatch draws its buffers from pools instead of allocating: event
//! batches are recycled [`crate::events::EventBatch`]es (one `Arc`
//! clone per shard, no copy), shed masks are pooled word-packed
//! [`crate::events::DropMask`]s, completions ride in per-shard sinks
//! the workers fill and hand back, and per-shed-pass accounting lives
//! in the inline [`crate::operator::PerShard`] array.  Batches are
//! tagged with a [`TypeMask`] occupancy while they are filled, and
//! **type-routed dispatch** uses it twice: each worker's operator skims
//! events whose type its queries cannot consume (bulk-accounted
//! bookkeeping, see `Operator::set_type_routing`), and the coordinator
//! skips the send entirely for a shard whose queries are irrelevant to
//! the whole batch *and* whose state is provably inert (no open
//! windows, no PMs, no event due for a local `EveryK` slide) — in that
//! case the skipped shard's virtual cost is reproduced
//! coordinator-side with the exact same FP accumulation the worker
//! would have performed, so results stay bit-for-bit identical.
//!
//! ## Rate-digest sync (PR 6)
//!
//! The one piece of worker state that moves on *every* event —
//! relevant or not — is the stream-rate digest
//! ([`crate::operator::RateDigest`]: last position + events-per-ms
//! EWMA, which time-window `R_w` estimates and expected window sizes
//! read).  The coordinator folds every dispatched batch into a mirror
//! digest and marks skipped shards stale; before a stale shard's next
//! real batch (or an observation harvest) one `SyncRate` message
//! installs the mirror, which is bit-identical to the digest the
//! worker would have folded itself.  This is what extends the send
//! skip beyond the count-windowed `OnMatch` shards of PR 4 to
//! time-windowed and slide-opened (`EveryK`) queries without giving up
//! exactness.
//!
//! ## The versioned model plane (PR 5)
//!
//! Model state is an `Arc`-shared, epoch-numbered
//! [`crate::model::TableSet`]: [`ShardedOperator::install_table_set`]
//! broadcasts the snapshot to every worker (`UpdateTables`), each
//! worker slices out its local queries' tables and cost factors, and
//! [`ShardedOperator::worker_epochs`] audits that all shards read the
//! same epoch.  Training inputs flow the other way:
//! [`ShardedOperator::harvest_observations`] merges every worker's
//! per-query statistics into the global order (queries are
//! partitioned, so the merge is placement — per-query statistics are
//! bit-identical to a single-threaded run), which is what lets
//! drift-triggered retraining drive the sharded runtime exactly like
//! the single-threaded operator.
//!
//! ## Supervision and shed-native recovery (PR 8)
//!
//! A worker death — panic, protocol fault, or closed channel — never
//! takes the coordinator down.  Workers wrap request handling in
//! `catch_unwind` and report a structured [`ShardFailure`] as their
//! final message; every coordinator↔worker channel operation detects
//! failure (a `Failed` response or a send/recv `Err`) and marks the
//! shard dead instead of panicking.  Recovery happens at the next
//! `&mut` entry point (and at the end of every dispatch, so a shard
//! killed mid-batch is back before the next one): the dead worker is
//! respawned with a fresh operator over its queries, the current
//! [`TableSet`] epoch, observation/routing toggles and the mirrored
//! [`RateDigest`] are re-installed, and the incarnation's lost PMs are
//! accounted as an **involuntary 100%-shed round**
//! ([`ShardedOperator::drain_failures`] →
//! `ShedReport::dropped_pms_failure`).  That framing is the point:
//! recovery is bounded-latency — no replay, no redelivery — so a
//! failure costs quality of results, never availability or the
//! latency bound, exactly like a deliberate shed.  The deterministic
//! [`FaultPlan`] (kill/delay/poison schedules keyed on cumulative
//! per-shard dispatch counts, surviving respawn) makes the whole path
//! testable: same seed + same plan ⇒ same deaths, same accounting.
//!
//! ## Checkpointed recovery, hang detection and quarantine (PR 9)
//!
//! Three additions turn the lossy PR 8 story into a *shed-native
//! checkpoint/recovery plane* (all default-off; see [`RecoveryConfig`]
//! and the [`checkpoint`] module docs):
//!
//! * **Snapshot + journal replay.**  With `checkpoint_every > 0` the
//!   coordinator periodically captures per-shard [`ShardSnapshot`]s
//!   (recycled boxes over the request/response channel) and journals
//!   every state-mutating request since the last acked snapshot
//!   (pooled-`Arc` clones — pointers, not events).  A dead shard's
//!   respawn then *restores* snapshot + journal instead of starting
//!   empty: recovered PMs are booked as `recovered_pms` rather than
//!   `dropped_pms_failure`, completions the dead worker never delivered
//!   are re-emitted, and replay cost is charged to the virtual clock.
//!   A journal outgrowing `journal_cap` degrades that shard to the
//!   lossy PR 8 path until the next completed checkpoint.
//!
//! * **Deadline-bounded dispatch.**  With `worker_deadline_ms > 0`
//!   every worker response is awaited with `recv_timeout`; a miss is a
//!   detected *hang* ([`FaultKind::Hang`] injects one
//!   deterministically): the shard is marked dead, its stuck thread
//!   detached — never joined — and recovery proceeds exactly as for a
//!   crash.  This closes the liveness hole of a blocking `recv`: a
//!   wedged worker used to stall the coordinator forever.
//!
//! * **Quarantine.**  A shard that fails [`QUARANTINE_AFTER`]
//!   consecutive dispatches (counter reset by any clean batch response)
//!   stops respawn-looping: its queries are rerouted to a fault-free
//!   *inline* fallback operator on the coordinator thread, seeded via
//!   the same restore-or-lossy path, and served synchronously through
//!   the same request vocabulary.

pub mod checkpoint;
mod fault;
pub(crate) mod merge;
mod worker;

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::events::{BatchPool, DropMask, Event, EventBatch, MaskPool, TypeMask};
use crate::model::plane::{ModelHarvest, TableSet};
use crate::model::UtilityTable;
use crate::operator::{
    BatchResult, CellTake, ComplexEvent, CostModel, FailureDrain, OperatorState, PerShard,
    PmRef, QueryStats, RateDigest, ShedCell, ShedOutcome, MAX_SHARDS,
};
use crate::query::{OpenPolicy, Query};
use crate::util::Rng;

pub use checkpoint::{RecoveryConfig, ShardSnapshot};
pub use fault::{FaultKind, FaultPlan, FaultSpec};
pub use merge::sort_completions;
pub use worker::ShardFailure;

use checkpoint::{Journal, JournalEntry, RestoreOutcome};
use worker::{Request, Response, WorkerState};

/// Consecutive failed dispatches after which a shard is quarantined to
/// the inline fallback operator instead of respawn-looping.  The
/// counter resets on any clean batch response, so only a shard that
/// *keeps* dying (crash-looping worker, poisoned environment) trips it.
pub const QUARANTINE_AFTER: u32 = 3;

/// A quarantined shard's fallback lane: the same [`WorkerState`] the
/// thread worker runs, driven synchronously on the coordinator thread.
/// `send` handles the request inline and parks the response in
/// `pending`; `recv` pops it — so every existing protocol path works
/// unchanged.  The inline state carries *no* fault schedule, and its
/// requests run without `catch_unwind`: quarantine is the trusted
/// last-resort lane, so a genuine panic here surfaces loudly instead of
/// being absorbed.
struct InlineShard {
    state: WorkerState,
    pending: VecDeque<Response>,
}

/// How queries are assigned to shards.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// `assignments[s]` = global query indices owned by shard `s`
    pub assignments: Vec<Vec<usize>>,
}

impl ShardPlan {
    /// Round-robin assignment of `n_queries` queries over at most
    /// `n_shards` shards (never more shards than queries).
    pub fn round_robin(n_queries: usize, n_shards: usize) -> Self {
        let n = n_shards.max(1).min(n_queries.max(1));
        let mut assignments = vec![Vec::new(); n];
        for q in 0..n_queries {
            assignments[q % n].push(q);
        }
        ShardPlan { assignments }
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.assignments.len()
    }

    /// `(shard, local index)` of a global query index.
    pub fn locate(&self, query: usize) -> Option<(usize, usize)> {
        for (s, qs) in self.assignments.iter().enumerate() {
            if let Some(l) = qs.iter().position(|&g| g == query) {
                return Some((s, l));
            }
        }
        None
    }
}

/// The sharded operator façade.  Owns one worker thread per shard; all
/// methods are synchronous (requests are answered before they return),
/// which keeps results deterministic and the channel protocol trivially
/// deadlock-free.
pub struct ShardedOperator {
    plan: ShardPlan,
    txs: Vec<SyncSender<Request>>,
    rxs: Vec<Receiver<Response>>,
    handles: Vec<JoinHandle<()>>,
    n_queries: usize,
    /// live PMs per shard (updated after every batch / drop)
    pms: Vec<usize>,
    /// PMs ever created per shard
    created: Vec<u64>,
    /// complex events ever emitted per shard
    completed: Vec<u64>,
    /// open windows per shard (tracked from batch outcomes; feeds both
    /// E-BL's per-window drop cost and the coordinator skip predicate)
    wins_open: Vec<usize>,
    /// open windows across all shards (cached sum of `wins_open`)
    open_windows: usize,
    /// cost model used for coordinator-side shed-cost accounting and
    /// for reproducing a skipped shard's idle batch cost (the worker's
    /// own model must keep the same `base_event_ns`/`open_check_ns`
    /// constants — only `check_factor` is configurable, through the
    /// installed [`TableSet`]'s `check_factors`)
    pub cost: CostModel,
    /// epoch of the installed model snapshot (coordinator view; every
    /// worker adopts the same epoch from the `UpdateTables` broadcast)
    table_epoch: u64,
    /// recycled event-batch buffers (dispatch plane)
    pool: BatchPool,
    /// recycled shed-mask buffers
    masks: MaskPool,
    /// per-shard recycled completion sinks (ride along each Batch
    /// request, come back filled in the response)
    comp_bufs: Vec<Vec<ComplexEvent>>,
    /// per-shard recycled shed-candidate sinks (ride each `Candidates`
    /// request the same way — no O(cells) allocation per shed round)
    cand_bufs: Vec<Vec<ShedCell>>,
    /// recycled per-round candidate list-of-lists for the k-way merge
    cand_lists: Vec<Vec<ShedCell>>,
    /// per-shard recycled victim take lists: filled by the k-way merge,
    /// sent to the shard as owned `DropCells` payloads, re-stowed from
    /// the `CellsDropped` responses — no O(cells) victim-list
    /// allocation or clone per shed round
    take_bufs: Vec<Vec<CellTake>>,
    /// per-shard recycled PM-ref sinks (`pm_refs` takes `&self`, so the
    /// recycling goes through a `RefCell`; the coordinator is
    /// single-threaded, so the borrow is never contended)
    ref_sinks: RefCell<Vec<Vec<PmRef>>>,
    /// per-shard union of the local queries' type masks
    relevant: Vec<TypeMask>,
    /// per-shard distinct `EveryK` slide values of the local queries:
    /// slide-opened windows open on `seq % k == 0` regardless of event
    /// type, so a skip additionally requires that no batch event is
    /// due for any of these (empty for all-`OnMatch` shards)
    every_ks: Vec<Vec<u64>>,
    /// coordinator mirror of the stream-rate digest: folded with every
    /// dispatched batch (shed or not), so it always equals the digest
    /// a worker that saw every event would hold — the payload of the
    /// `SyncRate` resync for shards whose batches were skipped
    rate: RateDigest,
    /// per-shard "rate digest is stale": set when a batch send is
    /// skipped, cleared by `sync_rate` before the shard's next real
    /// batch or observation harvest (`Cell`: the harvest path is
    /// `&self`, like `ref_sinks`)
    stale: Vec<Cell<bool>>,
    /// persistent mirror of the merged observation harvest: workers
    /// ship only rows dirtied since their last harvest
    /// ([`crate::operator::StatsDelta`], verbatim cumulative values),
    /// which this mirror accumulates into the global query slots — so
    /// a drift check costs O(changed rows) channel traffic instead of
    /// cloning every per-query count matrix (`RefCell`: the harvest
    /// path is `&self`, like `ref_sinks`)
    obs_mirror: RefCell<ModelHarvest>,
    /// type-routed dispatch enabled (default on)
    routing: bool,
    /// pooled buffers enabled (default on; off = the PR 3 copy-per-
    /// dispatch behavior, kept as the benchmark comparison baseline)
    pooling: bool,
    /// (shard, batch) sends skipped by type routing (diagnostics)
    skipped: u64,
    /// the full query set (global order), retained because respawning
    /// a dead shard needs fresh operators over its queries
    queries: Vec<Query>,
    /// the run's deterministic fault schedule (`None` for ordinary
    /// runs — the injection hooks cost nothing when absent)
    fault_plan: Option<Arc<FaultPlan>>,
    /// per-shard "worker is dead": set wherever a channel op fails
    /// (`Cell` — failures also surface on `&self` paths like
    /// `pm_refs`); the respawn waits for the next `&mut` entry point
    dead: Vec<Cell<bool>>,
    /// the failure report behind each dead mark, consumed at respawn
    /// (`RefCell`: same `&self` detection paths)
    failed: RefCell<Vec<Option<ShardFailure>>>,
    /// cumulative `Batch` requests accepted per shard — the dispatch
    /// offset a respawned worker resumes its fault schedule from
    batches_sent: Vec<u64>,
    /// created-PM totals of dead incarnations, folded in at recovery
    /// so `match_probability` spans the whole run
    created_base: Vec<u64>,
    /// completion totals of dead incarnations (see `created_base`)
    completed_base: Vec<u64>,
    /// PMs lost to worker deaths since the last `drain_failures` —
    /// the involuntary 100%-shed rounds
    failure_dropped: u64,
    /// worker respawns since the last `drain_failures`
    recoveries: u64,
    /// current observation-capture toggle, re-installed on respawn
    obs_enabled: bool,
    /// last installed model snapshot, re-installed on respawn
    current_tables: Option<Arc<TableSet>>,
    /// checkpoint/recovery knobs (all default-off; see [`checkpoint`])
    recovery: RecoveryConfig,
    /// per-shard last acked snapshot (`None` until the first checkpoint
    /// acks, or after a journal-overflow degrade; a `None` snapshot
    /// with an armed journal means restore-from-genesis — the empty
    /// state every fresh worker starts in)
    snaps: Vec<Option<Box<ShardSnapshot>>>,
    /// per-shard spare snapshot box: checkpoint N+1 is exported into
    /// the box snapshot N−1 came back in, so steady-state checkpoints
    /// of a warm shard allocate nothing
    spares: Vec<Option<Box<ShardSnapshot>>>,
    /// per-shard journal of state-mutating requests since the last
    /// acked snapshot (`RefCell`: appends also happen on `&self` paths
    /// like `sync_rate`; the coordinator is single-threaded)
    journals: RefCell<Vec<Journal>>,
    /// per-shard "worker missed its response deadline": its thread may
    /// be parked for minutes, so it is detached — never joined — at
    /// respawn and drop
    hung: Vec<Cell<bool>>,
    /// hangs detected since the last `drain_failures` (`Cell`:
    /// detection happens in the `&self` receive path)
    hangs_detected: Cell<u64>,
    /// per-shard consecutive failed dispatches (reset by a clean batch
    /// response); at [`QUARANTINE_AFTER`] the shard is quarantined
    consec_failures: Vec<Cell<u32>>,
    /// quarantined shards' inline fallback lanes (`RefCell`: `send` and
    /// `recv` are `&self`)
    quarantine: RefCell<Vec<Option<Box<InlineShard>>>>,
    /// completions recovered from a dead shard's unacked journal
    /// entries, merged into the current/next dispatch's output
    pending_completions: Vec<ComplexEvent>,
    /// PMs restored by snapshot + replay since the last drain (the
    /// counter that replaces `failure_dropped` on the recovered path)
    recovered_pms: u64,
    /// events replayed from journals since the last drain
    replayed_events: u64,
    /// PMs dropped by replaying unacked shed directives since the last
    /// drain (booked exactly once, as voluntary shedding)
    replayed_drop_pms: u64,
    /// virtual replay cost since the last drain (charged to the clock
    /// by the pipeline)
    replay_cost_ns: f64,
    /// lifetime batch dispatches (the checkpoint cadence counter)
    total_dispatches: u64,
}

impl ShardedOperator {
    /// Spawn a sharded operator over `n_shards` worker threads (capped
    /// at the query count; at most [`MAX_SHARDS`] — per-shard
    /// bookkeeping is inline, so more is a loud error, not a silent
    /// clamp).
    pub fn new(queries: Vec<Query>, n_shards: usize) -> Self {
        Self::with_faults(queries, n_shards, FaultPlan::none())
    }

    /// Like [`ShardedOperator::new`], carrying a deterministic
    /// [`FaultPlan`]: each worker receives its slice of the schedule at
    /// spawn (and, on respawn, the dispatch offset its predecessors
    /// already consumed), so the same plan and stream reproduce the
    /// same deaths and the same recovery accounting.  An empty plan is
    /// exactly [`ShardedOperator::new`].
    pub fn with_faults(queries: Vec<Query>, n_shards: usize, faults: FaultPlan) -> Self {
        Self::with_recovery(queries, n_shards, faults, RecoveryConfig::default())
    }

    /// Like [`ShardedOperator::with_faults`], with the checkpoint/
    /// recovery plane configured: periodic snapshots + journal replay
    /// (`checkpoint_every`), bounded journals (`journal_cap`), and
    /// deadline-bounded dispatch with hang detection
    /// (`worker_deadline_ms`).  The default [`RecoveryConfig`] is
    /// exactly [`ShardedOperator::with_faults`].
    pub fn with_recovery(
        queries: Vec<Query>,
        n_shards: usize,
        faults: FaultPlan,
        recovery: RecoveryConfig,
    ) -> Self {
        assert!(!queries.is_empty(), "sharded operator needs queries");
        assert!(
            n_shards <= MAX_SHARDS,
            "n_shards={n_shards} exceeds MAX_SHARDS={MAX_SHARDS}"
        );
        let n_queries = queries.len();
        let plan = ShardPlan::round_robin(n_queries, n_shards);
        // routing metadata, derived from the query set before it is
        // partitioned out to the workers
        let relevant: Vec<TypeMask> = plan
            .assignments
            .iter()
            .map(|a| {
                a.iter()
                    .fold(TypeMask::EMPTY, |m, &g| m.union(queries[g].type_mask()))
            })
            .collect();
        let every_ks: Vec<Vec<u64>> = plan
            .assignments
            .iter()
            .map(|a| {
                let mut ks: Vec<u64> = a
                    .iter()
                    .filter_map(|&g| match &queries[g].open {
                        OpenPolicy::EveryK(k) => Some(*k),
                        OpenPolicy::OnMatch(_) => None,
                    })
                    .collect();
                ks.sort_unstable();
                ks.dedup();
                ks
            })
            .collect();
        if let Some(max) = faults.max_shard() {
            assert!(
                max < plan.n_shards(),
                "fault plan targets shard {max}, but the run has {} shards",
                plan.n_shards()
            );
        }
        let fault_plan = if faults.is_empty() {
            None
        } else {
            // injected kills are reported in-band; keep their panic
            // output off stderr (ordinary runs never install the hook)
            fault::install_quiet_panic_hook();
            Some(Arc::new(faults))
        };
        let mut txs = Vec::with_capacity(plan.n_shards());
        let mut rxs = Vec::with_capacity(plan.n_shards());
        let mut handles = Vec::with_capacity(plan.n_shards());
        for (s, assignment) in plan.assignments.iter().enumerate() {
            let (req_tx, resp_rx, handle) =
                Self::spawn_worker(&queries, assignment, fault_plan.as_deref(), s, 0);
            txs.push(req_tx);
            rxs.push(resp_rx);
            handles.push(handle);
        }
        let n = plan.n_shards();
        ShardedOperator {
            plan,
            txs,
            rxs,
            handles,
            n_queries,
            pms: vec![0; n],
            created: vec![0; n],
            completed: vec![0; n],
            wins_open: vec![0; n],
            open_windows: 0,
            cost: CostModel::with_queries(n_queries),
            table_epoch: 0,
            pool: BatchPool::new(),
            masks: MaskPool::new(),
            comp_bufs: vec![Vec::new(); n],
            cand_bufs: vec![Vec::new(); n],
            cand_lists: Vec::new(),
            take_bufs: vec![Vec::new(); n],
            ref_sinks: RefCell::new(vec![Vec::new(); n]),
            relevant,
            every_ks,
            rate: RateDigest::default(),
            stale: vec![Cell::new(false); n],
            obs_mirror: RefCell::new(ModelHarvest::default()),
            routing: true,
            pooling: true,
            skipped: 0,
            queries,
            fault_plan,
            dead: vec![Cell::new(false); n],
            failed: RefCell::new(vec![None; n]),
            batches_sent: vec![0; n],
            created_base: vec![0; n],
            completed_base: vec![0; n],
            failure_dropped: 0,
            recoveries: 0,
            obs_enabled: true,
            current_tables: None,
            snaps: (0..n).map(|_| None).collect(),
            spares: (0..n).map(|_| None).collect(),
            journals: RefCell::new(
                (0..n)
                    .map(|_| Journal {
                        // genesis journals are armed from the first
                        // dispatch: snapshot `None` + journal = replay
                        // from the empty state a fresh worker starts in
                        armed: recovery.checkpointing(),
                        ..Journal::default()
                    })
                    .collect(),
            ),
            hung: vec![Cell::new(false); n],
            hangs_detected: Cell::new(0),
            consec_failures: vec![Cell::new(0); n],
            quarantine: RefCell::new((0..n).map(|_| None).collect()),
            pending_completions: Vec::new(),
            recovered_pms: 0,
            replayed_events: 0,
            replayed_drop_pms: 0,
            replay_cost_ns: 0.0,
            total_dispatches: 0,
            recovery,
        }
    }

    /// Spawn one shard worker: fresh bounded channels in both
    /// directions (array-backed — channel traffic itself never
    /// allocates per message), a fresh operator over the shard's
    /// queries, and the shard's slice of the fault schedule resumed at
    /// `dispatch_offset`.  Thread spawn is an OS-resource call, not a
    /// channel operation — failing it is a loud error.
    fn spawn_worker(
        queries: &[Query],
        assignment: &[usize],
        fault_plan: Option<&FaultPlan>,
        s: usize,
        dispatch_offset: u64,
    ) -> (SyncSender<Request>, Receiver<Response>, JoinHandle<()>) {
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(4);
        let (resp_tx, resp_rx) = mpsc::sync_channel::<Response>(4);
        let local: Vec<Query> = assignment.iter().map(|&g| queries[g].clone()).collect();
        let l2g = assignment.to_vec();
        let faults = fault_plan.map_or_else(Vec::new, |p| p.for_shard(s));
        let handle = std::thread::Builder::new()
            .name(format!("pspice-shard-{s}"))
            .spawn(move || worker::run(s, req_rx, resp_tx, local, l2g, faults, dispatch_offset))
            // audit:allow(panic): OS thread-spawn failure is a resource
            // exhaustion at construction time, not a worker fault the
            // supervision loop could degrade into a ShardFailure
            .expect("spawn shard worker");
        (req_tx, resp_rx, handle)
    }

    /// Enable or disable type-routed dispatch (on by default): the
    /// coordinator-side send skip *and* the workers' per-query skim
    /// path.  Disabling restores the PR 3 every-shard-matches-everything
    /// behavior for equivalence tests and benchmark baselines.
    pub fn set_type_routing(&mut self, enabled: bool) {
        self.recover_dead();
        self.routing = enabled;
        self.broadcast_ack(|| Request::SetTypeRouting(enabled));
    }

    /// Enable or disable the pooled batch/mask buffers (on by default;
    /// off = one fresh allocation + full copy per dispatch, the PR 3
    /// behavior kept as the benchmark comparison baseline).
    pub fn set_pooling(&mut self, enabled: bool) {
        self.pooling = enabled;
    }

    /// (shard, batch) sends skipped by type-routed dispatch so far.
    pub fn skipped_dispatches(&self) -> u64 {
        self.skipped
    }

    /// Distinct batch buffers the dispatch pool has grown to (steady
    /// state: 1 — the synchronous protocol keeps one batch in flight).
    pub fn pooled_batches(&self) -> usize {
        self.pool.pooled()
    }

    /// Number of worker shards.
    pub fn n_shards(&self) -> usize {
        self.plan.n_shards()
    }

    /// Number of queries across all shards.
    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    /// The query→shard assignment.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Global live PM count (the paper's `n_pm`).
    pub fn pm_count(&self) -> usize {
        self.pms.iter().sum()
    }

    /// Live PM count per shard.
    pub fn pm_counts(&self) -> &[usize] {
        &self.pms
    }

    /// Global completed-over-created PM ratio (the paper's match
    /// probability).  Spans the whole run: totals of dead worker
    /// incarnations are folded into per-shard bases at recovery.
    pub fn match_probability(&self) -> f64 {
        let created: u64 = self.created.iter().sum::<u64>()
            + self.created_base.iter().sum::<u64>();
        if created == 0 {
            0.0
        } else {
            let completed: u64 = self.completed.iter().sum::<u64>()
                + self.completed_base.iter().sum::<u64>();
            completed as f64 / created as f64
        }
    }

    /// Mark a shard dead, recording why.  Detection happens wherever a
    /// channel operation fails — `&self` paths included — while the
    /// respawn waits for the next `&mut` entry point
    /// ([`Self::recover_dead`]).
    fn mark_dead(&self, shard: usize, failure: Option<ShardFailure>) {
        self.dead[shard].set(true);
        let mut failed = self.failed.borrow_mut();
        if failed[shard].is_none() {
            // one increment per death (the report is taken at respawn);
            // a clean batch response resets the streak
            self.consec_failures[shard].set(self.consec_failures[shard].get() + 1);
            failed[shard] = Some(failure.unwrap_or_else(|| ShardFailure {
                shard,
                dispatch: self.batches_sent[shard],
                reason: "channel closed".to_string(),
            }));
        }
    }

    fn protocol_violation(&self, shard: usize, expected: &str) -> Option<ShardFailure> {
        Some(ShardFailure {
            shard,
            dispatch: self.batches_sent[shard],
            reason: format!("protocol violation: expected {expected}"),
        })
    }

    /// Receive a shard's response, turning worker death — a
    /// [`Response::Failed`] report, a closed channel, or (with a
    /// configured deadline) a response timeout — into a dead mark
    /// instead of a coordinator panic or an unbounded wait.  `None`
    /// means the shard is (now) dead and contributed nothing.
    fn recv(&self, shard: usize) -> Option<Response> {
        self.recv_with(shard, self.recovery.deadline())
    }

    fn recv_with(&self, shard: usize, deadline: Option<Duration>) -> Option<Response> {
        if self.dead[shard].get() {
            return None;
        }
        if let Some(q) = self.quarantine.borrow_mut()[shard].as_mut() {
            // inline lane: the response was parked at send time
            return match q.pending.pop_front() {
                Some(Response::Failed(f)) => {
                    self.mark_dead(shard, Some(f));
                    None
                }
                Some(resp) => Some(resp),
                None => {
                    self.mark_dead(
                        shard,
                        self.protocol_violation(shard, "a parked inline response"),
                    );
                    None
                }
            };
        }
        // Err(true) = deadline missed (hang), Err(false) = disconnected
        let got = match deadline {
            Some(d) => self.rxs[shard]
                .recv_timeout(d)
                .map_err(|e| e == RecvTimeoutError::Timeout),
            None => self.rxs[shard].recv().map_err(|_| false),
        };
        match got {
            Ok(Response::Failed(f)) => {
                self.mark_dead(shard, Some(f));
                None
            }
            Ok(resp) => Some(resp),
            Err(timed_out) => {
                if timed_out {
                    // hang detected: the thread may be parked for
                    // minutes, so it is detached at recovery (never
                    // joined); its eventual send lands on a dropped
                    // receiver
                    self.hung[shard].set(true);
                    self.hangs_detected.set(self.hangs_detected.get() + 1);
                    self.mark_dead(
                        shard,
                        Some(ShardFailure {
                            shard,
                            dispatch: self.batches_sent[shard],
                            reason: format!(
                                "hang: no response within the {:.1} ms deadline",
                                self.recovery.worker_deadline_ms
                            ),
                        }),
                    );
                } else {
                    self.mark_dead(shard, None);
                }
                None
            }
        }
    }

    /// Send a request to a shard.  Returns whether the shard accepted
    /// it — `false` for a shard already marked dead or whose request
    /// channel turns out closed (which marks it).  Callers only await
    /// responses for accepted requests.  A quarantined shard handles
    /// the request inline, synchronously, and parks the response for
    /// the matching [`Self::recv`].
    fn send(&self, shard: usize, req: Request) -> bool {
        if self.dead[shard].get() {
            return false;
        }
        if let Some(q) = self.quarantine.borrow_mut()[shard].as_mut() {
            let resp = match q.state.handle(req) {
                Ok(resp) => resp,
                Err(reason) => Response::Failed(ShardFailure {
                    shard,
                    dispatch: self.batches_sent[shard],
                    reason,
                }),
            };
            q.pending.push_back(resp);
            return true;
        }
        match self.txs[shard].send(req) {
            Ok(()) => true,
            Err(_) => {
                self.mark_dead(shard, None);
                false
            }
        }
    }

    /// Is snapshot + journal recovery live for this shard right now?
    fn journal_armed(&self, shard: usize) -> bool {
        self.recovery.checkpointing() && self.journals.borrow()[shard].armed
    }

    /// Journal a state-mutating request that a shard just accepted.
    /// Only `Batch` entries grow the event count, so the overflow check
    /// lives at the dispatch site ([`Self::check_journal_overflow`]).
    fn journal_push(&self, shard: usize, entry: JournalEntry) {
        self.journals.borrow_mut()[shard].push(entry);
    }

    /// Degrade a shard to lossy recovery if its journal outgrew the
    /// event cap — checkpoints too sparse for the event rate.  Bounded
    /// memory beats unbounded replay; the next completed checkpoint
    /// re-arms the shard.
    fn check_journal_overflow(&mut self, shard: usize) {
        {
            let mut journals = self.journals.borrow_mut();
            let j = &mut journals[shard];
            if j.events <= self.recovery.journal_cap {
                return;
            }
            log::warn!(
                "shard {shard}: journal overflowed {} events (cap {}); \
                 degrading to lossy recovery until the next checkpoint",
                j.events,
                self.recovery.journal_cap
            );
            j.clear();
            j.armed = false;
        }
        if let Some(b) = self.snaps[shard].take() {
            self.spares[shard] = Some(b);
        }
    }

    /// Mark a journaled request acknowledged: its completions were
    /// merged and its drops booked, so a later replay must not re-emit
    /// them.
    fn journal_ack(&self, shard: usize) {
        let mut journals = self.journals.borrow_mut();
        let j = &mut journals[shard];
        j.acked = j.entries.len();
    }

    /// Broadcast a state-setting request to every live shard and drain
    /// the acks; shards that die mid-round are marked and skipped.
    fn broadcast_ack(&self, mk: impl Fn() -> Request) {
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            sent[s] = self.send(s, mk());
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                continue;
            }
            match self.recv(s) {
                Some(Response::Ack) | None => {}
                Some(_) => self.mark_dead(s, self.protocol_violation(s, "ack")),
            }
        }
    }

    /// Respawn every dead shard.  Lost PMs are accounted as an
    /// involuntary 100%-shed round (drained into
    /// `ShedReport::dropped_pms_failure` by the pipeline), the
    /// replacement worker resumes the shard's fault schedule at its
    /// cumulative dispatch offset, and the coordinator re-installs its
    /// view of the mutable worker state: routing and observation
    /// toggles, the current model snapshot, and the mirrored rate
    /// digest (the PR 6 `SyncRate` machinery).  Recovery is
    /// bounded-latency by construction — no replay, no redelivery: the
    /// replacement starts empty, exactly like a shard after a 100%
    /// shed, so a failure costs QoR, never availability.
    fn recover_dead(&mut self) {
        for s in 0..self.n_shards() {
            if self.dead[s].get() {
                self.respawn(s);
            }
        }
    }

    fn respawn(&mut self, s: usize) {
        if let Some(f) = self.failed.borrow_mut()[s].take() {
            log::warn!(
                "shard {s} died at dispatch {} ({}); recovering",
                f.dispatch,
                f.reason
            );
        }
        self.recoveries += 1;
        // a crash-looping shard (or a failed inline lane) goes to the
        // quarantine path instead of another thread respawn
        if self.quarantine.borrow()[s].is_some()
            || self.consec_failures[s].get() >= QUARANTINE_AFTER
        {
            self.quarantine_shard(s);
            return;
        }
        let (tx, rx, handle) = Self::spawn_worker(
            &self.queries,
            &self.plan.assignments[s],
            self.fault_plan.as_deref(),
            s,
            self.batches_sent[s],
        );
        // install the new endpoints *before* joining: dropping the old
        // ones unblocks a worker still parked on a channel op, so the
        // join cannot hang
        self.txs[s] = tx;
        self.rxs[s] = rx;
        let old = std::mem::replace(&mut self.handles[s], handle);
        if self.hung[s].get() {
            // a hung thread may be parked far past any deadline:
            // detach it — its eventual send lands on the receiver we
            // just dropped, and the thread exits on its own
            self.hung[s].set(false);
            drop(old);
        } else {
            let _ = old.join();
        }
        self.dead[s].set(false);
        self.reseed(s);
    }

    /// Re-install the coordinator's view of worker state on a fresh
    /// incarnation (thread or inline — `send` routes either way), then
    /// recover its matching state: checkpointed restore when armed,
    /// the PR 8 lossy path otherwise.  If the incarnation dies during
    /// these (repeated kills are batch-keyed and cannot re-fire, but a
    /// genuine panic could), it is marked dead again and picked up at
    /// the next recovery point.
    fn reseed(&mut self, s: usize) {
        let routing = self.routing;
        self.reinstall(s, Request::SetTypeRouting(routing), "routing ack");
        let obs = self.obs_enabled;
        self.reinstall(s, Request::SetObsEnabled(obs), "obs ack");
        if let Some(set) = self.current_tables.clone() {
            self.reinstall(s, Request::UpdateTables(set), "tables ack");
        }
        if self.try_restore(s) {
            // shed-native checkpointed recovery: PMs, windows, counters
            // and rate digest are back exactly.  No `SyncRate` and no
            // `stale` reset: the snapshot restores the digest as of the
            // checkpoint and the replayed journal (including journaled
            // syncs) reproduces the dead worker's digest, which lags
            // the mirror by exactly the batches that worker also never
            // saw — the existing staleness machinery resyncs those.
            return;
        }
        // PR 8 lossy path: the incarnation's PMs become failure-shed
        // and the replacement starts empty on the mirrored digest
        self.book_lossy(s);
        self.stale[s].set(false);
        let rate = self.rate;
        self.reinstall(s, Request::SyncRate(rate), "rate ack");
        if self.journal_armed(s) {
            // the synced digest is part of the replacement's genesis
            // baseline: journal it so a replay reproduces it
            self.journal_push(s, JournalEntry::SyncRate(rate));
            self.journal_ack(s);
        }
    }

    /// The PR 8 lossy bookkeeping: the dead incarnation's PMs become an
    /// involuntary 100%-shed round and its lifetime counters fold into
    /// the per-shard bases.  The replacement starts empty, so the
    /// recovery baseline also restarts: journal cleared and re-armed
    /// (genesis = the empty state), snapshot retired to the spare slot.
    fn book_lossy(&mut self, s: usize) {
        self.failure_dropped += self.pms[s] as u64;
        self.created_base[s] += self.created[s];
        self.completed_base[s] += self.completed[s];
        self.created[s] = 0;
        self.completed[s] = 0;
        self.pms[s] = 0;
        self.wins_open[s] = 0;
        self.open_windows = self.wins_open.iter().sum();
        if self.recovery.checkpointing() {
            {
                let mut journals = self.journals.borrow_mut();
                journals[s].clear();
                journals[s].armed = true;
            }
            if let Some(b) = self.snaps[s].take() {
                self.spares[s] = Some(b);
            }
        }
    }

    /// Attempt checkpointed recovery of a freshly reseeded shard: ship
    /// the last acked snapshot plus the journal, let the replacement
    /// replay, and adopt the restored mirrors.  Returns `false`
    /// (leaving the mirrors untouched) when the plane is off or
    /// degraded, or when the replacement itself fails mid-restore —
    /// the caller then books the death lossily.
    fn try_restore(&mut self, s: usize) -> bool {
        if !self.journal_armed(s) {
            return false;
        }
        let snap = self.snaps[s].take();
        let (journal, emit_from) = {
            let mut journals = self.journals.borrow_mut();
            let j = &mut journals[s];
            let emit_from = j.acked;
            j.events = 0;
            j.acked = 0;
            (std::mem::take(&mut j.entries), emit_from)
        };
        if !self.send(
            s,
            Request::Restore {
                snap,
                journal,
                emit_from,
            },
        ) {
            self.journals.borrow_mut()[s].armed = false;
            return false;
        }
        // replay is bulk work that may legitimately exceed the per-
        // response deadline: wait without one (the replacement is
        // fresh, and no faults fire during replay)
        match self.recv_with(s, None) {
            Some(Response::Restored {
                outcome,
                snap,
                journal,
            }) => {
                self.adopt_restore(s, outcome, snap, journal);
                true
            }
            None => {
                // died mid-restore and the payload died with it: disarm
                // so the next respawn books this death lossily instead
                // of "restoring" an empty journal
                self.journals.borrow_mut()[s].armed = false;
                false
            }
            Some(_) => {
                self.mark_dead(s, self.protocol_violation(s, "restore outcome"));
                self.journals.borrow_mut()[s].armed = false;
                false
            }
        }
    }

    /// Adopt a successful restore: reinstate snapshot + journal (now
    /// fully acked), replace the mirrors with the restored counters —
    /// *without* folding bases, because the replacement continues the
    /// dead incarnation's lifetime counters — and book the replay
    /// accounting (`recovered_pms` instead of `dropped_pms_failure`).
    fn adopt_restore(
        &mut self,
        s: usize,
        outcome: RestoreOutcome,
        snap: Option<Box<ShardSnapshot>>,
        journal: Vec<JournalEntry>,
    ) {
        self.snaps[s] = snap;
        {
            let mut journals = self.journals.borrow_mut();
            let j = &mut journals[s];
            j.entries = journal;
            j.acked = j.entries.len();
            j.events = j
                .entries
                .iter()
                .map(|e| match e {
                    JournalEntry::Batch { events, .. } => events.len(),
                    _ => 0,
                })
                .sum();
            j.armed = true;
        }
        self.recovered_pms += outcome.pms as u64;
        self.replayed_events += outcome.replayed_events;
        self.replayed_drop_pms += outcome.replayed_drop_pms;
        self.replay_cost_ns += outcome.replay_cost_ns;
        self.pms[s] = outcome.pms;
        self.created[s] = outcome.created;
        self.completed[s] = outcome.completed;
        self.wins_open[s] = outcome.wins_open;
        self.open_windows = self.wins_open.iter().sum();
        let mut completions = outcome.completions;
        self.pending_completions.append(&mut completions);
    }

    /// Reroute a crash-looping shard to the inline fallback lane: a
    /// fault-free [`WorkerState`] on the coordinator thread, reseeded
    /// by the same restore-or-lossy recovery as a thread respawn and
    /// served synchronously through `send`/`recv` from then on.  The
    /// retired worker thread keeps its slot in `handles` and is joined
    /// at drop (skipped if it hung).
    fn quarantine_shard(&mut self, s: usize) {
        log::warn!(
            "shard {s}: {} consecutive failures; rerouting to the inline fallback operator",
            self.consec_failures[s].get()
        );
        let local: Vec<Query> = self.plan.assignments[s]
            .iter()
            .map(|&g| self.queries[g].clone())
            .collect();
        // deliberately no fault schedule: the fallback lane must not
        // inherit the faults that crash-looped the thread worker
        let state = WorkerState::new(
            local,
            self.plan.assignments[s].clone(),
            Vec::new(),
            self.batches_sent[s],
        );
        self.quarantine.borrow_mut()[s] = Some(Box::new(InlineShard {
            state,
            pending: VecDeque::new(),
        }));
        self.dead[s].set(false);
        self.reseed(s);
    }

    /// One re-install step of a respawn: fire the request and absorb
    /// the ack, marking the shard dead again on any failure.
    fn reinstall(&self, s: usize, req: Request, what: &str) {
        if !self.send(s, req) {
            return;
        }
        match self.recv(s) {
            Some(Response::Ack) | None => {}
            Some(_) => self.mark_dead(s, self.protocol_violation(s, what)),
        }
    }

    /// Take the failure accounting accumulated since the last drain:
    /// PMs lost to worker deaths (the involuntary shed rounds) and
    /// respawns performed.  Recovers any still-dead shard first, so
    /// the numbers are complete as of this call.
    pub fn drain_failures(&mut self) -> FailureDrain {
        self.recover_dead();
        let out = FailureDrain {
            dropped_pms: self.failure_dropped,
            recoveries: self.recoveries,
            recovered_pms: self.recovered_pms,
            replayed_events: self.replayed_events,
            replayed_drop_pms: self.replayed_drop_pms,
            hangs_detected: self.hangs_detected.get(),
            replay_cost_ns: self.replay_cost_ns,
        };
        self.failure_dropped = 0;
        self.recoveries = 0;
        self.recovered_pms = 0;
        self.replayed_events = 0;
        self.replayed_drop_pms = 0;
        self.hangs_detected.set(0);
        self.replay_cost_ns = 0.0;
        out
    }

    /// Is some event of the batch due to open a slide window on shard
    /// `s` (a local `EveryK(k)` query opens on `seq % k == 0`,
    /// whatever the event's type)?  O(k-values) for the contiguous-seq
    /// batches the pipeline dispatches; a scan only for gapped seqs.
    fn due_open(&self, s: usize, events: &[Event]) -> bool {
        self.every_ks[s].iter().any(|&k| {
            let first = events[0].seq;
            let last = events[events.len() - 1].seq;
            if last >= first && last - first + 1 == events.len() as u64 {
                // contiguous: is some multiple of k inside [first, last]?
                last / k >= (first + k - 1) / k
            } else {
                events.iter().any(|e| e.seq % k == 0)
            }
        })
    }

    /// May dispatch of this batch to shard `s` be skipped outright?
    /// Only when the outcome is provably reproducible coordinator-side:
    /// nothing in the batch is relevant to the shard's queries (so no
    /// PM can advance and no `OnMatch` window can open), the shard is
    /// inert (no open windows, no PMs — expiry over zero windows is a
    /// no-op), and no event is due for a local `EveryK` slide.  The one
    /// piece of worker state that still moves — the stream-rate digest
    /// every operator folds per event — is reproduced on the
    /// coordinator's mirror and re-installed via `sync_rate` before the
    /// shard's next real batch, so the skip stays bit-exact even for
    /// time-windowed and slide-opened queries.
    fn can_skip(&self, s: usize, types: TypeMask, events: &[Event]) -> bool {
        self.routing
            && self.pms[s] == 0
            && self.wins_open[s] == 0
            && !types.intersects(self.relevant[s])
            && !self.due_open(s, events)
    }

    /// Bring a stale shard's rate digest current: one `SyncRate`
    /// message installing the coordinator mirror, which at this point
    /// equals the digest of a worker that processed every batch.
    fn sync_rate(&self, s: usize) {
        if !self.send(s, Request::SyncRate(self.rate)) {
            return; // dead: the respawn re-installs the digest itself
        }
        if self.journal_armed(s) {
            self.journal_push(s, JournalEntry::SyncRate(self.rate));
        }
        match self.recv(s) {
            Some(Response::Ack) => {
                self.stale[s].set(false);
                if self.journal_armed(s) {
                    self.journal_ack(s);
                }
            }
            None => {}
            Some(_) => self.mark_dead(s, self.protocol_violation(s, "sync ack")),
        }
    }

    /// The virtual cost a skipped shard would have accounted for a
    /// `len`-event irrelevant batch on empty state: per event, the base
    /// cost plus one open-check per local query.  Replicates the
    /// worker's floating-point accumulation sequence exactly, so a
    /// skipped dispatch is bit-identical to a sent one.
    fn idle_cost(&self, s: usize, len: usize) -> f64 {
        let mut per_event = self.cost.base_event_ns;
        for _ in 0..self.plan.assignments[s].len() {
            per_event += self.cost.open_check_ns;
        }
        let mut total = 0.0f64;
        for _ in 0..len {
            total += per_event;
        }
        total
    }

    // audit: no-alloc
    fn dispatch_into(
        &mut self,
        events: &[Event],
        mask: Option<&DropMask>,
        out: &mut BatchResult,
    ) {
        out.reset();
        if events.is_empty() {
            return;
        }
        // a shard that died since the last dispatch is back before
        // this one sees it
        self.recover_dead();
        let batch = if self.pooling {
            self.pool.lease_with(|b| b.refill(events))
        } else {
            // audit:allow(alloc): pooling-off baseline path — exists to
            // measure exactly this allocation against the pooled path
            Arc::new(EventBatch::copied(events))
        };
        let types = batch.types();
        let shed: Option<Arc<DropMask>> = mask.map(|m| {
            assert_eq!(m.len(), events.len(), "one mask bit per event");
            if self.pooling {
                self.masks.lease_with(|p| p.copy_from(m))
            } else {
                // audit:allow(alloc): pooling-off baseline path, same
                // rationale as the batch buffer above
                Arc::new(m.clone())
            }
        });
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            if self.can_skip(s, types, events) {
                self.skipped += 1;
                // the worker misses this batch's rate folds; resynced
                // from the mirror before its next real batch
                self.stale[s].set(true);
                continue;
            }
            if self.stale[s].get() {
                self.sync_rate(s);
            }
            let sink = std::mem::take(&mut self.comp_bufs[s]);
            sent[s] = self.send(
                s,
                Request::Batch {
                    events: Arc::clone(&batch),
                    shed: shed.clone(),
                    sink,
                },
            );
            if sent[s] {
                self.batches_sent[s] += 1;
                if self.journal_armed(s) {
                    // journaling clones the pooled Arcs (pointers, not
                    // events); the pool grows beyond its steady-state
                    // single buffer only while checkpointing is on
                    self.journal_push(
                        s,
                        JournalEntry::Batch {
                            events: Arc::clone(&batch),
                            shed: shed.clone(),
                        },
                    );
                    self.check_journal_overflow(s);
                }
            }
        }
        // fold the batch into the mirror *after* the send decisions: a
        // resync above must deliver the digest as of the previous
        // batch — the worker folds this one itself (shed events fold
        // too, exactly like `process_bookkeeping`)
        for e in events {
            self.rate.fold(e);
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                if self.dead[s].get() {
                    // a dead shard contributes nothing this batch; its
                    // lost PMs become failure-shed at the recovery
                    // below — availability and the bound are preserved,
                    // the batch just misses that shard's completions
                    continue;
                }
                // reproduce the skipped shard's idle outcome: no
                // completions, checks or window movement — just the
                // modeled per-event bookkeeping cost
                let cost = self.idle_cost(s, events.len());
                out.cost_ns_max = out.cost_ns_max.max(cost);
                out.cost_ns_total += cost;
                continue;
            }
            match self.recv(s) {
                Some(Response::Batch(mut b)) => {
                    self.consec_failures[s].set(0);
                    if self.journal_armed(s) {
                        self.journal_ack(s);
                    }
                    out.cost_ns_max = out.cost_ns_max.max(b.cost_ns);
                    out.cost_ns_total += b.cost_ns;
                    out.checks += b.checks;
                    out.opened += b.opened;
                    out.closed += b.closed;
                    self.pms[s] = b.n_pms;
                    self.created[s] = b.pms_created;
                    self.completed[s] = b.completions_total;
                    self.wins_open[s] =
                        (self.wins_open[s] + b.opened).saturating_sub(b.closed);
                    out.completions.extend_from_slice(&b.completions);
                    // reclaim the sink for the next dispatch
                    b.completions.clear();
                    self.comp_bufs[s] = b.completions;
                }
                // died mid-batch (Failed response or closed channel):
                // no contribution, recovered below
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "batch outcome"))
                }
            }
        }
        self.open_windows = self.wins_open.iter().sum();
        // bounded-latency recovery: a shard that died during this
        // batch is respawned before the call returns, so the pipeline
        // drains complete failure accounting right after the dispatch;
        // a checkpointed restore may surface completions the dead
        // worker never delivered — merged into this batch's output
        self.recover_dead();
        if !self.pending_completions.is_empty() {
            out.completions.append(&mut self.pending_completions);
        }
        merge::sort_completions(&mut out.completions);
        self.total_dispatches += 1;
        if self.recovery.checkpointing()
            && self.total_dispatches % self.recovery.checkpoint_every == 0
        {
            self.take_checkpoints();
        }
    }

    /// One checkpoint round: every live shard exports its state into a
    /// recycled snapshot box; on ack the shard's journal baseline moves
    /// (cleared + re-armed) and the previous snapshot becomes the next
    /// round's spare.  Capture charges nothing to the virtual clock: it
    /// models an asynchronous state mirror whose real cost the
    /// wall-clock plane observes by itself.
    fn take_checkpoints(&mut self) {
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            let sink = self.spares[s].take().unwrap_or_default();
            sent[s] = self.send(s, Request::Checkpoint { sink });
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                continue;
            }
            match self.recv(s) {
                Some(Response::Checkpoint(snap)) => {
                    if let Some(old) = self.snaps[s].replace(snap) {
                        self.spares[s] = Some(old);
                    }
                    let mut journals = self.journals.borrow_mut();
                    journals[s].clear();
                    journals[s].armed = true;
                }
                // died during capture (box lost with it): recovered at
                // the next entry point, snapshot state unchanged
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "checkpoint"))
                }
            }
        }
    }

    /// Open windows across all shards.
    pub fn open_windows(&self) -> usize {
        self.open_windows
    }

    /// Process a batch of events on every shard, merging completions
    /// deterministically.
    pub fn process_batch(&mut self, events: &[Event]) -> BatchResult {
        let mut out = BatchResult::default();
        self.dispatch_into(events, None, &mut out);
        out
    }

    /// Like [`Self::process_batch`], but events whose [`DropMask`] bit
    /// is set get window bookkeeping only (black-box event-shedding
    /// semantics: shed events still exist in the stream).  The mask is
    /// forwarded to the workers through the pooled mask plane — no
    /// allocation in steady state.
    pub fn process_batch_masked(
        &mut self,
        events: &[Event],
        dropped: &DropMask,
    ) -> BatchResult {
        assert_eq!(events.len(), dropped.len());
        let mut out = BatchResult::default();
        self.dispatch_into(events, Some(dropped), &mut out);
        out
    }

    /// Broadcast a model snapshot to every worker (one `Arc` clone per
    /// shard — `Request::UpdateTables`); each worker slices out its
    /// local queries' tables and cost factors and adopts the epoch.
    /// Empty `tables` clear the installed tables; empty
    /// `check_factors` leave the cost model untouched.
    pub fn install_table_set(&mut self, set: Arc<TableSet>) {
        assert!(
            set.tables.is_empty() || set.tables.len() == self.n_queries,
            "one table per query"
        );
        if !set.check_factors.is_empty() {
            assert_eq!(
                set.check_factors.len(),
                self.n_queries,
                "one factor per query"
            );
            self.cost.check_factor.clone_from(&set.check_factors);
        }
        self.recover_dead();
        self.table_epoch = set.epoch;
        self.current_tables = Some(Arc::clone(&set));
        self.broadcast_ack(|| Request::UpdateTables(Arc::clone(&set)));
    }

    /// Install bare utility tables (global query order), wrapped in an
    /// anonymous next-epoch [`TableSet`] that leaves cost factors
    /// untouched.  Test/bench convenience around
    /// [`ShardedOperator::install_table_set`].
    pub fn set_tables(&mut self, tables: &[UtilityTable]) {
        assert_eq!(tables.len(), self.n_queries, "one table per query");
        let set = TableSet {
            epoch: self.table_epoch + 1,
            tables: tables.to_vec(),
            check_factors: Vec::new(),
            ws: Vec::new(),
            key: None,
        };
        self.install_table_set(Arc::new(set));
    }

    /// Epoch of the model snapshot the workers are reading (coordinator
    /// view; audit the workers themselves via
    /// [`ShardedOperator::worker_epochs`]).
    pub fn table_epoch(&self) -> u64 {
        self.table_epoch
    }

    /// Ask every worker for the epoch it is actually reading (shard
    /// order) — the broadcast invariant says they all match
    /// [`ShardedOperator::table_epoch`] between dispatches.  A dead
    /// shard reports the coordinator's epoch: that is what its
    /// replacement adopts at recovery, so the invariant holds.
    pub fn worker_epochs(&self) -> Vec<u64> {
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            sent[s] = self.send(s, Request::Epoch);
        }
        (0..self.n_shards())
            .map(|s| {
                if !sent[s] {
                    return self.table_epoch;
                }
                match self.recv(s) {
                    Some(Response::Epoch(e)) => e,
                    None => self.table_epoch,
                    Some(_) => {
                        self.mark_dead(s, self.protocol_violation(s, "epoch"));
                        self.table_epoch
                    }
                }
            })
            .collect()
    }

    /// Merge every worker's observation statistics and expected window
    /// sizes into `into` (global query order).  Queries are partitioned
    /// across shards, so each worker's local statistics land in their
    /// global slots verbatim — per-query statistics are bit-identical
    /// to a single-threaded run over the same stream.
    ///
    /// Workers ship **delta rows** (only statistics rows dirtied since
    /// their last harvest, as verbatim cumulative values — see
    /// [`crate::operator::QueryStats::take_delta`]) which are applied to
    /// a persistent coordinator-side mirror, so a quiet drift check
    /// costs O(changed rows) channel traffic instead of a full matrix
    /// clone per query.  The mirror is then copied into the caller's
    /// buffer allocation-free via `assign_from`.
    pub fn harvest_observations(&self, into: &mut ModelHarvest) {
        // expected window sizes read the stream-rate digest, so shards
        // whose batches were skipped must be brought current first
        for s in 0..self.n_shards() {
            if self.stale[s].get() {
                self.sync_rate(s);
            }
        }
        let mut mirror = self.obs_mirror.borrow_mut();
        if mirror.hub.queries.len() != self.n_queries {
            // first harvest: placeholder stats, resized by the all-dirty
            // first delta from each worker
            mirror.hub.queries.clear();
            mirror
                .hub
                .queries
                .resize_with(self.n_queries, || QueryStats::new(0));
            mirror.ws.clear();
            mirror.ws.resize(self.n_queries, 0);
        }
        mirror.hub.enabled = true;
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            sent[s] = self.send(s, Request::Observations);
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                // dead shard: its queries keep their last-harvested
                // rows in the mirror (the replacement restarts
                // observation counts from zero — a training-data cost
                // of the failure model, not a correctness one)
                continue;
            }
            match self.recv(s) {
                Some(Response::Observations { stats, ws }) => {
                    for ((delta, w), &g) in stats
                        .iter()
                        .zip(ws)
                        .zip(&self.plan.assignments[s])
                    {
                        mirror.hub.queries[g].apply_delta(delta);
                        mirror.ws[g] = w;
                    }
                }
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "observations"))
                }
            }
        }
        into.hub.assign_from(&mirror.hub);
        into.ws.clone_from(&mirror.ws);
    }

    /// Toggle observation capture on every shard.
    pub fn set_obs_enabled(&mut self, enabled: bool) {
        self.recover_dead();
        self.obs_enabled = enabled;
        self.broadcast_ack(|| Request::SetObsEnabled(enabled));
    }

    /// Drop the ρ globally lowest-utility PMs (paper Alg. 2, shard
    /// aware): per-shard cell-summary lists are k-way merged so exactly
    /// the globally lowest ρ are dropped, with the deterministic
    /// tie-break documented on [`crate::operator::cell_cmp`].
    pub fn shed_lowest(&mut self, rho: usize) -> ShedOutcome {
        self.recover_dead();
        let scanned = self.pm_count();
        // per-shard (cells scanned, PMs dropped): the cell counts come
        // back with the candidate responses (the O(cells) decision
        // scan), the drop counts with the `CellsDropped` acks
        let mut per_shard = PerShard::default();
        for _ in &self.pms {
            per_shard.push(0, 0);
        }
        let mut out = ShedOutcome {
            scanned,
            dropped: 0,
            per_shard,
        };
        if rho == 0 || scanned == 0 {
            return out;
        }
        // candidate lists ride recycled sinks, like completions: the
        // worker fills the sink in place and the coordinator reclaims
        // it after the merge — no O(cells) allocation per shed round
        let mut asked = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            let sink = std::mem::take(&mut self.cand_bufs[s]);
            asked[s] = self.send(s, Request::Candidates { rho, sink });
        }
        let mut lists = std::mem::take(&mut self.cand_lists);
        lists.clear();
        for s in 0..self.n_shards() {
            if !asked[s] {
                lists.push(Vec::new());
                continue;
            }
            match self.recv(s) {
                Some(Response::Candidates { cells, scanned }) => {
                    out.per_shard[s].0 = scanned;
                    lists.push(cells);
                }
                None => lists.push(Vec::new()),
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "candidates"));
                    lists.push(Vec::new());
                }
            }
        }
        let mut victims = std::mem::take(&mut self.take_bufs);
        merge::k_way_take(&lists, rho, &mut victims);
        for (s, mut c) in lists.drain(..).enumerate() {
            c.clear();
            self.cand_bufs[s] = c;
        }
        self.cand_lists = lists;
        // victim lists travel as owned payloads and come back (cleared)
        // in the responses — the buffers are recycled, never cloned
        let mut expected = [0usize; MAX_SHARDS];
        let mut sent = [false; MAX_SHARDS];
        for (s, takes) in victims.iter_mut().enumerate() {
            if takes.is_empty() {
                continue;
            }
            expected[s] = takes.iter().map(|t| t.take as usize).sum();
            let payload = std::mem::take(takes);
            let journaled = self.journal_armed(s).then(|| payload.clone());
            sent[s] = self.send(s, Request::DropCells(payload));
            if sent[s] {
                if let Some(j) = journaled {
                    self.journal_push(s, JournalEntry::DropCells(j));
                }
            }
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                continue;
            }
            match self.recv(s) {
                Some(Response::CellsDropped { n, takes }) => {
                    if self.journal_armed(s) {
                        self.journal_ack(s);
                    }
                    debug_assert_eq!(n, expected[s], "victim cells must be live");
                    self.pms[s] -= n;
                    out.per_shard[s].1 = n;
                    out.dropped += n;
                    debug_assert!(takes.is_empty(), "worker returns a cleared buffer");
                    victims[s] = takes;
                }
                // died mid-drop: everything it held becomes
                // failure-shed at the next recovery point, which
                // subsumes this round's takes
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "drop count"))
                }
            }
        }
        self.take_bufs = victims;
        out
    }

    /// Drop `rho` PMs uniformly at random across shards (PM-BL),
    /// allocating the budget proportionally to shard populations
    /// (largest-remainder rounding, deterministic).
    pub fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize {
        self.recover_dead();
        let total = self.pm_count();
        if rho == 0 || total == 0 {
            return 0;
        }
        let rho = rho.min(total);
        let mut alloc: Vec<usize> =
            self.pms.iter().map(|&c| rho * c / total).collect();
        let mut remainders: Vec<(usize, usize)> = (0..alloc.len())
            .map(|s| (rho * self.pms[s] % total, s))
            .collect();
        remainders.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut left = rho - alloc.iter().sum::<usize>();
        for &(_, s) in &remainders {
            if left == 0 {
                break;
            }
            if alloc[s] < self.pms[s] {
                alloc[s] += 1;
                left -= 1;
            }
        }
        // rounding can leave budget if some shards were capped; spill it
        // to any shard with headroom (total capacity ≥ rho by the min
        // above, so this terminates)
        let mut s = 0;
        while left > 0 {
            if alloc[s] < self.pms[s] {
                alloc[s] += 1;
                left -= 1;
            }
            s = (s + 1) % alloc.len();
        }
        let mut dropped = 0;
        let mut sent = [false; MAX_SHARDS];
        for (s, &k) in alloc.iter().enumerate() {
            if k > 0 {
                let seed = rng.next_u64();
                sent[s] = self.send(s, Request::DropRandom { rho: k, seed });
                if sent[s] && self.journal_armed(s) {
                    self.journal_push(s, JournalEntry::DropRandom { rho: k, seed });
                }
            }
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                continue;
            }
            match self.recv(s) {
                Some(Response::Dropped(d)) => {
                    if self.journal_armed(s) {
                        self.journal_ack(s);
                    }
                    self.pms[s] -= d;
                    dropped += d;
                }
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "drop count"))
                }
            }
        }
        dropped
    }

    /// Remove every PM and window on every shard (between phases).
    pub fn reset_state(&mut self) {
        self.recover_dead();
        self.broadcast_ack(|| Request::Reset);
        self.pms.fill(0);
        self.wins_open.fill(0);
        self.open_windows = 0;
        self.pending_completions.clear();
        if self.recovery.checkpointing() {
            // the recovery baseline restarts at the empty state the
            // reset produced: journals back to genesis, snapshots
            // retired to the spare slots; each shard's first journaled
            // entry will be a digest sync (`stale` below), aligning
            // replay with the digest the reset did *not* clear
            {
                let mut journals = self.journals.borrow_mut();
                for j in journals.iter_mut() {
                    j.clear();
                    j.armed = true;
                }
            }
            for s in 0..self.n_shards() {
                if let Some(b) = self.snaps[s].take() {
                    self.spares[s] = Some(b);
                }
                self.stale[s].set(true);
            }
        }
    }

    /// Enumerate every live PM across all shards (shard order, then
    /// each shard's enumeration order).  Query indices are global;
    /// `pm_id` is only unique within its shard.  Responses ride
    /// per-shard recycled sinks, so repeated enumeration allocates
    /// nothing once the sinks reach their working size.
    pub fn pm_refs(&self, buf: &mut Vec<PmRef>) {
        buf.clear();
        let mut sinks = self.ref_sinks.borrow_mut();
        let mut sent = [false; MAX_SHARDS];
        for s in 0..self.n_shards() {
            let sink = std::mem::take(&mut sinks[s]);
            sent[s] = self.send(s, Request::PmRefs { sink });
        }
        for s in 0..self.n_shards() {
            if !sent[s] {
                continue; // dead shard: no live PMs to enumerate
            }
            match self.recv(s) {
                Some(Response::PmRefs(mut refs)) => {
                    buf.extend_from_slice(&refs);
                    refs.clear();
                    sinks[s] = refs;
                }
                None => {}
                Some(_) => {
                    self.mark_dead(s, self.protocol_violation(s, "pm refs"))
                }
            }
        }
    }
}

impl OperatorState for ShardedOperator {
    fn parallelism(&self) -> usize {
        self.n_shards()
    }

    fn pm_count(&self) -> usize {
        ShardedOperator::pm_count(self)
    }

    fn open_windows(&self) -> usize {
        ShardedOperator::open_windows(self)
    }

    fn match_probability(&self) -> f64 {
        ShardedOperator::match_probability(self)
    }

    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn pm_refs(&self, buf: &mut Vec<PmRef>) {
        ShardedOperator::pm_refs(self, buf);
    }

    fn install_table_set(&mut self, set: Arc<TableSet>) {
        ShardedOperator::install_table_set(self, set);
    }

    fn table_epoch(&self) -> u64 {
        ShardedOperator::table_epoch(self)
    }

    fn harvest_observations(&self, into: &mut ModelHarvest) {
        ShardedOperator::harvest_observations(self, into);
    }

    fn set_obs_enabled(&mut self, enabled: bool) {
        ShardedOperator::set_obs_enabled(self, enabled);
    }

    fn process_batch_into(
        &mut self,
        events: &[Event],
        shed_mask: Option<&DropMask>,
        out: &mut BatchResult,
    ) {
        self.dispatch_into(events, shed_mask, out);
    }

    fn shed_lowest(&mut self, rho: usize) -> ShedOutcome {
        ShardedOperator::shed_lowest(self, rho)
    }

    fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize {
        ShardedOperator::drop_random(self, rho, rng)
    }

    fn reset_state(&mut self) {
        ShardedOperator::reset_state(self);
    }

    fn drain_failures(&mut self) -> FailureDrain {
        ShardedOperator::drain_failures(self)
    }
}

impl Drop for ShardedOperator {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Request::Shutdown);
        }
        for (s, h) in self.handles.drain(..).enumerate() {
            if self.hung[s].get() {
                // a hung worker may be parked far past any deadline;
                // joining it would stall teardown — detach instead
                // (its eventual send hits a dropped receiver and the
                // thread exits on its own)
                continue;
            }
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{BusGen, StockGen};
    use crate::events::EventStream;
    use crate::operator::Operator;
    use crate::query::builtin::{q1, q3, q4};

    #[test]
    fn round_robin_covers_all_queries_once() {
        let plan = ShardPlan::round_robin(7, 3);
        assert_eq!(plan.n_shards(), 3);
        let mut seen: Vec<usize> =
            plan.assignments.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
        // never more shards than queries
        assert_eq!(ShardPlan::round_robin(2, 8).n_shards(), 2);
        assert_eq!(ShardPlan::round_robin(5, 1).n_shards(), 1);
        assert_eq!(plan.locate(4), Some((1, 1)));
    }

    #[test]
    fn sharded_matches_unsharded_completions_and_pm_count() {
        let queries = q4(4, 2_000, 250).queries;
        let events: Vec<_> = {
            let mut g = BusGen::with_seed(21);
            g.take_events(15_000)
        };

        let mut plain = Operator::new(queries.clone());
        let mut expected = Vec::new();
        for e in &events {
            expected.extend(plain.process_event(e).completions);
        }
        sort_completions(&mut expected);

        // q4 is a single query, so run the two-query q1 set too for a
        // real multi-shard split below; here 1 shard must still match
        let mut sharded = ShardedOperator::new(queries, 1);
        let mut got = Vec::new();
        for chunk in events.chunks(512) {
            got.extend(sharded.process_batch(chunk).completions);
        }
        assert_eq!(got, expected);
        assert_eq!(sharded.pm_count(), plain.pm_count());
        assert!(
            (sharded.match_probability() - plain.match_probability()).abs()
                < 1e-12
        );
    }

    #[test]
    fn multi_shard_split_matches_unsharded_on_stock() {
        let queries = q1(1_500).queries; // two queries -> two shards
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(22);
            g.take_events(20_000)
        };
        let mut plain = Operator::new(queries.clone());
        let mut expected = Vec::new();
        for e in &events {
            expected.extend(plain.process_event(e).completions);
        }
        sort_completions(&mut expected);

        let mut sharded = ShardedOperator::new(queries, 2);
        assert_eq!(sharded.n_shards(), 2);
        let mut got = Vec::new();
        for chunk in events.chunks(777) {
            got.extend(sharded.process_batch(chunk).completions);
        }
        assert_eq!(got, expected);
        assert_eq!(sharded.pm_count(), plain.pm_count());
    }

    #[test]
    fn masked_batch_does_bookkeeping_only() {
        let queries = q4(3, 1_000, 100).queries;
        let events: Vec<_> = {
            let mut g = BusGen::with_seed(5);
            g.take_events(2_000)
        };
        let mask = crate::events::DropMask::from_bools(&vec![true; events.len()]);
        let mut sharded = ShardedOperator::new(queries, 1);
        let out = sharded.process_batch_masked(&events, &mask);
        assert!(out.completions.is_empty(), "shed events cannot match");
        assert_eq!(out.checks, 0);
        assert!(out.opened > 0, "windows still open on shed events");
        assert!(sharded.pm_count() > 0, "window seeds still exist");
    }

    #[test]
    fn dispatch_pool_stays_at_one_buffer() {
        let queries = q1(1_000).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(4);
            g.take_events(20_000)
        };
        let mut sharded = ShardedOperator::new(queries, 2);
        for chunk in events.chunks(512) {
            sharded.process_batch(chunk);
        }
        // the synchronous protocol keeps exactly one batch in flight,
        // so the pool never needs a second buffer
        assert_eq!(sharded.pooled_batches(), 1);
    }

    #[test]
    fn irrelevant_batches_skip_inert_shards_bitwise() {
        // q1 (stock, etype 0, count windows, OnMatch opens) sharded
        // with itself: feed a trace whose etype can never match — the
        // coordinator must skip the send entirely, with the same
        // observable outcome as an unskipped run
        let foreign: Vec<Event> = (0..4_000u64)
            .map(|i| Event::new(i, i, 7, &[1.0, 2.0, 0.0]))
            .collect();
        let run = |routing: bool| {
            let mut sop = ShardedOperator::new(q1(1_000).queries, 2);
            sop.set_type_routing(routing);
            let mut cost_max = Vec::new();
            for chunk in foreign.chunks(256) {
                let out = sop.process_batch(chunk);
                assert!(out.completions.is_empty());
                cost_max.push(out.cost_ns_max.to_bits());
            }
            (cost_max, sop.pm_count(), sop.skipped_dispatches())
        };
        let (cost_on, pms_on, skipped_on) = run(true);
        let (cost_off, pms_off, skipped_off) = run(false);
        assert_eq!(pms_on, 0);
        assert_eq!(pms_on, pms_off);
        assert!(skipped_on > 0, "inert shards must be skipped");
        assert_eq!(skipped_off, 0, "routing off must not skip");
        assert_eq!(
            cost_on, cost_off,
            "skipped dispatch must reproduce the worker's cost bit-for-bit"
        );
    }

    #[test]
    fn slide_opened_shards_skip_between_due_seqs_bitwise() {
        // q4 opens EveryK(250) — a window opens on every 250th seq
        // whatever the event's type, so PR 4's static predicate could
        // never skip it.  Foreign batches are skippable exactly in the
        // stretches where no seq is due and the previous slide's window
        // has expired, and the outcome must stay bit-identical to a
        // routing-off run that sends every batch.
        let queries = q4(3, 100, 250).queries;
        let foreign: Vec<Event> = (0..5_000u64)
            .map(|i| Event::new(i, i, 7, &[1.0, 2.0, 0.0, 0.0]))
            .collect();
        let run = |routing: bool| {
            let mut sop = ShardedOperator::new(queries.clone(), 1);
            sop.set_type_routing(routing);
            let mut cost = Vec::new();
            let mut opened = 0usize;
            for chunk in foreign.chunks(50) {
                let out = sop.process_batch(chunk);
                assert!(out.completions.is_empty());
                opened += out.opened;
                cost.push(out.cost_ns_max.to_bits());
            }
            (cost, opened, sop.pm_count(), sop.skipped_dispatches())
        };
        let (cost_on, opened_on, pms_on, skipped_on) = run(true);
        let (cost_off, opened_off, pms_off, skipped_off) = run(false);
        assert!(opened_on > 0, "due seqs must still open slide windows");
        assert_eq!(opened_on, opened_off);
        assert_eq!(pms_on, pms_off);
        assert!(skipped_on > 0, "no-due stretches must be skipped");
        assert_eq!(skipped_off, 0, "routing off must not skip");
        assert_eq!(
            cost_on, cost_off,
            "skipped dispatch must reproduce the worker's cost bit-for-bit"
        );
    }

    #[test]
    fn skipped_time_window_shards_resync_rate_digest() {
        // q3 opens OnMatch with a *time* window, whose expected window
        // size reads the events-per-ms EWMA — worker state that moves
        // on every event, relevant or not.  Fully-foreign batches are
        // skipped, and the SyncRate resync must make the harvest
        // report exactly the ws an unsharded run computes.
        let queries = q3(4, 1_500).queries;
        let foreign: Vec<Event> = (0..4_000u64)
            .map(|i| Event::new(i, 3 * i, 7, &[1.0, 2.0, 0.0]))
            .collect();
        let mut plain = Operator::new(queries.clone());
        for e in &foreign {
            plain.process_event(e);
        }
        let mut sop = ShardedOperator::new(queries, 1);
        for chunk in foreign.chunks(256) {
            let out = sop.process_batch(chunk);
            assert!(out.completions.is_empty());
            assert_eq!(out.opened, 0);
        }
        assert!(sop.skipped_dispatches() > 0, "foreign batches must skip");
        let mut h = ModelHarvest::default();
        sop.harvest_observations(&mut h);
        assert_eq!(h.ws, plain.expected_ws(), "rate digest must resync exactly");
        // the digest carried real information: a worker left on the
        // default digest (1 event/ms) would have reported ws = 1500
        assert_ne!(h.ws[0], 1_500, "ws must reflect the folded stream rate");
    }

    #[test]
    fn drop_random_is_exact_across_shards() {
        let queries = q1(2_000).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(10_000)
        };
        let mut sharded = ShardedOperator::new(queries, 2);
        sharded.process_batch(&events);
        let before = sharded.pm_count();
        assert!(before > 10, "need PMs, got {before}");
        let mut rng = Rng::seeded(3);
        let dropped = sharded.drop_random(before / 2, &mut rng);
        assert_eq!(dropped, before / 2);
        assert_eq!(sharded.pm_count(), before - dropped);
        // over-draw drops everything
        let rest = sharded.pm_count();
        assert_eq!(sharded.drop_random(rest + 100, &mut rng), rest);
        assert_eq!(sharded.pm_count(), 0);
    }

    #[test]
    fn shed_rounds_recycle_victim_buffers() {
        let queries = q1(2_000).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(10_000)
        };
        let mut sharded = ShardedOperator::new(queries, 2);
        sharded.process_batch(&events);
        let before = sharded.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let out1 = sharded.shed_lowest(10);
        assert_eq!(out1.dropped, 10);
        // the victim take lists came back from the workers: the
        // re-stowed buffers keep their capacity for the next round
        let cap: usize = sharded.take_bufs.iter().map(|b| b.capacity()).sum();
        assert!(cap > 0, "take buffers must be re-stowed after the round");
        let out2 = sharded.shed_lowest(5);
        assert_eq!(out2.dropped, 5);
        assert_eq!(sharded.pm_count(), before - 15);
    }

    #[test]
    fn pm_refs_enumerates_across_shards() {
        let queries = q1(2_000).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(10_000)
        };
        let mut sharded = ShardedOperator::new(queries, 2);
        sharded.process_batch(&events);
        let mut refs = Vec::new();
        sharded.pm_refs(&mut refs);
        assert_eq!(refs.len(), sharded.pm_count());
        // query indices come back global, covering both shards
        assert!(refs.iter().any(|r| r.query == 0));
        assert!(refs.iter().any(|r| r.query == 1));
    }

    #[test]
    fn table_set_broadcast_reaches_every_worker_and_harvest_merges() {
        let queries = q1(1_500).queries; // two queries -> two shards
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(3);
            g.take_events(8_000)
        };
        let mut plain = Operator::new(queries.clone());
        for e in &events {
            plain.process_event(e);
        }
        let mut sop = ShardedOperator::new(queries, 2);
        for chunk in events.chunks(512) {
            sop.process_batch(chunk);
        }
        // harvest merges worker statistics into global order,
        // bit-identical to the single-threaded hub
        let mut h = ModelHarvest::default();
        sop.harvest_observations(&mut h);
        assert_eq!(h.ws, plain.expected_ws());
        assert_eq!(h.hub.total(), plain.obs.total());
        assert!(h.hub.total() > 0, "scenario must observe transitions");
        for (a, b) in h.hub.queries.iter().zip(&plain.obs.queries) {
            assert_eq!(a.counts, b.counts, "per-query counts diverged");
        }
        // epoch 0 before any install; a broadcast reaches every worker
        assert_eq!(sop.table_epoch(), 0);
        assert_eq!(sop.worker_epochs(), vec![0, 0]);
        let set = Arc::new(TableSet {
            epoch: 7,
            tables: Vec::new(),
            check_factors: vec![2.0, 3.0],
            ws: Vec::new(),
            key: None,
        });
        sop.install_table_set(set);
        assert_eq!(sop.table_epoch(), 7);
        assert_eq!(sop.worker_epochs(), vec![7, 7]);
        assert_eq!(sop.cost.check_factor, vec![2.0, 3.0]);
    }

    #[test]
    fn reset_clears_all_shards() {
        let queries = q1(2_000).queries;
        let mut g = StockGen::with_seed(2);
        let events = g.take_events(5_000);
        let mut sharded = ShardedOperator::new(queries, 2);
        sharded.process_batch(&events);
        assert!(sharded.pm_count() > 0);
        sharded.reset_state();
        assert_eq!(sharded.pm_count(), 0);
    }

    #[test]
    fn empty_fault_plan_is_exactly_new() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(22);
            g.take_events(8_000)
        };
        let run = |mut sop: ShardedOperator| {
            let mut got = Vec::new();
            let mut cost = Vec::new();
            for chunk in events.chunks(512) {
                let out = sop.process_batch(chunk);
                cost.push(out.cost_ns_max.to_bits());
                got.extend(out.completions);
            }
            let drain = sop.drain_failures();
            assert_eq!(drain, FailureDrain::default());
            (got, cost, sop.pm_count())
        };
        let plain = run(ShardedOperator::new(queries.clone(), 2));
        let faulted = run(ShardedOperator::with_faults(
            queries,
            2,
            FaultPlan::none(),
        ));
        assert_eq!(plain, faulted);
    }

    #[test]
    fn injected_kill_recovers_and_accounts_lost_pms_as_shed() {
        let queries = q1(1_500).queries; // two queries -> two shards
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(20_000)
        };
        let run = || {
            let plan = FaultPlan::parse("kill:0@10").unwrap();
            let mut sop = ShardedOperator::with_faults(queries.clone(), 2, plan);
            let mut completions = 0usize;
            let mut lost = 0u64;
            let mut recoveries = 0u64;
            for chunk in events.chunks(512) {
                completions += sop.process_batch(chunk).completions.len();
                let d = sop.drain_failures();
                lost += d.dropped_pms;
                recoveries += d.recoveries;
            }
            assert_eq!(recoveries, 1, "exactly one kill, exactly one respawn");
            assert!(lost > 0, "the dead shard held PMs that must count as shed");
            assert!(completions > 0, "the surviving shard keeps completing");
            assert!(sop.pm_count() > 0, "the replacement accumulates state again");
            (completions, lost, sop.pm_count())
        };
        // same seed + same plan => identical failure accounting
        assert_eq!(run(), run());
    }

    #[test]
    fn poison_drop_cells_fails_structured_and_recovers() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(12_000)
        };
        let plan = FaultPlan::parse("poison:1@5").unwrap();
        let mut sop = ShardedOperator::with_faults(queries.clone(), 2, plan);
        for chunk in events.chunks(512) {
            sop.process_batch(chunk);
        }
        let d = sop.drain_failures();
        assert_eq!(d.recoveries, 1, "the poisoned take must kill shard 1 once");
        // the run kept going on both shards afterwards
        assert!(sop.pm_count() > 0);
        assert_eq!(sop.drain_failures(), FailureDrain::default(), "drain resets");
    }

    #[test]
    fn delayed_response_changes_nothing_but_wall_time() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(6_000)
        };
        let run = |spec: &str| {
            let plan = FaultPlan::parse(spec).unwrap();
            let mut sop = ShardedOperator::with_faults(queries.clone(), 2, plan);
            let mut got = Vec::new();
            for chunk in events.chunks(512) {
                got.extend(sop.process_batch(chunk).completions);
            }
            assert_eq!(sop.drain_failures(), FailureDrain::default());
            (got, sop.pm_count())
        };
        assert_eq!(run(""), run("delay:0@2:1.5"));
    }

    #[test]
    fn recovery_reinstalls_tables_routing_and_rate() {
        // kill a shard after a table install and a routing toggle: the
        // replacement must adopt the same epoch without any caller
        // intervention, and the harvest must still resync its digest
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(3);
            g.take_events(10_000)
        };
        let plan = FaultPlan::parse("kill:0@8").unwrap();
        let mut sop = ShardedOperator::with_faults(queries.clone(), 2, plan);
        let set = Arc::new(TableSet {
            epoch: 9,
            tables: Vec::new(),
            check_factors: vec![2.0, 3.0],
            ws: Vec::new(),
            key: None,
        });
        sop.install_table_set(set);
        for chunk in events.chunks(512) {
            sop.process_batch(chunk);
        }
        assert_eq!(sop.drain_failures().recoveries, 1);
        assert_eq!(sop.worker_epochs(), vec![9, 9], "replacement re-adopts epoch");
        let mut h = ModelHarvest::default();
        sop.harvest_observations(&mut h);
        assert!(h.ws.iter().all(|&w| w > 0), "ws flows from a synced digest");
    }

    /// Checkpointing on: a killed shard restores snapshot + journal,
    /// reproducing the clean run's completions and PM state exactly —
    /// nothing is booked as failure shedding.
    #[test]
    fn checkpointed_kill_restores_state_exactly() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(20_000)
        };
        let clean = {
            let mut sop = ShardedOperator::new(queries.clone(), 2);
            let mut got = Vec::new();
            for chunk in events.chunks(512) {
                got.extend(sop.process_batch(chunk).completions);
            }
            (got, sop.pm_count())
        };
        let recovery = RecoveryConfig {
            checkpoint_every: 4,
            journal_cap: 100_000,
            worker_deadline_ms: 0.0,
        };
        let plan = FaultPlan::parse("kill:0@10").unwrap();
        let mut sop =
            ShardedOperator::with_recovery(queries, 2, plan, recovery);
        let mut got = Vec::new();
        for chunk in events.chunks(512) {
            got.extend(sop.process_batch(chunk).completions);
        }
        let d = sop.drain_failures();
        assert_eq!(d.recoveries, 1, "one kill, one recovery");
        assert_eq!(d.dropped_pms, 0, "recovery must not be lossy");
        assert!(d.recovered_pms > 0, "the dead shard's PMs come back");
        assert!(d.replayed_events > 0, "replay covers the journal");
        assert_eq!(d.hangs_detected, 0);
        assert_eq!(
            (got, sop.pm_count()),
            clean,
            "restored run must match the clean run bit-for-bit"
        );
    }

    /// A worker killed between the `Candidates` harvest and `DropCells`
    /// (the mid-shed-round death): victim selection stays deterministic
    /// and no dropped PM is ever booked twice — lossily the whole shard
    /// becomes failure-shed, checkpointed the takes are replayed and
    /// booked exactly once as voluntary shedding.
    #[test]
    fn shed_kill_mid_round_never_double_books() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(12_000)
        };
        let run = |recovery: RecoveryConfig| {
            let plan = FaultPlan::parse("shedkill:1@4").unwrap();
            let mut sop =
                ShardedOperator::with_recovery(queries.clone(), 2, plan, recovery);
            for chunk in events.chunks(512) {
                sop.process_batch(chunk);
            }
            let before = sop.pm_count();
            let before_s1 = sop.pm_counts()[1];
            assert!(before_s1 > 0, "shard 1 must hold PMs before the round");
            // a budget past shard 0's whole population forces takes
            // onto shard 1, so the armed shed-kill is guaranteed to
            // fire mid-round
            let rho = sop.pm_counts()[0] + before_s1 / 2;
            let out = sop.shed_lowest(rho);
            let d = sop.drain_failures();
            assert_eq!(d.recoveries, 1, "the armed shed-kill fires exactly once");
            assert_eq!(
                out.per_shard[1].1, 0,
                "no CellsDropped ack can come from the dead shard"
            );
            (before, before_s1, out.dropped, d, sop.pm_count())
        };
        // lossy: shard 1 dies before applying its takes; its entire
        // population is booked as failure shedding, exactly once
        let lossy = run(RecoveryConfig::default());
        let (_, before_s1, _, d, _) = lossy;
        assert_eq!(d.dropped_pms, before_s1 as u64, "whole shard becomes failure-shed");
        assert_eq!(d.recovered_pms, 0);
        // deterministic victim selection: same seed + plan => same round
        assert_eq!(run(RecoveryConfig::default()), lossy);
        // checkpointed: the unacked takes replay on the restored state
        // and are booked exactly once, as voluntary shedding
        let recovery = RecoveryConfig {
            checkpoint_every: 4,
            journal_cap: 100_000,
            worker_deadline_ms: 0.0,
        };
        let (before, before_s1, dropped, d, pm_after) = run(recovery);
        assert_eq!(d.dropped_pms, 0, "nothing is lossily shed");
        assert!(d.replayed_drop_pms > 0, "the takes replay exactly once");
        assert_eq!(
            d.recovered_pms,
            before_s1 as u64 - d.replayed_drop_pms,
            "recovered = shard population minus its replayed drops"
        );
        assert_eq!(
            pm_after,
            before - dropped - d.replayed_drop_pms as usize,
            "population reflects each drop exactly once"
        );
    }

    /// An injected hang is detected by the response deadline instead of
    /// blocking the coordinator forever: the shard is marked hung, its
    /// thread detached, and the run continues through a recovery.
    #[test]
    fn hang_is_detected_within_the_deadline() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(6_000)
        };
        let recovery = RecoveryConfig {
            checkpoint_every: 0,
            journal_cap: 8_192,
            worker_deadline_ms: 200.0,
        };
        let plan = FaultPlan::parse("hang:0@3").unwrap();
        let mut sop =
            ShardedOperator::with_recovery(queries, 2, plan, recovery);
        let mut completions = 0usize;
        for chunk in events.chunks(512) {
            completions += sop.process_batch(chunk).completions.len();
        }
        let d = sop.drain_failures();
        assert_eq!(d.hangs_detected, 1, "the deadline must catch the hang");
        assert_eq!(d.recoveries, 1, "a hang recovers like a crash");
        assert!(completions > 0, "the run keeps completing");
        assert!(sop.pm_count() > 0);
    }

    /// Three consecutive failures quarantine the shard onto the inline
    /// fallback lane: no more respawns, later faults aimed at the shard
    /// can never fire, and the run stays deterministic.
    #[test]
    fn crash_loop_quarantines_to_the_inline_fallback() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(12_000)
        };
        let run = || {
            // kills at 2, 3, 4 are consecutive (no clean response in
            // between); the kill at 6 would hit the quarantined lane,
            // which carries no fault schedule — it must never fire
            let plan =
                FaultPlan::parse("kill:0@2,kill:0@3,kill:0@4,kill:0@6").unwrap();
            let mut sop = ShardedOperator::with_faults(queries.clone(), 2, plan);
            let mut got = Vec::new();
            for chunk in events.chunks(512) {
                got.extend(sop.process_batch(chunk).completions);
            }
            let d = sop.drain_failures();
            assert_eq!(
                d.recoveries, 3,
                "third failure quarantines; the fourth kill never fires"
            );
            assert!(sop.pm_count() > 0, "the inline lane accumulates state");
            (got, sop.pm_count())
        };
        assert_eq!(run(), run());
    }

    /// A journal that outgrows its cap degrades the shard to lossy
    /// recovery until the next checkpoint: a later kill books its PMs
    /// as failure shedding, with nothing recovered.
    #[test]
    fn journal_overflow_degrades_to_lossy_recovery() {
        let queries = q1(1_500).queries;
        let events: Vec<_> = {
            let mut g = StockGen::with_seed(9);
            g.take_events(12_000)
        };
        let recovery = RecoveryConfig {
            // no checkpoint ever completes within the run, and the very
            // first 512-event batch overflows the 100-event cap
            checkpoint_every: 1_000,
            journal_cap: 100,
            worker_deadline_ms: 0.0,
        };
        let plan = FaultPlan::parse("kill:0@10").unwrap();
        let mut sop =
            ShardedOperator::with_recovery(queries, 2, plan, recovery);
        for chunk in events.chunks(512) {
            sop.process_batch(chunk);
        }
        let d = sop.drain_failures();
        assert_eq!(d.recoveries, 1);
        assert!(d.dropped_pms > 0, "degraded shard loses its PMs lossily");
        assert_eq!(d.recovered_pms, 0, "nothing can be restored after overflow");
    }
}
