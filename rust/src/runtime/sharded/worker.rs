//! The shard worker: a thread owning one [`Operator`] over a subset of
//! the query set, driven by a small request/response protocol over
//! bounded channels.
//!
//! Workers never talk to each other — all cross-shard coordination
//! (completion merging, global victim selection) happens at the
//! [`super::ShardedOperator`] façade, which is what keeps the protocol
//! deadlock-free: every request gets exactly one response, and the
//! coordinator always drains responses before sending the next round.

use std::collections::HashSet;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::events::Event;
use crate::model::UtilityTable;
use crate::operator::{ComplexEvent, Operator, PmRef};
use crate::query::Query;
use crate::util::Rng;

/// One shed candidate: a PM with its utility and its sharding-invariant
/// identity (used for deterministic cross-shard tie-breaking).
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// looked-up utility
    pub utility: f64,
    /// shard-local PM id (only meaningful to the shard that sent it)
    pub pm_id: u64,
    /// global query index
    pub query: usize,
    /// opening sequence number of the PM's window
    pub open_seq: u64,
    /// bound correlation keys
    pub key_bits: u64,
    /// current state
    pub state: u32,
}

/// Aggregated outcome of one batch on one shard.
#[derive(Debug, Default, Clone)]
pub struct BatchOutcome {
    /// completions with *global* query indices, in processing order
    pub completions: Vec<ComplexEvent>,
    /// summed virtual cost of the batch on this shard (ns)
    pub cost_ns: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
    /// live PMs after the batch
    pub n_pms: usize,
    /// PMs ever created on this shard
    pub pms_created: u64,
    /// complex events ever emitted on this shard
    pub completions_total: u64,
}

/// Coordinator → worker.
pub(super) enum Request {
    /// Process a batch; events with a true `skip_match` bit get window
    /// bookkeeping only (black-box event shedding semantics).
    Batch {
        /// the shared batch
        events: Arc<Vec<Event>>,
        /// optional per-event "event was shed" mask
        skip_match: Option<Arc<Vec<bool>>>,
    },
    /// Install utility tables, one per *local* query, local order.
    SetTables(Vec<UtilityTable>),
    /// Apply per-local-query check-cost factors.
    SetCostFactors(Vec<f64>),
    /// Toggle observation capture.
    SetObsEnabled(bool),
    /// Return the shard's `rho` lowest-utility PMs, sorted ascending.
    Candidates {
        /// global drop budget (upper bound on candidates needed)
        rho: usize,
    },
    /// Enumerate every live PM (query indices remapped to global).
    PmRefs,
    /// Drop the PMs with these (shard-local) ids.
    DropByIds(HashSet<u64>),
    /// Drop `rho` PMs uniformly at random with a seeded RNG.
    DropRandom {
        /// how many to drop
        rho: usize,
        /// RNG seed from the coordinator (keeps runs deterministic)
        seed: u64,
    },
    /// Remove every PM and window.
    Reset,
    /// Exit the worker loop.
    Shutdown,
}

/// Worker → coordinator.
pub(super) enum Response {
    /// outcome of a `Batch`
    Batch(BatchOutcome),
    /// sorted lowest-utility candidates
    Candidates(Vec<Candidate>),
    /// every live PM with global query indices
    PmRefs(Vec<PmRef>),
    /// PMs actually dropped
    Dropped(usize),
    /// acknowledgement of a state-setting request
    Ack,
}

/// The worker loop.  `local_to_global[i]` is the global index of the
/// shard's `i`-th query.
pub(super) fn run(
    rx: Receiver<Request>,
    tx: Sender<Response>,
    queries: Vec<Query>,
    local_to_global: Vec<usize>,
) {
    let mut op = Operator::new(queries);
    let mut tables: Vec<UtilityTable> = Vec::new();
    let mut refs: Vec<PmRef> = Vec::new();
    while let Ok(req) = rx.recv() {
        let resp = match req {
            Request::Batch { events, skip_match } => {
                let mut out = BatchOutcome::default();
                for (i, e) in events.iter().enumerate() {
                    let skip = skip_match.as_ref().is_some_and(|m| m[i]);
                    let o = if skip {
                        op.process_bookkeeping(e)
                    } else {
                        op.process_event(e)
                    };
                    out.cost_ns += o.cost_ns;
                    out.checks += o.checks;
                    out.opened += o.opened;
                    out.closed += o.closed;
                    for mut ce in o.completions {
                        ce.query = local_to_global[ce.query];
                        out.completions.push(ce);
                    }
                }
                out.n_pms = op.pm_count();
                out.pms_created = op.pms_created;
                out.completions_total = op.completions_total;
                Response::Batch(out)
            }
            Request::SetTables(t) => {
                tables = t;
                Response::Ack
            }
            Request::SetCostFactors(f) => {
                op.cost.check_factor = f;
                Response::Ack
            }
            Request::SetObsEnabled(enabled) => {
                op.obs.enabled = enabled;
                Response::Ack
            }
            Request::Candidates { rho } => {
                op.pm_refs(&mut refs);
                let mut cands: Vec<Candidate> = refs
                    .iter()
                    .map(|r| Candidate {
                        utility: tables
                            .get(r.query)
                            .map_or(0.0, |t| t.lookup(r.state, r.remaining)),
                        pm_id: r.pm_id,
                        query: local_to_global[r.query],
                        open_seq: r.open_seq,
                        key_bits: r.key_bits,
                        state: r.state,
                    })
                    .collect();
                // O(n) partial selection of the rho lowest before the
                // O(rho log rho) sort the k-way merge needs — matches
                // the single-threaded shedder's select_nth approach
                if rho > 0 && rho < cands.len() {
                    cands.select_nth_unstable_by(rho - 1, super::merge::cand_cmp);
                    cands.truncate(rho);
                }
                cands.sort_unstable_by(super::merge::cand_cmp);
                Response::Candidates(cands)
            }
            Request::PmRefs => {
                op.pm_refs(&mut refs);
                Response::PmRefs(
                    refs.iter()
                        .map(|r| PmRef {
                            query: local_to_global[r.query],
                            ..*r
                        })
                        .collect(),
                )
            }
            Request::DropByIds(ids) => Response::Dropped(op.drop_pms(&ids)),
            Request::DropRandom { rho, seed } => {
                let mut rng = Rng::seeded(seed);
                Response::Dropped(op.drop_random(rho, &mut rng))
            }
            Request::Reset => {
                op.reset_state();
                Response::Ack
            }
            Request::Shutdown => break,
        };
        if tx.send(resp).is_err() {
            break; // coordinator gone
        }
    }
}
