//! The shard worker: a thread owning one [`Operator`] over a subset of
//! the query set, driven by a small request/response protocol over
//! bounded channels.
//!
//! Workers never talk to each other — all cross-shard coordination
//! (completion merging, global victim selection, model-snapshot
//! broadcast, observation harvest) happens at the
//! [`super::ShardedOperator`] façade, which is what keeps the protocol
//! deadlock-free: every request gets exactly one response, and the
//! coordinator always drains responses before sending the next round.
//!
//! The batch plane is allocation-free in steady state: batches arrive
//! as clones of pooled [`EventBatch`] `Arc`s (no copy), shed masks as
//! pooled [`DropMask`] `Arc`s, the per-event [`ProcessOutcome`] is a
//! worker-owned scratch, and completions are written into a recycled
//! sink the coordinator sends with each batch and gets back in the
//! response.  Shed-round traffic rides the same pattern:
//! [`Request::Candidates`] and [`Request::PmRefs`] carry recycled
//! sinks the worker fills *in place* (remapping query indices to
//! global), so a shed round allocates nothing on either side of the
//! channel.  Both channels are bounded (array-backed), so message
//! passing itself allocates nothing per dispatch.
//!
//! Model state arrives as an `Arc`-shared, epoch-numbered
//! [`TableSet`] ([`Request::UpdateTables`] — one broadcast per
//! install/retrain); the worker slices out its local queries' tables
//! and cost factors and remembers the epoch, which the coordinator can
//! audit via [`Request::Epoch`].  Training inputs flow the other way:
//! [`Request::Observations`] returns the worker's per-local-query
//! statistic *deltas* (only rows dirtied since the last harvest, as
//! verbatim cumulative values) plus expected window sizes for the
//! coordinator's mirrored harvest (cold path — retraining cadence,
//! not dispatch cadence — but O(changed rows), not O(m²), per check).
//!
//! Shed candidates travel as compact `(query, window, state)` **cell
//! summaries** ([`ShedCell`]) instead of per-PM `PmRef` streams: all
//! PMs of a cell share one utility, so worker-channel traffic for a
//! shed round is O(cells), not O(n_pm).
//!
//! # Supervision
//!
//! The worker never takes the coordinator down with it.  Each request
//! is handled under [`std::panic::catch_unwind`]; a panic — or a
//! protocol-level fault like a `DropCells` take for a query this shard
//! does not own — turns into a structured [`Response::Failed`] carrying
//! a [`ShardFailure`], after which the thread exits and the coordinator
//! respawns a replacement (see `ShardedOperator::recover_dead`).  The
//! deterministic [`FaultSpec`] list a worker carries makes this path
//! testable: injected kills/delays/poisons trigger on the worker's
//! cumulative batch-dispatch count, which survives respawn via
//! `dispatch_offset`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;

use super::checkpoint::{JournalEntry, RestoreOutcome};
use super::fault::{FaultKind, FaultSpec};
use crate::events::{DropMask, EventBatch};
use crate::model::plane::TableSet;
use crate::operator::{
    CellTake, ComplexEvent, Operator, PmRef, ProcessOutcome, RateDigest, ShardSnapshot, ShedCell,
    StatsDelta,
};
use crate::query::Query;
use crate::util::Rng;

/// How long an injected [`FaultKind::Hang`] sleeps: far past any
/// plausible `worker_deadline_ms`, so the coordinator always times out
/// first; the stuck thread is detached and its eventual send lands on a
/// dropped receiver.
const HANG_SLEEP: std::time::Duration = std::time::Duration::from_secs(600);

/// Aggregated outcome of one batch on one shard.
#[derive(Debug, Default, Clone)]
pub struct BatchOutcome {
    /// completions with *global* query indices, in processing order
    /// (written into the coordinator's recycled sink)
    pub completions: Vec<ComplexEvent>,
    /// summed virtual cost of the batch on this shard (ns)
    pub cost_ns: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
    /// live PMs after the batch
    pub n_pms: usize,
    /// PMs ever created on this shard
    pub pms_created: u64,
    /// complex events ever emitted on this shard
    pub completions_total: u64,
}

/// Why a shard worker died.  Sent as the worker's final message
/// ([`Response::Failed`]) instead of letting a panic poison the
/// channel; the coordinator turns it into dead-shard accounting and a
/// respawn.
#[derive(Debug, Clone)]
pub struct ShardFailure {
    /// which shard died
    pub shard: usize,
    /// the worker's cumulative batch-dispatch count at death (1-based;
    /// 0 if it never saw a batch)
    pub dispatch: u64,
    /// human-readable cause (panic message or protocol violation)
    pub reason: String,
}

/// Coordinator → worker.
pub(super) enum Request {
    /// Process a batch; events with a set [`DropMask`] bit get window
    /// bookkeeping only (black-box event shedding semantics).
    Batch {
        /// the shared pooled batch
        events: Arc<EventBatch>,
        /// optional per-event "event was shed" mask (pooled)
        shed: Option<Arc<DropMask>>,
        /// recycled completion sink — filled by the worker, returned in
        /// [`Response::Batch`], recycled by the coordinator
        sink: Vec<ComplexEvent>,
    },
    /// Install the model snapshot: the worker slices its local queries'
    /// tables and cost factors out of the `Arc`-shared [`TableSet`]
    /// and adopts its epoch.
    UpdateTables(Arc<TableSet>),
    /// Toggle observation capture.
    SetObsEnabled(bool),
    /// Toggle the operator's type-routed skim path.
    SetTypeRouting(bool),
    /// Return the shard's lowest-utility cells, sorted ascending by
    /// [`crate::operator::cell_cmp`], covering at least `rho` PMs
    /// (query indices remapped to global).  `sink` is the recycled
    /// cell buffer the worker fills in place.
    Candidates {
        /// global drop budget (upper bound on PMs needed)
        rho: usize,
        /// recycled cell sink, returned in [`Response::Candidates`]
        sink: Vec<ShedCell>,
    },
    /// Enumerate every live PM (query indices remapped to global) into
    /// the recycled `sink`.
    PmRefs {
        /// recycled PM-ref sink, returned in [`Response::PmRefs`]
        sink: Vec<PmRef>,
    },
    /// Report the worker's per-local-query observation statistics —
    /// as **delta rows** dirtied since the last harvest, not full
    /// matrix clones — and expected window sizes (the coordinator
    /// applies them to its persistent mirror of the global harvest).
    Observations,
    /// Report the epoch of the model snapshot the worker is reading.
    Epoch,
    /// Drop PMs cell-wise (global query indices; the worker remaps and
    /// applies them in place via [`Operator::drop_cells`]).  The take
    /// list is a recycled per-shard buffer — it comes back, cleared,
    /// in [`Response::CellsDropped`].
    DropCells(Vec<CellTake>),
    /// Overwrite the operator's stream-rate digest with the
    /// coordinator's mirror.  Sent before the next real batch to a
    /// shard whose irrelevant batches were skipped: every operator
    /// folds every event into the digest, so installing the mirror is
    /// bit-identical to having processed the skipped events.
    SyncRate(RateDigest),
    /// Drop `rho` PMs uniformly at random with a seeded RNG.
    DropRandom {
        /// how many to drop
        rho: usize,
        /// RNG seed from the coordinator (keeps runs deterministic)
        seed: u64,
    },
    /// Remove every PM and window.
    Reset,
    /// Export the operator's matching state into the recycled snapshot
    /// box (the checkpoint plane; see [`super::checkpoint`]).
    Checkpoint {
        /// recycled snapshot box — filled in place via
        /// [`Operator::export_snapshot`], returned in
        /// [`Response::Checkpoint`]
        sink: Box<ShardSnapshot>,
    },
    /// Restore a snapshot and replay the journal on a respawned worker
    /// (tables/routing/obs-enabled were already reinstalled by the
    /// preceding requests, exactly as on the lossy path).  Replay runs
    /// *without* fault injection or dispatch accounting — it reproduces
    /// state, it is not new work.
    Restore {
        /// the shard's last acked snapshot; `None` replays the journal
        /// from genesis — the empty state a fresh worker starts in
        snap: Option<Box<ShardSnapshot>>,
        /// journaled requests since that snapshot, oldest first
        journal: Vec<JournalEntry>,
        /// index of the first *unacked* entry: only completions and
        /// drops from entries at or past it are emitted/booked (the
        /// acked prefix was already merged before the crash)
        emit_from: usize,
    },
    /// Exit the worker loop.
    Shutdown,
}

/// Worker → coordinator.
pub(super) enum Response {
    /// outcome of a `Batch`
    Batch(BatchOutcome),
    /// sorted lowest-utility cell summaries (the recycled sink)
    Candidates {
        /// the rho-covering prefix of the shard's cells, sorted
        /// ascending (the recycled sink)
        cells: Vec<ShedCell>,
        /// cells enumerated by the O(cells) decision scan — the
        /// pre-truncation count, which is what the shed-cost model
        /// charges for
        scanned: usize,
    },
    /// every live PM with global query indices (the recycled sink)
    PmRefs(Vec<PmRef>),
    /// per-local-query statistic deltas + expected window sizes
    Observations {
        /// rows dirtied since the last harvest
        /// ([`crate::operator::QueryStats::take_delta`] — verbatim
        /// cumulative values, so the coordinator's mirror stays
        /// bit-identical to a full clone), local query order
        stats: Vec<StatsDelta>,
        /// expected window sizes, local query order
        ws: Vec<u64>,
    },
    /// epoch of the installed model snapshot
    Epoch(u64),
    /// PMs actually dropped ([`Request::DropRandom`])
    Dropped(usize),
    /// PMs actually dropped cell-wise, plus the recycled take buffer
    /// ([`Request::DropCells`])
    CellsDropped {
        /// PMs actually dropped
        n: usize,
        /// the request's take list, cleared for the coordinator to
        /// re-stow
        takes: Vec<CellTake>,
    },
    /// acknowledgement of a state-setting request
    Ack,
    /// the filled snapshot box ([`Request::Checkpoint`])
    Checkpoint(Box<ShardSnapshot>),
    /// outcome of a [`Request::Restore`]: restored counters + replay
    /// accounting, with the snapshot and journal handed back so the
    /// coordinator can reinstate them without cloning
    Restored {
        /// what the restore + replay produced
        outcome: RestoreOutcome,
        /// the snapshot, returned for reinstatement
        snap: Option<Box<ShardSnapshot>>,
        /// the journal, returned for reinstatement (now fully acked)
        journal: Vec<JournalEntry>,
    },
    /// the worker died (panic or protocol fault); this is its final
    /// message before the thread exits
    Failed(ShardFailure),
}

/// Mutable worker state, grouped so the request handler can be run
/// under one `AssertUnwindSafe` borrow.  `pub(super)` because the
/// coordinator also drives one *inline* (same-thread, fault-free) for
/// quarantined shards — see `quarantine` in [`super`].
pub(super) struct WorkerState {
    op: Operator,
    /// recycled local-index take buffer for `DropCells`
    takes: Vec<CellTake>,
    /// reused per-event outcome: the batch loop never allocates once
    /// the completions buffer has grown to its working size
    scratch: ProcessOutcome,
    local_to_global: Vec<usize>,
    /// injected faults for this shard, sorted by dispatch
    faults: Vec<FaultSpec>,
    /// cumulative batches handled (1-based after the first), starting
    /// from the respawn offset so fault triggers survive recovery
    dispatches: u64,
    /// a [`FaultKind::ShedKill`] fired: panic on the next `DropCells`
    /// request before applying any take
    armed_shed_kill: bool,
}

impl WorkerState {
    /// Fresh worker state over its own operator.
    pub(super) fn new(
        queries: Vec<Query>,
        local_to_global: Vec<usize>,
        faults: Vec<FaultSpec>,
        dispatch_offset: u64,
    ) -> Self {
        WorkerState {
            op: Operator::new(queries),
            takes: Vec::new(),
            scratch: ProcessOutcome::default(),
            local_to_global,
            faults,
            dispatches: dispatch_offset,
            armed_shed_kill: false,
        }
    }

    fn global_to_local(&self, g: usize) -> Result<usize, String> {
        self.local_to_global
            .iter()
            .position(|&x| x == g)
            .ok_or_else(|| format!("cell take for query {g}, which this shard does not own"))
    }

    /// Remap global-index takes to local and apply them; the malformed
    /// input that used to panic the worker is now a structured error.
    fn apply_cell_takes(&mut self, global_takes: &[CellTake]) -> Result<usize, String> {
        self.takes.clear();
        for t in global_takes {
            let query = self.global_to_local(t.query)?;
            self.takes.push(CellTake { query, ..*t });
        }
        // regroup under local indices (the remap is monotone for
        // round-robin plans, but don't rely on it)
        self.takes.sort_unstable_by_key(|t| (t.query, t.open_seq, t.state));
        Ok(self.op.drop_cells(&self.takes))
    }

    /// Fire any injected faults due at the current dispatch count.
    fn inject_due_faults(&mut self) -> Result<(), String> {
        // the list is tiny (a handful of specs per chaos run), so a
        // linear scan per batch is cheaper than tracking a cursor
        // across respawns
        for f in &self.faults {
            if f.dispatch != self.dispatches {
                continue;
            }
            match f.kind {
                FaultKind::Kill => {
                    // audit:allow(panic): deliberate chaos-injection crash —
                    // the supervision loop under test must absorb it
                    panic!("injected kill at dispatch {}", self.dispatches)
                }
                FaultKind::Delay(ms) => {
                    std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
                }
                FaultKind::PoisonDropCells => {
                    // exercise the real malformed-input path: a take
                    // for a query no shard owns
                    let poisoned = CellTake {
                        query: usize::MAX,
                        open_seq: 0,
                        state: 0,
                        take: 1,
                    };
                    self.apply_cell_takes(&[poisoned])?;
                }
                FaultKind::Hang => {
                    std::thread::sleep(HANG_SLEEP);
                }
                FaultKind::ShedKill => {
                    self.armed_shed_kill = true;
                }
            }
        }
        Ok(())
    }

    /// The batch plane's event loop, shared between live dispatch
    /// ([`Request::Batch`]) and journal replay ([`Request::Restore`]):
    /// process every event (bookkeeping-only where the shed mask is
    /// set), accumulate cost/check/window counters into `out`, and push
    /// completions — remapped to global query indices — into `sink`.
    fn process_batch_events(
        &mut self,
        events: &EventBatch,
        shed: Option<&DropMask>,
        out: &mut BatchOutcome,
        sink: &mut Vec<ComplexEvent>,
    ) {
        for (i, e) in events.events().iter().enumerate() {
            let skip = shed.is_some_and(|m| m.get(i));
            self.scratch.reset();
            if skip {
                self.op.process_bookkeeping_into(e, &mut self.scratch);
            } else {
                self.op.process_event_into(e, &mut self.scratch);
            }
            out.cost_ns += self.scratch.cost_ns;
            out.checks += self.scratch.checks;
            out.opened += self.scratch.opened;
            out.closed += self.scratch.closed;
            for ce in &self.scratch.completions {
                sink.push(ComplexEvent {
                    query: self.local_to_global[ce.query],
                    ..*ce
                });
            }
        }
    }

    pub(super) fn handle(&mut self, req: Request) -> Result<Response, String> {
        Ok(match req {
            Request::Batch {
                events,
                shed,
                mut sink,
            } => {
                self.dispatches += 1;
                self.inject_due_faults()?;
                let mut out = BatchOutcome::default();
                self.process_batch_events(&events, shed.as_deref(), &mut out, &mut sink);
                out.completions = sink;
                out.n_pms = self.op.pm_count();
                out.pms_created = self.op.pms_created;
                out.completions_total = self.op.completions_total;
                Response::Batch(out)
            }
            Request::UpdateTables(set) => {
                self.op.apply_table_set(&set, &self.local_to_global);
                Response::Ack
            }
            Request::SetObsEnabled(enabled) => {
                self.op.obs.enabled = enabled;
                Response::Ack
            }
            Request::SetTypeRouting(enabled) => {
                self.op.set_type_routing(enabled);
                Response::Ack
            }
            Request::Candidates { rho, mut sink } => {
                // O(cells) enumeration off the per-window state counts,
                // remapped to global indices and sorted *in the
                // recycled sink*; only the prefix covering rho PMs can
                // ever be picked, so the rest never crosses the channel
                self.op.cell_refs(&mut sink);
                let scanned = sink.len();
                for c in &mut sink {
                    c.query = self.local_to_global[c.query];
                }
                sink.sort_unstable_by(crate::operator::cell_cmp);
                let mut covered = 0usize;
                let mut keep = 0usize;
                for c in &sink {
                    keep += 1;
                    covered += c.count as usize;
                    if covered >= rho {
                        break;
                    }
                }
                sink.truncate(keep);
                Response::Candidates {
                    cells: sink,
                    scanned,
                }
            }
            Request::PmRefs { mut sink } => {
                self.op.pm_refs(&mut sink);
                for r in &mut sink {
                    r.query = self.local_to_global[r.query];
                }
                Response::PmRefs(sink)
            }
            Request::Observations => Response::Observations {
                stats: self
                    .op
                    .obs
                    .queries
                    .iter_mut()
                    .map(|q| q.take_delta())
                    .collect(),
                ws: self.op.expected_ws(),
            },
            Request::Epoch => Response::Epoch(self.op.table_epoch()),
            Request::DropCells(mut global_takes) => {
                if self.armed_shed_kill {
                    // die between the Candidates harvest and the drop:
                    // the coordinator already merged victims, but no
                    // take lands on this shard
                    // audit:allow(panic): deliberate chaos-injection crash
                    panic!(
                        "injected shed-kill after dispatch {} (before applying takes)",
                        self.dispatches
                    );
                }
                let n = self.apply_cell_takes(&global_takes)?;
                global_takes.clear();
                Response::CellsDropped {
                    n,
                    takes: global_takes,
                }
            }
            Request::SyncRate(digest) => {
                self.op.set_rate_digest(digest);
                Response::Ack
            }
            Request::DropRandom { rho, seed } => {
                let mut rng = Rng::seeded(seed);
                Response::Dropped(self.op.drop_random(rho, &mut rng))
            }
            Request::Reset => {
                self.op.reset_state();
                Response::Ack
            }
            Request::Checkpoint { mut sink } => {
                self.op.export_snapshot(&mut sink);
                Response::Checkpoint(sink)
            }
            Request::Restore {
                snap,
                journal,
                emit_from,
            } => {
                if let Some(snap) = &snap {
                    self.op.import_snapshot(snap);
                }
                let mut outcome = RestoreOutcome::default();
                // replay accounting rides the normal batch counters; a
                // scratch sink swallows completions of acked entries
                // (the coordinator merged them before the crash)
                let mut acc = BatchOutcome::default();
                let mut discard: Vec<ComplexEvent> = Vec::new();
                for (i, entry) in journal.iter().enumerate() {
                    let emit = i >= emit_from;
                    match entry {
                        JournalEntry::Batch { events, shed } => {
                            outcome.replayed_events += events.len() as u64;
                            let dst = if emit {
                                &mut outcome.completions
                            } else {
                                &mut discard
                            };
                            self.process_batch_events(events, shed.as_deref(), &mut acc, dst);
                            discard.clear();
                        }
                        JournalEntry::DropCells(takes) => {
                            let n = self.apply_cell_takes(takes)?;
                            if emit {
                                outcome.replayed_drop_pms += n as u64;
                            }
                        }
                        JournalEntry::DropRandom { rho, seed } => {
                            let mut rng = Rng::seeded(*seed);
                            let n = self.op.drop_random(*rho, &mut rng);
                            if emit {
                                outcome.replayed_drop_pms += n as u64;
                            }
                        }
                        JournalEntry::SyncRate(digest) => {
                            self.op.set_rate_digest(*digest);
                        }
                    }
                }
                outcome.replay_cost_ns = acc.cost_ns;
                outcome.pms = self.op.pm_count();
                outcome.created = self.op.pms_created;
                outcome.completed = self.op.completions_total;
                outcome.wins_open = self.op.open_windows();
                Response::Restored {
                    outcome,
                    snap,
                    journal,
                }
            }
            // audit:allow(panic): the run loop matches Shutdown before
            // calling handle(), so this arm is statically dead
            Request::Shutdown => unreachable!("Shutdown is handled by the loop"),
        })
    }
}

fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

/// The worker loop.  `local_to_global[i]` is the global index of the
/// shard's `i`-th query.  `faults` is this shard's slice of the run's
/// [`super::FaultPlan`]; `dispatch_offset` is how many batches
/// previous incarnations of this shard already handled, so fault
/// triggers keyed on cumulative dispatch counts survive respawn.
pub(super) fn run(
    shard: usize,
    rx: Receiver<Request>,
    tx: SyncSender<Response>,
    queries: Vec<Query>,
    local_to_global: Vec<usize>,
    faults: Vec<FaultSpec>,
    dispatch_offset: u64,
) {
    let mut state = WorkerState::new(queries, local_to_global, faults, dispatch_offset);
    while let Ok(req) = rx.recv() {
        if matches!(req, Request::Shutdown) {
            break;
        }
        let resp = match catch_unwind(AssertUnwindSafe(|| state.handle(req))) {
            Ok(Ok(resp)) => resp,
            Ok(Err(reason)) => {
                // structured protocol fault: report and die — the
                // operator may hold partially-applied state
                let _ = tx.send(Response::Failed(ShardFailure {
                    shard,
                    dispatch: state.dispatches,
                    reason,
                }));
                return;
            }
            Err(payload) => {
                let _ = tx.send(Response::Failed(ShardFailure {
                    shard,
                    dispatch: state.dispatches,
                    reason: panic_reason(payload.as_ref()),
                }));
                return;
            }
        };
        if tx.send(resp).is_err() {
            break; // coordinator gone
        }
    }
}
