//! The shard worker: a thread owning one [`Operator`] over a subset of
//! the query set, driven by a small request/response protocol over
//! bounded channels.
//!
//! Workers never talk to each other — all cross-shard coordination
//! (completion merging, global victim selection, model-snapshot
//! broadcast, observation harvest) happens at the
//! [`super::ShardedOperator`] façade, which is what keeps the protocol
//! deadlock-free: every request gets exactly one response, and the
//! coordinator always drains responses before sending the next round.
//!
//! The batch plane is allocation-free in steady state: batches arrive
//! as clones of pooled [`EventBatch`] `Arc`s (no copy), shed masks as
//! pooled [`DropMask`] `Arc`s, the per-event [`ProcessOutcome`] is a
//! worker-owned scratch, and completions are written into a recycled
//! sink the coordinator sends with each batch and gets back in the
//! response.  Shed-round traffic rides the same pattern:
//! [`Request::Candidates`] and [`Request::PmRefs`] carry recycled
//! sinks the worker fills *in place* (remapping query indices to
//! global), so a shed round allocates nothing on either side of the
//! channel.  Both channels are bounded (array-backed), so message
//! passing itself allocates nothing per dispatch.
//!
//! Model state arrives as an `Arc`-shared, epoch-numbered
//! [`TableSet`] ([`Request::UpdateTables`] — one broadcast per
//! install/retrain); the worker slices out its local queries' tables
//! and cost factors and remembers the epoch, which the coordinator can
//! audit via [`Request::Epoch`].  Training inputs flow the other way:
//! [`Request::Observations`] returns the worker's per-local-query
//! statistic *deltas* (only rows dirtied since the last harvest, as
//! verbatim cumulative values) plus expected window sizes for the
//! coordinator's mirrored harvest (cold path — retraining cadence,
//! not dispatch cadence — but O(changed rows), not O(m²), per check).
//!
//! Shed candidates travel as compact `(query, window, state)` **cell
//! summaries** ([`ShedCell`]) instead of per-PM `PmRef` streams: all
//! PMs of a cell share one utility, so worker-channel traffic for a
//! shed round is O(cells), not O(n_pm).

use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;

use crate::events::{DropMask, EventBatch};
use crate::model::plane::TableSet;
use crate::operator::{
    CellTake, ComplexEvent, Operator, PmRef, ProcessOutcome, RateDigest, ShedCell, StatsDelta,
};
use crate::query::Query;
use crate::util::Rng;

/// Aggregated outcome of one batch on one shard.
#[derive(Debug, Default, Clone)]
pub struct BatchOutcome {
    /// completions with *global* query indices, in processing order
    /// (written into the coordinator's recycled sink)
    pub completions: Vec<ComplexEvent>,
    /// summed virtual cost of the batch on this shard (ns)
    pub cost_ns: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
    /// live PMs after the batch
    pub n_pms: usize,
    /// PMs ever created on this shard
    pub pms_created: u64,
    /// complex events ever emitted on this shard
    pub completions_total: u64,
}

/// Coordinator → worker.
pub(super) enum Request {
    /// Process a batch; events with a set [`DropMask`] bit get window
    /// bookkeeping only (black-box event shedding semantics).
    Batch {
        /// the shared pooled batch
        events: Arc<EventBatch>,
        /// optional per-event "event was shed" mask (pooled)
        shed: Option<Arc<DropMask>>,
        /// recycled completion sink — filled by the worker, returned in
        /// [`Response::Batch`], recycled by the coordinator
        sink: Vec<ComplexEvent>,
    },
    /// Install the model snapshot: the worker slices its local queries'
    /// tables and cost factors out of the `Arc`-shared [`TableSet`]
    /// and adopts its epoch.
    UpdateTables(Arc<TableSet>),
    /// Toggle observation capture.
    SetObsEnabled(bool),
    /// Toggle the operator's type-routed skim path.
    SetTypeRouting(bool),
    /// Return the shard's lowest-utility cells, sorted ascending by
    /// [`crate::operator::cell_cmp`], covering at least `rho` PMs
    /// (query indices remapped to global).  `sink` is the recycled
    /// cell buffer the worker fills in place.
    Candidates {
        /// global drop budget (upper bound on PMs needed)
        rho: usize,
        /// recycled cell sink, returned in [`Response::Candidates`]
        sink: Vec<ShedCell>,
    },
    /// Enumerate every live PM (query indices remapped to global) into
    /// the recycled `sink`.
    PmRefs {
        /// recycled PM-ref sink, returned in [`Response::PmRefs`]
        sink: Vec<PmRef>,
    },
    /// Report the worker's per-local-query observation statistics —
    /// as **delta rows** dirtied since the last harvest, not full
    /// matrix clones — and expected window sizes (the coordinator
    /// applies them to its persistent mirror of the global harvest).
    Observations,
    /// Report the epoch of the model snapshot the worker is reading.
    Epoch,
    /// Drop PMs cell-wise (global query indices; the worker remaps and
    /// applies them in place via [`Operator::drop_cells`]).  The take
    /// list is a recycled per-shard buffer — it comes back, cleared,
    /// in [`Response::CellsDropped`].
    DropCells(Vec<CellTake>),
    /// Overwrite the operator's stream-rate digest with the
    /// coordinator's mirror.  Sent before the next real batch to a
    /// shard whose irrelevant batches were skipped: every operator
    /// folds every event into the digest, so installing the mirror is
    /// bit-identical to having processed the skipped events.
    SyncRate(RateDigest),
    /// Drop `rho` PMs uniformly at random with a seeded RNG.
    DropRandom {
        /// how many to drop
        rho: usize,
        /// RNG seed from the coordinator (keeps runs deterministic)
        seed: u64,
    },
    /// Remove every PM and window.
    Reset,
    /// Exit the worker loop.
    Shutdown,
}

/// Worker → coordinator.
pub(super) enum Response {
    /// outcome of a `Batch`
    Batch(BatchOutcome),
    /// sorted lowest-utility cell summaries (the recycled sink)
    Candidates(Vec<ShedCell>),
    /// every live PM with global query indices (the recycled sink)
    PmRefs(Vec<PmRef>),
    /// per-local-query statistic deltas + expected window sizes
    Observations {
        /// rows dirtied since the last harvest
        /// ([`crate::operator::QueryStats::take_delta`] — verbatim
        /// cumulative values, so the coordinator's mirror stays
        /// bit-identical to a full clone), local query order
        stats: Vec<StatsDelta>,
        /// expected window sizes, local query order
        ws: Vec<u64>,
    },
    /// epoch of the installed model snapshot
    Epoch(u64),
    /// PMs actually dropped ([`Request::DropRandom`])
    Dropped(usize),
    /// PMs actually dropped cell-wise, plus the recycled take buffer
    /// ([`Request::DropCells`])
    CellsDropped {
        /// PMs actually dropped
        n: usize,
        /// the request's take list, cleared for the coordinator to
        /// re-stow
        takes: Vec<CellTake>,
    },
    /// acknowledgement of a state-setting request
    Ack,
}

/// The worker loop.  `local_to_global[i]` is the global index of the
/// shard's `i`-th query.
pub(super) fn run(
    rx: Receiver<Request>,
    tx: SyncSender<Response>,
    queries: Vec<Query>,
    local_to_global: Vec<usize>,
) {
    let mut op = Operator::new(queries);
    let mut takes: Vec<CellTake> = Vec::new();
    // reused per-event outcome: the batch loop never allocates once the
    // completions buffer has grown to its working size
    let mut scratch = ProcessOutcome::default();
    let global_to_local = |g: usize| -> usize {
        local_to_global
            .iter()
            .position(|&x| x == g)
            .expect("cell take for a query this shard does not own")
    };
    while let Ok(req) = rx.recv() {
        let resp = match req {
            Request::Batch {
                events,
                shed,
                mut sink,
            } => {
                let mut out = BatchOutcome::default();
                for (i, e) in events.events().iter().enumerate() {
                    let skip = shed.as_ref().is_some_and(|m| m.get(i));
                    scratch.reset();
                    if skip {
                        op.process_bookkeeping_into(e, &mut scratch);
                    } else {
                        op.process_event_into(e, &mut scratch);
                    }
                    out.cost_ns += scratch.cost_ns;
                    out.checks += scratch.checks;
                    out.opened += scratch.opened;
                    out.closed += scratch.closed;
                    for ce in &scratch.completions {
                        sink.push(ComplexEvent {
                            query: local_to_global[ce.query],
                            ..*ce
                        });
                    }
                }
                out.completions = sink;
                out.n_pms = op.pm_count();
                out.pms_created = op.pms_created;
                out.completions_total = op.completions_total;
                Response::Batch(out)
            }
            Request::UpdateTables(set) => {
                op.apply_table_set(&set, &local_to_global);
                Response::Ack
            }
            Request::SetObsEnabled(enabled) => {
                op.obs.enabled = enabled;
                Response::Ack
            }
            Request::SetTypeRouting(enabled) => {
                op.set_type_routing(enabled);
                Response::Ack
            }
            Request::Candidates { rho, mut sink } => {
                // O(cells) enumeration off the per-window state counts,
                // remapped to global indices and sorted *in the
                // recycled sink*; only the prefix covering rho PMs can
                // ever be picked, so the rest never crosses the channel
                op.cell_refs(&mut sink);
                for c in &mut sink {
                    c.query = local_to_global[c.query];
                }
                sink.sort_unstable_by(crate::operator::cell_cmp);
                let mut covered = 0usize;
                let mut keep = 0usize;
                for c in &sink {
                    keep += 1;
                    covered += c.count as usize;
                    if covered >= rho {
                        break;
                    }
                }
                sink.truncate(keep);
                Response::Candidates(sink)
            }
            Request::PmRefs { mut sink } => {
                op.pm_refs(&mut sink);
                for r in &mut sink {
                    r.query = local_to_global[r.query];
                }
                Response::PmRefs(sink)
            }
            Request::Observations => Response::Observations {
                stats: op
                    .obs
                    .queries
                    .iter_mut()
                    .map(|q| q.take_delta())
                    .collect(),
                ws: op.expected_ws(),
            },
            Request::Epoch => Response::Epoch(op.table_epoch()),
            Request::DropCells(mut global_takes) => {
                takes.clear();
                takes.extend(global_takes.iter().map(|t| CellTake {
                    query: global_to_local(t.query),
                    ..*t
                }));
                // regroup under local indices (the remap is monotone
                // for round-robin plans, but don't rely on it)
                takes.sort_unstable_by_key(|t| (t.query, t.open_seq, t.state));
                let n = op.drop_cells(&takes);
                global_takes.clear();
                Response::CellsDropped {
                    n,
                    takes: global_takes,
                }
            }
            Request::SyncRate(digest) => {
                op.set_rate_digest(digest);
                Response::Ack
            }
            Request::DropRandom { rho, seed } => {
                let mut rng = Rng::seeded(seed);
                Response::Dropped(op.drop_random(rho, &mut rng))
            }
            Request::Reset => {
                op.reset_state();
                Response::Ack
            }
            Request::Shutdown => break,
        };
        if tx.send(resp).is_err() {
            break; // coordinator gone
        }
    }
}
