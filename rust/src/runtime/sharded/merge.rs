//! Deterministic merging: complex-event ordering across shards and the
//! k-way merge that picks the globally lowest-utility shed victims from
//! per-shard **cell** candidate lists (paper Alg. 2's "drop the ρ
//! lowest-utility PMs", preserved across shards at O(cells) traffic).

use std::cmp::Ordering;

use crate::operator::{cell_cmp, CellTake, ComplexEvent, ShedCell, MAX_SHARDS};

/// K-way merge over per-shard cell lists (each sorted ascending by
/// [`cell_cmp`]): walks the global cell order, consuming whole cells
/// until the budget `rho` is met — the final cell may be taken
/// partially — and fills, per shard, the [`CellTake`] drop
/// instructions (global query indices, grouped by window) into the
/// caller's recycled `out` buffers (cleared first; one per shard, so a
/// steady-state shed round allocates no victim lists).
///
/// Because [`cell_cmp`] is a sharding-invariant total order and a
/// partial take removes the first PMs of the cell in window position
/// order, a 1-shard and an N-shard run select the *identical* victim
/// set — the first `rho` PMs in the engine's documented order
/// `(utility, query, open_seq, state, window position)`.
pub(super) fn k_way_take(lists: &[Vec<ShedCell>], rho: usize, out: &mut [Vec<CellTake>]) {
    let k = lists.len();
    debug_assert_eq!(k, out.len(), "one take buffer per shard");
    for takes in out.iter_mut() {
        takes.clear();
    }
    debug_assert!(k <= MAX_SHARDS);
    let mut cursor = [0usize; MAX_SHARDS];
    let mut left = rho;
    while left > 0 {
        let mut best: Option<usize> = None;
        for s in 0..k {
            if cursor[s] >= lists[s].len() {
                continue;
            }
            best = match best {
                None => Some(s),
                Some(b) => {
                    if cell_cmp(&lists[s][cursor[s]], &lists[b][cursor[b]])
                        == Ordering::Less
                    {
                        Some(s)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        let Some(b) = best else { break };
        let c = &lists[b][cursor[b]];
        let take = (c.count as usize).min(left) as u32;
        out[b].push(CellTake {
            query: c.query,
            open_seq: c.open_seq,
            state: c.state,
            take,
        });
        left -= take as usize;
        cursor[b] += 1;
    }
    // each per-shard list regrouped by window for the in-place drop
    for takes in out.iter_mut() {
        takes.sort_unstable_by_key(|t: &CellTake| (t.query, t.open_seq, t.state));
    }
}

/// Sort completions into the canonical deterministic order.  The key
/// `(completed_seq, query, window_open_seq, key_bits)` reproduces the
/// single-threaded operator's emission order: event order first, then
/// query order, then window order within the event.
pub fn sort_completions(ces: &mut [ComplexEvent]) {
    ces.sort_unstable_by_key(|ce| {
        (ce.completed_seq, ce.query, ce.window_open_seq, ce.key_bits)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(utility: f64, query: usize, open_seq: u64, count: u32) -> ShedCell {
        ShedCell {
            utility,
            query,
            open_seq,
            state: 0,
            count,
        }
    }

    /// Run the merge into fresh buffers (tests for the recycled path
    /// pass their own).
    fn take(lists: &[Vec<ShedCell>], rho: usize) -> Vec<Vec<CellTake>> {
        let mut out = vec![Vec::new(); lists.len()];
        k_way_take(lists, rho, &mut out);
        out
    }

    /// Flatten one shard's takes into comparable tuples.
    fn keys(takes: &[CellTake]) -> Vec<(usize, u64, u32, u32)> {
        takes
            .iter()
            .map(|t| (t.query, t.open_seq, t.state, t.take))
            .collect()
    }

    fn total(takes: &[Vec<CellTake>]) -> usize {
        takes.iter().flatten().map(|t| t.take as usize).sum()
    }

    #[test]
    fn k_way_take_picks_global_lowest_cells() {
        // shard 0: utilities 1 (x3), 5 (x2) — shard 1: 2 (x2), 3 (x4)
        let lists = vec![
            vec![cell(1.0, 0, 0, 3), cell(5.0, 0, 10, 2)],
            vec![cell(2.0, 1, 0, 2), cell(3.0, 1, 10, 4)],
        ];
        let v = take(&lists, 7);
        // 3 from u=1, 2 from u=2, then 2 of the 4 at u=3
        assert_eq!(keys(&v[0]), vec![(0, 0, 0, 3)]);
        assert_eq!(keys(&v[1]), vec![(1, 0, 0, 2), (1, 10, 0, 2)]);
        assert_eq!(total(&v), 7);
    }

    #[test]
    fn k_way_take_handles_short_lists_and_overdraw() {
        let lists = vec![vec![cell(1.0, 0, 0, 2)], vec![]];
        let v = take(&lists, 10);
        assert_eq!(keys(&v[0]), vec![(0, 0, 0, 2)]);
        assert!(v[1].is_empty());
        assert_eq!(total(&v), 2);
    }

    #[test]
    fn cell_ties_break_on_identity() {
        // equal utilities: the lower (query, open_seq, state) cell wins
        let a = cell(1.0, 0, 5, 1);
        let b = cell(1.0, 0, 9, 1);
        assert_eq!(cell_cmp(&a, &b), Ordering::Less);
        // NaN sorts above every finite utility (poisoned cells survive)
        let n = ShedCell {
            utility: f64::NAN,
            ..a
        };
        assert_eq!(cell_cmp(&a, &n), Ordering::Less);
        let lists = vec![vec![b], vec![a]];
        let v = take(&lists, 1);
        assert!(v[0].is_empty(), "the open_seq=5 cell must win the tie");
        assert_eq!(v[1].len(), 1);
    }

    #[test]
    fn takes_come_back_grouped_by_window() {
        // one shard, three single-PM cells: two windows interleaved by
        // utility — the output must still be window-grouped
        let mut c1 = cell(1.0, 0, 20, 1);
        c1.state = 0;
        let mut c2 = cell(2.0, 0, 10, 1);
        c2.state = 1;
        let mut c3 = cell(3.0, 0, 20, 1);
        c3.state = 2;
        let lists = vec![vec![c1, c2, c3]];
        let v = take(&lists, 3);
        assert_eq!(keys(&v[0]), vec![(0, 10, 1, 1), (0, 20, 0, 1), (0, 20, 2, 1)]);
    }

    #[test]
    fn recycled_buffers_are_cleared_before_reuse() {
        let lists = vec![vec![cell(1.0, 0, 0, 2)], vec![cell(2.0, 1, 0, 2)]];
        let mut out = vec![Vec::new(), Vec::new()];
        k_way_take(&lists, 4, &mut out);
        assert_eq!(total(&out), 4);
        // same buffers, smaller budget: stale takes must not survive
        k_way_take(&lists, 1, &mut out);
        assert_eq!(keys(&out[0]), vec![(0, 0, 0, 1)]);
        assert!(out[1].is_empty());
        assert_eq!(total(&out), 1);
    }

    #[test]
    fn sort_completions_is_canonical() {
        let mut ces = vec![
            ComplexEvent {
                query: 1,
                window_open_seq: 0,
                key_bits: 0,
                completed_seq: 7,
            },
            ComplexEvent {
                query: 0,
                window_open_seq: 3,
                key_bits: 1,
                completed_seq: 7,
            },
            ComplexEvent {
                query: 0,
                window_open_seq: 2,
                key_bits: 0,
                completed_seq: 5,
            },
        ];
        sort_completions(&mut ces);
        assert_eq!(ces[0].completed_seq, 5);
        assert_eq!(ces[1].query, 0);
        assert_eq!(ces[2].query, 1);
    }
}
