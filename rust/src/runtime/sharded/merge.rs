//! Deterministic merging: complex-event ordering across shards and the
//! k-way merge that picks the globally lowest-utility shed victims from
//! per-shard candidate lists (paper Alg. 2's "drop the ρ lowest-utility
//! PMs", preserved across shards).

use std::cmp::Ordering;

use crate::operator::ComplexEvent;

use super::worker::Candidate;

/// Total order over shed candidates: utility first (NaN-safe total
/// order, +NaN sorts above all numbers so poisoned PMs survive), then
/// the sharding-invariant PM identity so 1-shard and N-shard runs pick
/// identical victims even under utility ties.
pub(super) fn cand_cmp(a: &Candidate, b: &Candidate) -> Ordering {
    a.utility
        .total_cmp(&b.utility)
        .then_with(|| a.query.cmp(&b.query))
        .then_with(|| a.open_seq.cmp(&b.open_seq))
        .then_with(|| a.key_bits.cmp(&b.key_bits))
        .then_with(|| a.state.cmp(&b.state))
        .then_with(|| a.pm_id.cmp(&b.pm_id))
}

/// K-way merge over per-shard candidate lists (each sorted ascending by
/// [`cand_cmp`]): selects the `rho` globally lowest candidates and
/// returns, per shard, the (shard-local) PM ids to drop.
pub(super) fn k_way_select(lists: &[Vec<Candidate>], rho: usize) -> Vec<Vec<u64>> {
    let k = lists.len();
    let mut cursor = vec![0usize; k];
    let mut out = vec![Vec::new(); k];
    let mut taken = 0;
    while taken < rho {
        let mut best: Option<usize> = None;
        for s in 0..k {
            if cursor[s] >= lists[s].len() {
                continue;
            }
            best = match best {
                None => Some(s),
                Some(b) => {
                    if cand_cmp(&lists[s][cursor[s]], &lists[b][cursor[b]])
                        == Ordering::Less
                    {
                        Some(s)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        let Some(b) = best else { break };
        out[b].push(lists[b][cursor[b]].pm_id);
        cursor[b] += 1;
        taken += 1;
    }
    out
}

/// Sort completions into the canonical deterministic order.  The key
/// `(completed_seq, query, window_open_seq, key_bits)` reproduces the
/// single-threaded operator's emission order: event order first, then
/// query order, then window order within the event.
pub fn sort_completions(ces: &mut [ComplexEvent]) {
    ces.sort_unstable_by_key(|ce| {
        (ce.completed_seq, ce.query, ce.window_open_seq, ce.key_bits)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(utility: f64, pm_id: u64, query: usize) -> Candidate {
        Candidate {
            utility,
            pm_id,
            query,
            open_seq: 0,
            key_bits: 0,
            state: 0,
        }
    }

    #[test]
    fn k_way_select_picks_global_lowest() {
        // shard 0: utilities 1, 5, 9 — shard 1: 2, 3, 4
        let lists = vec![
            vec![cand(1.0, 10, 0), cand(5.0, 11, 0), cand(9.0, 12, 0)],
            vec![cand(2.0, 20, 1), cand(3.0, 21, 1), cand(4.0, 22, 1)],
        ];
        let v = k_way_select(&lists, 4);
        assert_eq!(v[0], vec![10]);
        assert_eq!(v[1], vec![20, 21, 22]);
    }

    #[test]
    fn k_way_select_handles_short_lists_and_overdraw() {
        let lists = vec![vec![cand(1.0, 1, 0)], vec![]];
        let v = k_way_select(&lists, 10);
        assert_eq!(v[0], vec![1]);
        assert!(v[1].is_empty());
    }

    #[test]
    fn ties_break_on_identity_not_arrival() {
        // equal utilities: the lower (query, open_seq, ...) identity wins
        let a = Candidate {
            utility: 1.0,
            pm_id: 99,
            query: 0,
            open_seq: 5,
            key_bits: 0,
            state: 1,
        };
        let b = Candidate {
            utility: 1.0,
            pm_id: 1,
            query: 0,
            open_seq: 9,
            key_bits: 0,
            state: 1,
        };
        assert_eq!(cand_cmp(&a, &b), Ordering::Less);
        // NaN sorts above every finite utility
        let n = Candidate {
            utility: f64::NAN,
            ..a
        };
        assert_eq!(cand_cmp(&a, &n), Ordering::Less);
    }

    #[test]
    fn sort_completions_is_canonical() {
        let mut ces = vec![
            ComplexEvent {
                query: 1,
                window_open_seq: 0,
                key_bits: 0,
                completed_seq: 7,
            },
            ComplexEvent {
                query: 0,
                window_open_seq: 3,
                key_bits: 1,
                completed_seq: 7,
            },
            ComplexEvent {
                query: 0,
                window_open_seq: 2,
                key_bits: 0,
                completed_seq: 5,
            },
        ];
        sort_completions(&mut ces);
        assert_eq!(ces[0].completed_seq, 5);
        assert_eq!(ces[1].query, 0);
        assert_eq!(ces[2].query, 1);
    }
}
