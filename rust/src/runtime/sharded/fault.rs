//! Deterministic fault injection for the sharded runtime.
//!
//! A [`FaultPlan`] is a list of [`FaultSpec`]s — *kill shard k at its
//! n-th batch dispatch*, *delay its response*, *poison a `DropCells`
//! take* — handed to each worker at spawn time.  Faults trigger on the
//! worker's **cumulative** batch-dispatch count (continuing across
//! respawns, see [`FaultPlan::for_shard`]), so a plan is a pure
//! function of the event stream: the same seed and plan produce the
//! same failures, the same recovery accounting, and the same surviving
//! completions on the virtual clock — which is what makes a chaos run
//! assertable in CI instead of merely stressful.
//!
//! The spec string (config key `faults`, CLI `--faults`) is a
//! comma-separated list:
//!
//! ```text
//! kill:1@10, delay:0@5:2.5, poison:2@30
//! ```
//!
//! * `kill:<shard>@<dispatch>` — the worker panics while handling its
//!   `<dispatch>`-th batch (exercising the `catch_unwind` supervision
//!   and the coordinator's respawn path),
//! * `delay:<shard>@<dispatch>:<ms>` — the worker sleeps `<ms>` wall
//!   milliseconds before answering (latency fault; virtual-clock
//!   accounting is untouched, so simulated runs stay bit-exact),
//! * `poison:<shard>@<dispatch>` — the worker runs a `DropCells` take
//!   for a query it does not own (the malformed-input path that used
//!   to panic the worker; now a structured [`super::ShardFailure`]),
//! * `hang:<shard>@<dispatch>` — the worker stops responding (sleeps
//!   far past any deadline) instead of crashing: exercises the
//!   coordinator's `worker_deadline_ms` hang detection, which marks the
//!   shard dead, *detaches* the stuck thread, and recovers exactly like
//!   a crash,
//! * `shedkill:<shard>@<dispatch>` — arms on the `<dispatch>`-th batch
//!   and panics the worker on its *next* `DropCells` request, before
//!   any take is applied: the worker dies mid-shed-round, between the
//!   `Candidates` harvest and the drop, pinning the coordinator's
//!   already-merged victim selection and its no-double-booking
//!   accounting.
//!
//! Dispatch counts are 1-based and per shard.

use std::sync::Once;

/// What to inject.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// panic inside the worker's batch handler
    Kill,
    /// sleep this many wall-clock milliseconds before responding
    Delay(f64),
    /// apply a `DropCells` take for an unowned query
    PoisonDropCells,
    /// stop responding (sleep far past any deadline) instead of
    /// crashing — the hang-detection fault
    Hang,
    /// arm on this batch, then panic on the next `DropCells` request
    /// before applying any take (death mid-shed-round)
    ShedKill,
}

/// One injected fault: `kind` fires when `shard` handles its
/// `dispatch`-th batch (1-based, cumulative across respawns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// target shard index
    pub shard: usize,
    /// 1-based cumulative batch-dispatch count that triggers the fault
    pub dispatch: u64,
    /// what happens
    pub kind: FaultKind,
}

/// A deterministic fault schedule for one sharded run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// every injected fault (any order; matched by shard + dispatch)
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// No faults (the plan every ordinary run carries implicitly).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Is there nothing to inject?
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The faults aimed at `shard`, in dispatch order — the list a
    /// (re)spawned worker carries.
    pub fn for_shard(&self, shard: usize) -> Vec<FaultSpec> {
        let mut v: Vec<FaultSpec> = self
            .faults
            .iter()
            .filter(|f| f.shard == shard)
            .copied()
            .collect();
        v.sort_by_key(|f| f.dispatch);
        v
    }

    /// Highest shard index any fault targets (validation: the plan
    /// must fit the actual shard count).
    pub fn max_shard(&self) -> Option<usize> {
        self.faults.iter().map(|f| f.shard).max()
    }

    /// Parse the comma-separated spec-string format documented on the
    /// [module](self).  Empty input is the empty plan.
    pub fn parse(s: &str) -> crate::Result<Self> {
        let mut faults = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            faults.push(Self::parse_entry(entry)?);
        }
        Ok(FaultPlan { faults })
    }

    fn parse_entry(entry: &str) -> crate::Result<FaultSpec> {
        let (kind_name, rest) = entry
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault {entry:?}: expected kind:shard@dispatch"))?;
        let (shard_s, rest) = rest
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault {entry:?}: expected shard@dispatch"))?;
        let shard: usize = shard_s
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("fault {entry:?}: bad shard: {e}"))?;
        let (dispatch_s, tail) = match rest.split_once(':') {
            Some((d, t)) => (d, Some(t)),
            None => (rest, None),
        };
        let dispatch: u64 = dispatch_s
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("fault {entry:?}: bad dispatch: {e}"))?;
        anyhow::ensure!(dispatch >= 1, "fault {entry:?}: dispatch counts are 1-based");
        let kind = match (kind_name.trim(), tail) {
            ("kill", None) => FaultKind::Kill,
            ("poison", None) => FaultKind::PoisonDropCells,
            ("hang", None) => FaultKind::Hang,
            ("shedkill", None) => FaultKind::ShedKill,
            ("delay", Some(ms)) => {
                let ms: f64 = ms
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault {entry:?}: bad delay ms: {e}"))?;
                anyhow::ensure!(
                    ms.is_finite() && ms >= 0.0,
                    "fault {entry:?}: delay must be a finite non-negative ms value"
                );
                FaultKind::Delay(ms)
            }
            ("delay", None) => {
                anyhow::bail!("fault {entry:?}: delay needs a trailing :ms value")
            }
            (k @ ("kill" | "poison" | "hang" | "shedkill"), Some(_)) => {
                anyhow::bail!("fault {entry:?}: {k} takes no trailing value")
            }
            (other, _) => anyhow::bail!(
                "fault {entry:?}: unknown kind {other:?} (kill|delay|poison|hang|shedkill)"
            ),
        };
        Ok(FaultSpec { shard, dispatch, kind })
    }
}

/// Keep injected worker panics from spraying the default panic
/// backtrace over stderr: panics on `pspice-shard-*` threads are
/// reported in-band as [`super::ShardFailure`]s, so the hook stays
/// quiet for them and delegates everything else to the previous hook.
/// Installed once per process, and only when a run actually carries a
/// fault plan — ordinary runs keep the stock panic output.
pub(super) fn install_quiet_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let on_shard = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("pspice-shard-"));
            if !on_shard {
                previous(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_spec_vocabulary() {
        let plan = FaultPlan::parse("kill:1@10, delay:0@5:2.5,poison:2@30").unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(
            plan.faults[0],
            FaultSpec { shard: 1, dispatch: 10, kind: FaultKind::Kill }
        );
        assert_eq!(
            plan.faults[1],
            FaultSpec { shard: 0, dispatch: 5, kind: FaultKind::Delay(2.5) }
        );
        assert_eq!(
            plan.faults[2],
            FaultSpec { shard: 2, dispatch: 30, kind: FaultKind::PoisonDropCells }
        );
        assert_eq!(plan.max_shard(), Some(2));
        let plan = FaultPlan::parse("hang:3@7,shedkill:1@4").unwrap();
        assert_eq!(
            plan.faults[0],
            FaultSpec { shard: 3, dispatch: 7, kind: FaultKind::Hang }
        );
        assert_eq!(
            plan.faults[1],
            FaultSpec { shard: 1, dispatch: 4, kind: FaultKind::ShedKill }
        );
        // per-shard extraction sorts by dispatch
        let plan = FaultPlan::parse("kill:0@20,kill:0@5").unwrap();
        let s0 = plan.for_shard(0);
        assert_eq!(s0.len(), 2);
        assert_eq!(s0[0].dispatch, 5);
        assert_eq!(s0[1].dispatch, 20);
        assert!(plan.for_shard(1).is_empty());
    }

    #[test]
    fn empty_and_bad_specs() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ,  ").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
        assert_eq!(FaultPlan::none().max_shard(), None);
        for bad in [
            "kill",             // no shard@dispatch
            "kill:1",           // no dispatch
            "kill:x@3",         // bad shard
            "kill:1@zero",      // bad dispatch
            "kill:1@0",         // dispatch is 1-based
            "delay:1@3",        // delay without ms
            "delay:1@3:soon",   // bad ms
            "delay:1@3:-1",     // negative ms
            "explode:1@3",      // unknown kind
            "hang:1@3:9",       // hang takes no tail
            "shedkill:1@3:9",   // shedkill takes no tail
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
