//! The shed-native checkpoint/recovery plane for the sharded runtime.
//!
//! PR 8 made worker death survivable but *lossy*: a crashed shard's PMs
//! were booked wholesale as involuntary shedding
//! (`dropped_pms_failure`) — the one failure mode where the system
//! dropped state with zero regard for utility.  This module closes that
//! gap with the classic snapshot + journal-replay recipe, specialized
//! to the engine's zero-alloc batch plane:
//!
//! * **Snapshots.**  Every [`RecoveryConfig::checkpoint_every`] batch
//!   dispatches, the coordinator sends each shard a recycled
//!   [`ShardSnapshot`] box (`Request::Checkpoint`); the worker fills it
//!   via [`crate::operator::Operator::export_snapshot`] — live PMs,
//!   window positions and their `StateCounts` cell indexes, the
//!   PM-id/created/completed counters, the rate digest and the obs-stat
//!   rows — reusing the box's buffers, and ships it back on the same
//!   request/response channel.  Steady-state checkpoints of a warm
//!   shard touch no allocator (the PR 4 discipline).
//!
//! * **Journal.**  Between acked snapshots the coordinator journals
//!   every state-mutating request it sends a shard: batches as clones
//!   of the pooled `EventBatch`/`DropMask` `Arc`s (no copy), shed
//!   directives as their take lists / RNG seeds.  `respawn` then
//!   restores the last snapshot and replays the journal
//!   (`Request::Restore`), which reproduces the dead worker's state
//!   bit-exactly — the one-request-in-flight protocol means at most the
//!   final journal entry was unacknowledged at death.
//!
//! * **Accounting.**  Restored PMs are booked as `recovered_pms`
//!   instead of `dropped_pms_failure`; completions of unacked entries
//!   are emitted into the next dispatch's merge (exactly the ones the
//!   dead worker never delivered); PMs dropped by replaying *unacked*
//!   shed directives are booked once, as ordinary voluntary shedding;
//!   and the replay's processing cost is charged to the virtual clock
//!   so recovery cannot hide work from the latency accounting.
//!   Snapshot capture itself charges nothing virtual: it models an
//!   asynchronous state mirror whose cost is real (wall) time, which
//!   the wall-clock plane observes on its own.
//!
//! * **Overflow degrade.**  The journal is bounded by
//!   [`RecoveryConfig::journal_cap`] (counted in events).  When a shard
//!   overflows it — checkpoints too sparse for the event rate — its
//!   snapshot and journal are discarded and the shard degrades to
//!   PR 8's lossy recovery (PMs booked as `dropped_pms_failure`) until
//!   the next completed checkpoint re-arms it.  Bounded memory beats
//!   unbounded replay: the cap is the knob that keeps recovery from
//!   becoming the thing that kills the latency bound.
//!
//! Deadline-bounded dispatch and quarantine (the hang-detection half of
//! this plane) live in the coordinator — see `recv_deadline` and
//! `quarantine` in `runtime/sharded/mod.rs`.

use std::sync::Arc;

use crate::events::{DropMask, EventBatch};
use crate::operator::{CellTake, ComplexEvent, RateDigest};

pub use crate::operator::ShardSnapshot;

/// Checkpoint/recovery knobs, threaded from `PipelineBuilder` into the
/// sharded coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    /// Take a per-shard snapshot every this many batch dispatches
    /// (0 = checkpointing off: worker death falls back to PR 8's lossy
    /// recovery).
    pub checkpoint_every: u64,
    /// Journal capacity per shard, in *events*.  A shard whose journal
    /// outgrows this between checkpoints degrades to lossy recovery
    /// until the next completed checkpoint (see the module docs).
    pub journal_cap: usize,
    /// Deadline for any single worker response, in wall milliseconds
    /// (0 = block forever, the PR 8 behavior).  A worker that misses it
    /// is treated as hung: marked dead, its thread detached, and the
    /// shard recovered like a crash.  Only meaningful on the wall
    /// clock; `PipelineBuilder::build` derives a default from the
    /// latency bound for wall-clock runs.
    pub worker_deadline_ms: f64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            checkpoint_every: 0,
            journal_cap: 8_192,
            worker_deadline_ms: 0.0,
        }
    }
}

impl RecoveryConfig {
    /// Is snapshot + journal recovery armed?
    #[inline]
    pub fn checkpointing(&self) -> bool {
        self.checkpoint_every > 0
    }

    /// The worker-response deadline, if one is set.
    #[inline]
    pub fn deadline(&self) -> Option<std::time::Duration> {
        (self.worker_deadline_ms > 0.0)
            .then(|| std::time::Duration::from_secs_f64(self.worker_deadline_ms / 1e3))
    }
}

/// One state-mutating request journaled at the coordinator since the
/// shard's last acked snapshot.  Batches hold clones of the pooled
/// `Arc`s — journaling copies pointers, never events.
pub(super) enum JournalEntry {
    /// a dispatched event batch (with its shed mask, if any)
    Batch {
        /// the shared pooled batch
        events: Arc<EventBatch>,
        /// the shared pooled shed mask
        shed: Option<Arc<DropMask>>,
    },
    /// a cell-wise shed directive (global query indices, as sent)
    DropCells(Vec<CellTake>),
    /// a random-drop directive with its deterministic seed
    DropRandom {
        /// how many PMs to drop
        rho: usize,
        /// the coordinator-chosen RNG seed
        seed: u64,
    },
    /// a rate-digest install (the PR 6 resync after skipped batches):
    /// journaled so a replayed worker's digest evolves exactly like the
    /// dead one's — snapshot digest, then the same interleaving of
    /// installs and per-event folds
    SyncRate(RateDigest),
}

/// Per-shard journal of state-mutating requests since the last acked
/// snapshot.  `acked` is the prefix of entries whose responses arrived
/// (their completions were merged and their drops booked); with the
/// synchronous one-in-flight protocol, at most one entry past `acked`
/// can exist when a worker dies.
#[derive(Default)]
pub(super) struct Journal {
    /// journaled requests, oldest first
    pub entries: Vec<JournalEntry>,
    /// total events across the `Batch` entries (the capacity metric)
    pub events: usize,
    /// acknowledged prefix length
    pub acked: usize,
    /// is snapshot + journal replay valid for this shard right now?
    /// `false` while checkpointing is off, after a journal-capacity
    /// overflow (until the next completed checkpoint re-arms it), and
    /// after a failed restore consumed the journal
    pub armed: bool,
}

impl Journal {
    /// Append one entry, accounting its event count.
    pub fn push(&mut self, entry: JournalEntry) {
        if let JournalEntry::Batch { events, .. } = &entry {
            self.events += events.len();
        }
        self.entries.push(entry);
    }

    /// Forget everything (new snapshot acked, or degrade-to-lossy).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.events = 0;
        self.acked = 0;
    }
}

/// What a `Request::Restore` did: the restored counters the coordinator
/// needs for its mirrors, plus the replay accounting.
#[derive(Debug, Default)]
pub(super) struct RestoreOutcome {
    /// live PMs after restore + replay (the recovered population)
    pub pms: usize,
    /// `pms_created` after restore + replay
    pub created: u64,
    /// `completions_total` after restore + replay
    pub completed: u64,
    /// open windows after restore + replay
    pub wins_open: usize,
    /// events replayed from the journal (all `Batch` entries)
    pub replayed_events: u64,
    /// PMs dropped by replaying *unacked* shed directives — decided
    /// before the crash but never applied/booked, so the coordinator
    /// books them now, exactly once, as voluntary shedding
    pub replayed_drop_pms: u64,
    /// virtual processing cost of the replay (charged to the clock)
    pub replay_cost_ns: f64,
    /// completions of unacked journal entries, global query indices —
    /// the ones the dead worker never delivered; the coordinator merges
    /// them into the next dispatch
    pub completions: Vec<ComplexEvent>,
}
