//! The model-engine interface: per-bin Markov tables for a batch of
//! patterns, from composed per-bin chains `(T_bs, r_bs)`.

use crate::linalg::markov::MarkovTables;
use crate::linalg::Mat;

/// Tables for a batch of patterns (one [`MarkovTables`] per pattern).
pub type BatchTables = Vec<MarkovTables>;

/// Something that can run the L2 recurrence.
pub trait ModelEngine {
    /// Compute `nbins` rows of completion/remaining-time tables for each
    /// pattern `(t[i], r[i])`.  Matrices may have different sizes.
    fn build_tables(
        &mut self,
        chains: &[(Mat, Vec<f64>)],
        nbins: usize,
    ) -> crate::Result<BatchTables>;

    /// Engine name for logs/EXPERIMENTS.md.
    fn name(&self) -> &'static str;
}

/// Pick the best available engine: the PJRT/AOT path when artifacts are
/// present and usable (and the crate is built with the `xla` feature),
/// otherwise the pure-rust fallback.
#[cfg(feature = "xla")]
pub fn auto_engine() -> Box<dyn ModelEngine> {
    let dir = super::ArtifactManifest::default_dir();
    match super::PjrtEngine::load(&dir) {
        Ok(e) => {
            log::info!("model engine: PJRT artifacts from {}", dir.display());
            Box::new(e)
        }
        Err(err) => {
            log::warn!("PJRT engine unavailable ({err:#}); using rust fallback");
            Box::new(super::FallbackEngine)
        }
    }
}

/// Without the `xla` feature the pure-rust fallback is the only engine.
#[cfg(not(feature = "xla"))]
pub fn auto_engine() -> Box<dyn ModelEngine> {
    Box::new(super::FallbackEngine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_engine_always_returns_something() {
        // in a checkout without artifacts this must still work
        let mut e = auto_engine();
        let t = Mat::from_rows(2, 2, &[0.5, 0.5, 0.0, 1.0]);
        let out = e.build_tables(&[(t, vec![1.0, 0.0])], 4).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].completion.len(), 4);
    }
}
