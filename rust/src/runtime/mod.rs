//! Runtime: the model-engine execution path for the L2/L1 utility
//! computation, plus the sharded multi-worker operator runtime.
//!
//! * [`artifacts`] — manifest parsing, shape-variant selection, and the
//!   state-permuting pad/unpad that makes any `(B, m)` problem fit a
//!   compiled `(B*, M, N)` artifact exactly (absorbing-identity padding),
//! * `pjrt` — the PJRT CPU client wrapper (load HLO text once, compile
//!   once per variant, execute per model build); needs the `xla`
//!   bindings, so it only compiles with the `xla` cargo feature,
//! * [`fallback`] — the pure-rust twin of the L2 graph (tests,
//!   differential validation, artifact-less operation),
//! * [`engine`] — the [`engine::ModelEngine`] trait + auto-selection,
//! * [`sharded`] — the sharded operator runtime: queries partitioned
//!   across worker threads, batched event dispatch over bounded
//!   channels, deterministic completion merging, and globally-ordered
//!   PM shedding (paper Alg. 2 semantics preserved across shards).

pub mod artifacts;
pub mod engine;
pub mod fallback;
#[cfg(feature = "xla")]
pub mod pjrt;
pub mod sharded;

pub use artifacts::{ArtifactManifest, Variant};
pub use engine::{auto_engine, BatchTables, ModelEngine};
pub use fallback::FallbackEngine;
#[cfg(feature = "xla")]
pub use pjrt::PjrtEngine;
pub use sharded::{
    FaultKind, FaultPlan, FaultSpec, RecoveryConfig, ShardFailure, ShardPlan, ShardSnapshot,
    ShardedOperator,
};
