//! Model-engine runtime: executes the AOT-compiled L2/L1 utility
//! computation from the rust request path.
//!
//! * [`artifacts`] — manifest parsing, shape-variant selection, and the
//!   state-permuting pad/unpad that makes any `(B, m)` problem fit a
//!   compiled `(B*, M, N)` artifact exactly (absorbing-identity padding),
//! * [`pjrt`] — the PJRT CPU client wrapper: load HLO text once, compile
//!   once per variant, execute per model build,
//! * [`fallback`] — the pure-rust twin of the L2 graph (tests,
//!   differential validation, artifact-less operation),
//! * [`engine`] — the [`engine::ModelEngine`] trait + auto-selection.

pub mod artifacts;
pub mod engine;
pub mod fallback;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, Variant};
pub use engine::{auto_engine, BatchTables, ModelEngine};
pub use fallback::FallbackEngine;
pub use pjrt::PjrtEngine;
