//! Windows: the stream partitions PMs live in (paper §II-A).
//!
//! Windows open by predicate (`OnMatch`) or by slide (`EveryK`), and
//! close by count or source time.  Each window owns its PMs; closing a
//! window retires all of them (they can no longer complete).

pub mod manager;

pub use manager::{
    ClaimSet, Expired, QueryWindows, StateCounts, Window, CLAIM_SPILL_THRESHOLD,
};
