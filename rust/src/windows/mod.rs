//! Windows: the stream partitions PMs live in (paper §II-A).
//!
//! Windows open by predicate (`OnMatch`) or by slide (`EveryK`), and
//! close by count or source time.  Each window owns its PMs; closing a
//! window retires all of them (they can no longer complete).

pub mod manager;

pub use manager::{claim_sorted, has_claim_sorted, Expired, QueryWindows, StateCounts, Window};
