//! Per-query window bookkeeping, including the *cell index*: every
//! window incrementally tracks how many of its PMs sit at each NFA
//! state.  Because a PM's utility is `table[state][bin(R_w)]` and `R_w`
//! is a per-window quantity, all PMs of one `(window, state)` cell share
//! one utility — the shedder ranks cells, not PMs, which is what makes
//! the shed path O(cells) instead of O(n_pm).

use std::collections::{BTreeSet, VecDeque};

use crate::events::Event;
use crate::nfa::{CompiledQuery, PartialMatch};
use crate::query::{OpenPolicy, WindowSpec};

/// Claim-set size at which [`ClaimSet`] migrates from the sorted-`Vec`
/// representation to a `BTreeSet`.  Below it, binary-search membership
/// plus an O(k) shifting insert into one contiguous allocation beats
/// the tree on locality; above it, the shifts dominate and the tree's
/// O(log k) node insert wins.  64 keys ≈ one 512-byte memmove worst
/// case — roughly where the two curves cross on the built-in
/// workloads' key widths.
pub const CLAIM_SPILL_THRESHOLD: usize = 64;

/// Key-bit values already claimed by an advanced seed of a multi-seed
/// window.  Small sets (the overwhelmingly common case — a window
/// claims one key per correlation group) live in a sorted `Vec`;
/// past [`CLAIM_SPILL_THRESHOLD`] keys the set spills to a `BTreeSet`
/// so inserts stop paying O(k) element shifts.
#[derive(Debug, Clone, PartialEq)]
pub enum ClaimSet {
    /// sorted ascending; membership is a binary search
    Sorted(Vec<u64>),
    /// spilled representation for claim-heavy windows
    Tree(BTreeSet<u64>),
}

impl Default for ClaimSet {
    fn default() -> Self {
        ClaimSet::Sorted(Vec::new())
    }
}

impl ClaimSet {
    /// Is `key` claimed?  O(log k) in both representations.
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        match self {
            ClaimSet::Sorted(v) => v.binary_search(&key).is_ok(),
            ClaimSet::Tree(t) => t.contains(&key),
        }
    }

    /// Claim `key` (idempotent), spilling to the tree representation
    /// once the sorted vector reaches [`CLAIM_SPILL_THRESHOLD`].
    pub fn insert(&mut self, key: u64) {
        match self {
            ClaimSet::Sorted(v) => match v.binary_search(&key) {
                Ok(_) => {}
                Err(pos) => {
                    if v.len() >= CLAIM_SPILL_THRESHOLD {
                        let mut t: BTreeSet<u64> = v.iter().copied().collect();
                        t.insert(key);
                        *self = ClaimSet::Tree(t);
                    } else {
                        v.insert(pos, key);
                    }
                }
            },
            ClaimSet::Tree(t) => {
                t.insert(key);
            }
        }
    }

    /// Number of claimed keys.
    pub fn len(&self) -> usize {
        match self {
            ClaimSet::Sorted(v) => v.len(),
            ClaimSet::Tree(t) => t.len(),
        }
    }

    /// No keys claimed?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Has the set spilled to the tree representation?
    pub fn is_spilled(&self) -> bool {
        matches!(self, ClaimSet::Tree(_))
    }

    /// Drop every claim.  The sorted representation keeps its buffer
    /// (window recycling stays allocation-free); a spilled set reverts
    /// to (an empty) sorted form, since the recycled window starts its
    /// life small again.
    pub fn clear(&mut self) {
        match self {
            ClaimSet::Sorted(v) => v.clear(),
            ClaimSet::Tree(_) => *self = ClaimSet::default(),
        }
    }

    /// The claimed keys in ascending order (test/debug helper).
    pub fn to_sorted_vec(&self) -> Vec<u64> {
        match self {
            ClaimSet::Sorted(v) => v.clone(),
            ClaimSet::Tree(t) => t.iter().copied().collect(),
        }
    }

    /// Become a copy of `other`, reusing this set's buffer when both
    /// sides are in the compact representation (snapshot recycling).
    pub fn assign_from(&mut self, other: &ClaimSet) {
        match (&mut *self, other) {
            (ClaimSet::Sorted(dst), ClaimSet::Sorted(src)) => dst.clone_from(src),
            (dst, src) => *dst = src.clone(),
        }
    }
}

/// Incrementally-maintained per-state PM counts of one window — the
/// shedder's cell index.  Entries beyond the stored length are zero, so
/// the vector only grows to the highest state the window has actually
/// seen (lazily, without knowing the query's state count up front).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct StateCounts {
    counts: Vec<u32>,
}

impl StateCounts {
    /// PMs at state `s`.
    #[inline]
    pub fn get(&self, s: u32) -> u32 {
        self.counts.get(s as usize).copied().unwrap_or(0)
    }

    /// One more PM at state `s`.
    #[inline]
    pub fn inc(&mut self, s: u32) {
        let s = s as usize;
        if self.counts.len() <= s {
            self.counts.resize(s + 1, 0);
        }
        self.counts[s] += 1;
    }

    /// One fewer PM at state `s`.
    #[inline]
    pub fn dec(&mut self, s: u32) {
        debug_assert!(self.get(s) > 0, "cell index underflow at state {s}");
        self.counts[s as usize] -= 1;
    }

    /// A PM moved `from → to`.
    #[inline]
    pub fn advance(&mut self, from: u32, to: u32) {
        self.dec(from);
        self.inc(to);
    }

    /// Forget every count, keeping the buffer for reuse.
    #[inline]
    pub fn clear(&mut self) {
        self.counts.clear();
    }

    /// Non-empty `(state, count)` cells, ascending by state.
    pub fn iter_nonzero(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, &c)| (s as u32, c))
    }

    /// Become a copy of `other`, reusing this index's buffer
    /// (snapshot recycling).
    #[inline]
    pub fn assign_from(&mut self, other: &StateCounts) {
        self.counts.clone_from(&other.counts);
    }

    /// Does the index agree with a direct recount of `pms`?  (Test and
    /// debug-assert helper — the hot path never recounts.)
    pub fn matches(&self, pms: &[PartialMatch]) -> bool {
        let top = pms.iter().map(|pm| pm.state as usize + 1).max().unwrap_or(0);
        let mut direct = vec![0u32; top.max(self.counts.len())];
        for pm in pms {
            direct[pm.state as usize] += 1;
        }
        direct
            .iter()
            .enumerate()
            .all(|(s, &c)| self.get(s as u32) == c)
    }
}

/// Windows (and their PM counts) closed by one
/// [`QueryWindows::expire`] pass.  Returning counts instead of the
/// window objects keeps the per-event no-expiry fast path
/// allocation-free.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Expired {
    /// windows closed
    pub windows: usize,
    /// PMs retired with them
    pub pms: usize,
}

/// One open window of one query.
#[derive(Debug, Clone)]
pub struct Window {
    /// Sequence number of the opening event.
    pub open_seq: u64,
    /// Timestamp of the opening event (ms).
    pub open_ts: u64,
    /// Live partial matches.
    pub pms: Vec<PartialMatch>,
    /// Key-bit values already claimed by an advanced seed (multi-seed
    /// windows only): prevents two PMs for the same correlation key.
    /// A [`ClaimSet`] — sorted vector with binary-search membership,
    /// spilling to a `BTreeSet` past [`CLAIM_SPILL_THRESHOLD`] keys.
    pub claimed: ClaimSet,
    /// Per-state PM counts (the shedder's cell index).  Every code path
    /// that adds, removes or advances a PM must keep this in step;
    /// [`Window::retain_pms`] does so automatically for removals.
    pub counts: StateCounts,
}

impl Window {
    /// Remaining events before this window closes, given the current
    /// position in the stream.  Count windows are exact; time windows
    /// are estimated with `events_per_ms` (paper: `R_w` is "the expected
    /// number of events left in the window").
    pub fn remaining_events(
        &self,
        spec: WindowSpec,
        cur_seq: u64,
        cur_ts: u64,
        events_per_ms: f64,
    ) -> u64 {
        match spec {
            WindowSpec::Count(ws) => (self.open_seq + ws).saturating_sub(cur_seq),
            WindowSpec::TimeMs(ms) => {
                let left_ms = (self.open_ts + ms).saturating_sub(cur_ts);
                (left_ms as f64 * events_per_ms).ceil() as u64
            }
        }
    }

    /// Is `key` already claimed by an advanced seed?  O(log k).
    #[inline]
    pub fn has_claim(&self, key: u64) -> bool {
        self.claimed.contains(key)
    }

    /// Claim `key` (idempotent).
    #[inline]
    pub fn claim(&mut self, key: u64) {
        self.claimed.insert(key);
    }

    /// Forget all state but keep every buffer's capacity, readying the
    /// shell for reuse by [`QueryWindows::open`].
    fn recycle(&mut self) {
        self.pms.clear();
        self.claimed.clear();
        self.counts.clear();
    }

    /// Become a copy of `other`, reusing every buffer this window
    /// already owns (the checkpoint plane's snapshot recycling).
    pub fn assign_from(&mut self, other: &Window) {
        self.open_seq = other.open_seq;
        self.open_ts = other.open_ts;
        self.pms.clone_from(&other.pms);
        self.claimed.assign_from(&other.claimed);
        self.counts.assign_from(&other.counts);
    }

    /// Remove the PMs rejected by `keep`, maintaining the cell index.
    /// Preserves PM order and returns how many were removed.
    pub fn retain_pms(&mut self, mut keep: impl FnMut(&PartialMatch) -> bool) -> usize {
        let Window { pms, counts, .. } = self;
        let before = pms.len();
        pms.retain(|pm| {
            if keep(pm) {
                true
            } else {
                counts.dec(pm.state);
                false
            }
        });
        before - pms.len()
    }
}

/// Retired window shells kept for reuse beyond this count are dropped
/// instead: bounds the recycling pool's memory under expiry bursts
/// while keeping the steady open→expire→open cycle allocation-free.
const GRAVEYARD_CAP: usize = 64;

/// All open windows of one query, oldest first, plus a bounded free
/// list of expired window shells whose buffers [`QueryWindows::open`]
/// reuses — steady-state window churn touches no allocator.
#[derive(Debug, Default, Clone)]
pub struct QueryWindows {
    /// open windows, ordered by `open_seq`
    pub windows: VecDeque<Window>,
    /// recycled shells (cleared, capacity retained)
    graveyard: Vec<Window>,
}

impl QueryWindows {
    /// Should a new window open on this event?
    pub fn should_open(&self, cq: &CompiledQuery, e: &Event) -> bool {
        match &cq.query.open {
            OpenPolicy::OnMatch(spec) => {
                // predicate evaluated against a keyless dummy PM
                let dummy = PartialMatch::seed(u64::MAX, e.seq);
                crate::nfa::machine::matches_spec(spec, e, &dummy)
            }
            OpenPolicy::EveryK(k) => e.seq % k == 0,
        }
    }

    /// Open a window seeded with one initial-state PM, reusing a
    /// recycled shell when one is available.
    pub fn open(&mut self, e: &Event, next_pm_id: &mut u64) -> &mut Window {
        let mut w = self.graveyard.pop().unwrap_or_else(|| Window {
            open_seq: 0,
            open_ts: 0,
            pms: Vec::with_capacity(4),
            claimed: ClaimSet::default(),
            counts: StateCounts::default(),
        });
        w.open_seq = e.seq;
        w.open_ts = e.ts_ms;
        w.pms.push(PartialMatch::seed(*next_pm_id, e.seq));
        w.counts.inc(0);
        *next_pm_id += 1;
        self.windows.push_back(w);
        self.windows.back_mut().expect("just pushed")
    }

    /// Close all windows that have expired at the given stream position
    /// and return how many windows / PMs were retired.  Windows are
    /// FIFO by `open_seq`, so expiry pops from the front; the common
    /// nothing-expired case touches no memory beyond the front peek.
    pub fn expire(&mut self, spec: WindowSpec, cur_seq: u64, cur_ts: u64) -> Expired {
        let mut out = Expired::default();
        while let Some(front) = self.windows.front() {
            let dead = match spec {
                WindowSpec::Count(ws) => cur_seq >= front.open_seq + ws,
                WindowSpec::TimeMs(ms) => cur_ts > front.open_ts + ms,
            };
            if dead {
                let mut w = self.windows.pop_front().expect("front checked");
                out.windows += 1;
                out.pms += w.pms.len();
                if self.graveyard.len() < GRAVEYARD_CAP {
                    w.recycle();
                    self.graveyard.push(w);
                }
            } else {
                break;
            }
        }
        out
    }

    /// Total PMs across all open windows.
    pub fn pm_count(&self) -> usize {
        self.windows.iter().map(|w| w.pms.len()).sum()
    }

    /// Become a copy of `other`'s open windows, recycling this query's
    /// window shells (surplus shells retire to the graveyard, deficits
    /// draw from it).  The graveyard itself is a local buffer pool and
    /// is never copied, so steady-state snapshots of a warm window set
    /// touch no allocator — the PR 4 discipline extended to the
    /// checkpoint plane.
    pub fn assign_from(&mut self, other: &QueryWindows) {
        while self.windows.len() > other.windows.len() {
            let mut w = self.windows.pop_back().expect("len checked");
            if self.graveyard.len() < GRAVEYARD_CAP {
                w.recycle();
                self.graveyard.push(w);
            }
        }
        for (dst, src) in self.windows.iter_mut().zip(other.windows.iter()) {
            dst.assign_from(src);
        }
        while self.windows.len() < other.windows.len() {
            let src = &other.windows[self.windows.len()];
            let mut w = self.graveyard.pop().unwrap_or_else(|| Window {
                open_seq: 0,
                open_ts: 0,
                pms: Vec::new(),
                claimed: ClaimSet::default(),
                counts: StateCounts::default(),
            });
            w.assign_from(src);
            self.windows.push_back(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::{q1, q4};

    fn quote(seq: u64, sym: f64) -> Event {
        Event::new(seq, seq * 2, 0, &[sym, 100.0, 1.0])
    }

    #[test]
    fn opens_on_leader_only() {
        let cq = CompiledQuery::compile(q1(100).queries.remove(0));
        let qw = QueryWindows::default();
        assert!(qw.should_open(&cq, &quote(0, 0.0)));
        assert!(qw.should_open(&cq, &quote(1, 3.0)));
        assert!(!qw.should_open(&cq, &quote(2, 7.0))); // not a leader
    }

    #[test]
    fn opens_every_k() {
        let cq = CompiledQuery::compile(q4(3, 1000, 500).queries.remove(0));
        let qw = QueryWindows::default();
        let bus = |seq| Event::new(seq, seq, 0, &[1.0, 2.0, 0.0, 0.0]);
        assert!(qw.should_open(&cq, &bus(0)));
        assert!(!qw.should_open(&cq, &bus(499)));
        assert!(qw.should_open(&cq, &bus(500)));
    }

    #[test]
    fn count_expiry_is_exact() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(10, 0.0), &mut id);
        // window [10, 10+50): last contained seq is 59
        assert_eq!(qw.expire(WindowSpec::Count(50), 59, 0), Expired::default());
        let closed = qw.expire(WindowSpec::Count(50), 60, 0);
        assert_eq!((closed.windows, closed.pms), (1, 1));
        assert!(qw.windows.is_empty());
    }

    #[test]
    fn time_expiry() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id); // open_ts = 0
        assert_eq!(
            qw.expire(WindowSpec::TimeMs(100), 5, 100),
            Expired::default()
        );
        let closed = qw.expire(WindowSpec::TimeMs(100), 6, 101);
        assert_eq!((closed.windows, closed.pms), (1, 1));
    }

    #[test]
    fn remaining_events_count_and_time() {
        let w = Window {
            open_seq: 100,
            open_ts: 1000,
            pms: Vec::new(),
            claimed: ClaimSet::default(),
            counts: StateCounts::default(),
        };
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 120, 0, 0.0),
            30
        );
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 200, 0, 0.0),
            0
        );
        // 500 ms left at 2 events/ms -> 1000 events
        assert_eq!(
            w.remaining_events(WindowSpec::TimeMs(1000), 0, 1500, 2.0),
            1000
        );
    }

    #[test]
    fn pm_count_sums_windows() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        qw.open(&quote(5, 1.0), &mut id);
        assert_eq!(qw.pm_count(), 2);
    }

    #[test]
    fn state_counts_track_inc_dec_advance() {
        let mut c = StateCounts::default();
        assert_eq!(c.get(3), 0);
        c.inc(0);
        c.inc(0);
        c.inc(2);
        assert_eq!(c.get(0), 2);
        assert_eq!(c.get(1), 0);
        assert_eq!(c.get(2), 1);
        c.advance(0, 1);
        assert_eq!(c.get(0), 1);
        assert_eq!(c.get(1), 1);
        c.dec(2);
        assert_eq!(c.get(2), 0);
        let cells: Vec<(u32, u32)> = c.iter_nonzero().collect();
        assert_eq!(cells, vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn retain_pms_keeps_cell_index_in_step() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        let w = &mut qw.windows[0];
        for s in [0u32, 1, 1, 2] {
            let mut pm = PartialMatch::seed(id, 0);
            id += 1;
            pm.state = s;
            w.pms.push(pm);
            w.counts.inc(s);
        }
        assert!(w.counts.matches(&w.pms));
        let removed = w.retain_pms(|pm| pm.state != 1);
        assert_eq!(removed, 2);
        assert!(w.counts.matches(&w.pms));
        assert_eq!(w.counts.get(1), 0);
        assert_eq!(w.counts.get(0), 2); // the seed + the pushed state-0 PM
    }

    #[test]
    fn claims_stay_sorted_and_binary_search() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        let w = &mut qw.windows[0];
        for key in [9u64, 3, 7, 3, 1] {
            w.claim(key);
        }
        assert_eq!(w.claimed.to_sorted_vec(), vec![1, 3, 7, 9]);
        assert!(w.has_claim(7));
        assert!(!w.has_claim(2));
        assert!(!w.claimed.is_spilled());
    }

    #[test]
    fn claim_set_spills_to_tree_and_back_on_clear() {
        // both regimes of the ClaimSet: sorted-Vec below the threshold,
        // BTreeSet above it, identical membership semantics throughout
        let mut c = ClaimSet::default();
        for key in 0..CLAIM_SPILL_THRESHOLD as u64 {
            c.insert(key * 2); // even keys
            c.insert(key * 2); // idempotent
        }
        assert!(!c.is_spilled());
        assert_eq!(c.len(), CLAIM_SPILL_THRESHOLD);
        // one more unique key crosses the threshold
        c.insert(1);
        assert!(c.is_spilled());
        assert_eq!(c.len(), CLAIM_SPILL_THRESHOLD + 1);
        c.insert(1); // idempotent in the tree too
        assert_eq!(c.len(), CLAIM_SPILL_THRESHOLD + 1);
        for key in 0..CLAIM_SPILL_THRESHOLD as u64 {
            assert!(c.contains(key * 2), "key {} lost in spill", key * 2);
        }
        assert!(c.contains(1));
        assert!(!c.contains(3));
        // membership order is preserved by the debug view
        let v = c.to_sorted_vec();
        assert!(v.windows(2).all(|p| p[0] < p[1]));
        // clear reverts a spilled set to the compact representation
        c.clear();
        assert!(c.is_empty());
        assert!(!c.is_spilled());
        assert!(!c.contains(2));
    }

    #[test]
    fn assign_from_round_trips_windows_claims_and_counts() {
        let mut src = QueryWindows::default();
        let mut id = 0;
        src.open(&quote(0, 0.0), &mut id);
        src.open(&quote(5, 1.0), &mut id);
        src.windows[0].claim(42);
        let mut pm = PartialMatch::seed(id, 5);
        pm.state = 2;
        src.windows[1].counts.inc(2);
        src.windows[1].pms.push(pm);

        // dst starts with MORE windows than src: surplus shells retire
        let mut dst = QueryWindows::default();
        for s in 0..3 {
            dst.open(&quote(s * 10, 0.0), &mut id);
        }
        dst.assign_from(&src);
        assert_eq!(dst.windows.len(), 2);
        for (d, s) in dst.windows.iter().zip(src.windows.iter()) {
            assert_eq!(d.open_seq, s.open_seq);
            assert_eq!(d.open_ts, s.open_ts);
            assert_eq!(d.pms, s.pms);
            assert_eq!(d.claimed.to_sorted_vec(), s.claimed.to_sorted_vec());
            assert!(d.counts.matches(&d.pms));
        }

        // and a deficit grows the window set without losing any state
        let mut empty = QueryWindows::default();
        empty.assign_from(&src);
        assert_eq!(empty.windows.len(), 2);
        assert!(empty.windows[0].has_claim(42));
        assert_eq!(empty.windows[1].counts.get(2), 1);
        assert_eq!(empty.pm_count(), src.pm_count());
    }

    #[test]
    fn expired_windows_are_recycled_by_open() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        qw.windows[0].claim(42);
        let closed = qw.expire(WindowSpec::Count(10), 100, 0);
        assert_eq!((closed.windows, closed.pms), (1, 1));
        assert!(qw.windows.is_empty());
        // the recycled shell must come back empty
        let w = qw.open(&quote(200, 0.0), &mut id);
        assert_eq!(w.open_seq, 200);
        assert_eq!(w.pms.len(), 1, "exactly the fresh seed");
        assert_eq!(w.pms[0].state, 0);
        assert!(!w.has_claim(42), "stale claims must not survive recycling");
        assert_eq!(w.counts.get(0), 1);
        assert!(w.counts.matches(&w.pms));
    }
}
