//! Per-query window bookkeeping.

use std::collections::VecDeque;

use crate::events::Event;
use crate::nfa::{CompiledQuery, PartialMatch};
use crate::query::{OpenPolicy, WindowSpec};

/// One open window of one query.
#[derive(Debug, Clone)]
pub struct Window {
    /// Sequence number of the opening event.
    pub open_seq: u64,
    /// Timestamp of the opening event (ms).
    pub open_ts: u64,
    /// Live partial matches.
    pub pms: Vec<PartialMatch>,
    /// Key-bit values already claimed by an advanced seed (multi-seed
    /// windows only): prevents two PMs for the same correlation key.
    pub claimed: Vec<u64>,
}

impl Window {
    /// Remaining events before this window closes, given the current
    /// position in the stream.  Count windows are exact; time windows
    /// are estimated with `events_per_ms` (paper: `R_w` is "the expected
    /// number of events left in the window").
    pub fn remaining_events(
        &self,
        spec: WindowSpec,
        cur_seq: u64,
        cur_ts: u64,
        events_per_ms: f64,
    ) -> u64 {
        match spec {
            WindowSpec::Count(ws) => (self.open_seq + ws).saturating_sub(cur_seq),
            WindowSpec::TimeMs(ms) => {
                let left_ms = (self.open_ts + ms).saturating_sub(cur_ts);
                (left_ms as f64 * events_per_ms).ceil() as u64
            }
        }
    }
}

/// All open windows of one query, oldest first.
#[derive(Debug, Default, Clone)]
pub struct QueryWindows {
    /// open windows, ordered by `open_seq`
    pub windows: VecDeque<Window>,
}

impl QueryWindows {
    /// Should a new window open on this event?
    pub fn should_open(&self, cq: &CompiledQuery, e: &Event) -> bool {
        match &cq.query.open {
            OpenPolicy::OnMatch(spec) => {
                // predicate evaluated against a keyless dummy PM
                let dummy = PartialMatch::seed(u64::MAX, e.seq);
                crate::nfa::machine::matches_spec(spec, e, &dummy)
            }
            OpenPolicy::EveryK(k) => e.seq % k == 0,
        }
    }

    /// Open a window seeded with one initial-state PM.
    pub fn open(&mut self, e: &Event, next_pm_id: &mut u64) -> &mut Window {
        let mut w = Window {
            open_seq: e.seq,
            open_ts: e.ts_ms,
            pms: Vec::with_capacity(4),
            claimed: Vec::new(),
        };
        w.pms.push(PartialMatch::seed(*next_pm_id, e.seq));
        *next_pm_id += 1;
        self.windows.push_back(w);
        self.windows.back_mut().expect("just pushed")
    }

    /// Close (and return) all windows that have expired at the given
    /// stream position.  Windows are FIFO by `open_seq`, so expiry pops
    /// from the front.
    pub fn expire(&mut self, spec: WindowSpec, cur_seq: u64, cur_ts: u64) -> Vec<Window> {
        let mut closed = Vec::new();
        while let Some(front) = self.windows.front() {
            let dead = match spec {
                WindowSpec::Count(ws) => cur_seq >= front.open_seq + ws,
                WindowSpec::TimeMs(ms) => cur_ts > front.open_ts + ms,
            };
            if dead {
                closed.push(self.windows.pop_front().expect("front checked"));
            } else {
                break;
            }
        }
        closed
    }

    /// Total PMs across all open windows.
    pub fn pm_count(&self) -> usize {
        self.windows.iter().map(|w| w.pms.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::{q1, q4};

    fn quote(seq: u64, sym: f64) -> Event {
        Event::new(seq, seq * 2, 0, &[sym, 100.0, 1.0])
    }

    #[test]
    fn opens_on_leader_only() {
        let cq = CompiledQuery::compile(q1(100).queries.remove(0));
        let qw = QueryWindows::default();
        assert!(qw.should_open(&cq, &quote(0, 0.0)));
        assert!(qw.should_open(&cq, &quote(1, 3.0)));
        assert!(!qw.should_open(&cq, &quote(2, 7.0))); // not a leader
    }

    #[test]
    fn opens_every_k() {
        let cq = CompiledQuery::compile(q4(3, 1000, 500).queries.remove(0));
        let qw = QueryWindows::default();
        let bus = |seq| Event::new(seq, seq, 0, &[1.0, 2.0, 0.0, 0.0]);
        assert!(qw.should_open(&cq, &bus(0)));
        assert!(!qw.should_open(&cq, &bus(499)));
        assert!(qw.should_open(&cq, &bus(500)));
    }

    #[test]
    fn count_expiry_is_exact() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(10, 0.0), &mut id);
        // window [10, 10+50): last contained seq is 59
        assert!(qw.expire(WindowSpec::Count(50), 59, 0).is_empty());
        let closed = qw.expire(WindowSpec::Count(50), 60, 0);
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].open_seq, 10);
        assert!(qw.windows.is_empty());
    }

    #[test]
    fn time_expiry() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id); // open_ts = 0
        assert!(qw.expire(WindowSpec::TimeMs(100), 5, 100).is_empty());
        assert_eq!(qw.expire(WindowSpec::TimeMs(100), 6, 101).len(), 1);
    }

    #[test]
    fn remaining_events_count_and_time() {
        let w = Window {
            open_seq: 100,
            open_ts: 1000,
            pms: Vec::new(),
            claimed: Vec::new(),
        };
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 120, 0, 0.0),
            30
        );
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 200, 0, 0.0),
            0
        );
        // 500 ms left at 2 events/ms -> 1000 events
        assert_eq!(
            w.remaining_events(WindowSpec::TimeMs(1000), 0, 1500, 2.0),
            1000
        );
    }

    #[test]
    fn pm_count_sums_windows() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        qw.open(&quote(5, 1.0), &mut id);
        assert_eq!(qw.pm_count(), 2);
    }
}
