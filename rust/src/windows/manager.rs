//! Per-query window bookkeeping, including the *cell index*: every
//! window incrementally tracks how many of its PMs sit at each NFA
//! state.  Because a PM's utility is `table[state][bin(R_w)]` and `R_w`
//! is a per-window quantity, all PMs of one `(window, state)` cell share
//! one utility — the shedder ranks cells, not PMs, which is what makes
//! the shed path O(cells) instead of O(n_pm).

use std::collections::VecDeque;

use crate::events::Event;
use crate::nfa::{CompiledQuery, PartialMatch};
use crate::query::{OpenPolicy, WindowSpec};

/// Incrementally-maintained per-state PM counts of one window — the
/// shedder's cell index.  Entries beyond the stored length are zero, so
/// the vector only grows to the highest state the window has actually
/// seen (lazily, without knowing the query's state count up front).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct StateCounts {
    counts: Vec<u32>,
}

impl StateCounts {
    /// PMs at state `s`.
    #[inline]
    pub fn get(&self, s: u32) -> u32 {
        self.counts.get(s as usize).copied().unwrap_or(0)
    }

    /// One more PM at state `s`.
    #[inline]
    pub fn inc(&mut self, s: u32) {
        let s = s as usize;
        if self.counts.len() <= s {
            self.counts.resize(s + 1, 0);
        }
        self.counts[s] += 1;
    }

    /// One fewer PM at state `s`.
    #[inline]
    pub fn dec(&mut self, s: u32) {
        debug_assert!(self.get(s) > 0, "cell index underflow at state {s}");
        self.counts[s as usize] -= 1;
    }

    /// A PM moved `from → to`.
    #[inline]
    pub fn advance(&mut self, from: u32, to: u32) {
        self.dec(from);
        self.inc(to);
    }

    /// Non-empty `(state, count)` cells, ascending by state.
    pub fn iter_nonzero(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, &c)| (s as u32, c))
    }

    /// Does the index agree with a direct recount of `pms`?  (Test and
    /// debug-assert helper — the hot path never recounts.)
    pub fn matches(&self, pms: &[PartialMatch]) -> bool {
        let top = pms.iter().map(|pm| pm.state as usize + 1).max().unwrap_or(0);
        let mut direct = vec![0u32; top.max(self.counts.len())];
        for pm in pms {
            direct[pm.state as usize] += 1;
        }
        direct
            .iter()
            .enumerate()
            .all(|(s, &c)| self.get(s as u32) == c)
    }
}

/// Windows (and their PM counts) closed by one
/// [`QueryWindows::expire`] pass.  Returning counts instead of the
/// window objects keeps the per-event no-expiry fast path
/// allocation-free.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Expired {
    /// windows closed
    pub windows: usize,
    /// PMs retired with them
    pub pms: usize,
}

/// One open window of one query.
#[derive(Debug, Clone)]
pub struct Window {
    /// Sequence number of the opening event.
    pub open_seq: u64,
    /// Timestamp of the opening event (ms).
    pub open_ts: u64,
    /// Live partial matches.
    pub pms: Vec<PartialMatch>,
    /// Key-bit values already claimed by an advanced seed (multi-seed
    /// windows only): prevents two PMs for the same correlation key.
    /// Kept **sorted** so membership checks binary-search; mutate only
    /// through [`Window::claim`] / [`Window::has_claim`] (or keep the
    /// ordering by hand when borrowing fields directly).
    pub claimed: Vec<u64>,
    /// Per-state PM counts (the shedder's cell index).  Every code path
    /// that adds, removes or advances a PM must keep this in step;
    /// [`Window::retain_pms`] does so automatically for removals.
    pub counts: StateCounts,
}

impl Window {
    /// Remaining events before this window closes, given the current
    /// position in the stream.  Count windows are exact; time windows
    /// are estimated with `events_per_ms` (paper: `R_w` is "the expected
    /// number of events left in the window").
    pub fn remaining_events(
        &self,
        spec: WindowSpec,
        cur_seq: u64,
        cur_ts: u64,
        events_per_ms: f64,
    ) -> u64 {
        match spec {
            WindowSpec::Count(ws) => (self.open_seq + ws).saturating_sub(cur_seq),
            WindowSpec::TimeMs(ms) => {
                let left_ms = (self.open_ts + ms).saturating_sub(cur_ts);
                (left_ms as f64 * events_per_ms).ceil() as u64
            }
        }
    }

    /// Is `key` already claimed by an advanced seed?  O(log k).
    #[inline]
    pub fn has_claim(&self, key: u64) -> bool {
        has_claim_sorted(&self.claimed, key)
    }

    /// Claim `key`, keeping [`Window::claimed`] sorted (idempotent).
    #[inline]
    pub fn claim(&mut self, key: u64) {
        claim_sorted(&mut self.claimed, key);
    }

    /// Remove the PMs rejected by `keep`, maintaining the cell index.
    /// Preserves PM order and returns how many were removed.
    pub fn retain_pms(&mut self, mut keep: impl FnMut(&PartialMatch) -> bool) -> usize {
        let Window { pms, counts, .. } = self;
        let before = pms.len();
        pms.retain(|pm| {
            if keep(pm) {
                true
            } else {
                counts.dec(pm.state);
                false
            }
        });
        before - pms.len()
    }
}

/// Membership test against a sorted claim list — the free-function
/// form of [`Window::has_claim`], usable under split field borrows
/// (the operator's match loop holds `pms` and `claimed` separately).
#[inline]
pub fn has_claim_sorted(claimed: &[u64], key: u64) -> bool {
    claimed.binary_search(&key).is_ok()
}

/// Sorted idempotent insert into a claim list — the single home of the
/// "`Window::claimed` stays sorted" invariant; [`Window::claim`] and
/// the operator's match loop both delegate here.
#[inline]
pub fn claim_sorted(claimed: &mut Vec<u64>, key: u64) {
    if let Err(pos) = claimed.binary_search(&key) {
        claimed.insert(pos, key);
    }
}

/// All open windows of one query, oldest first.
#[derive(Debug, Default, Clone)]
pub struct QueryWindows {
    /// open windows, ordered by `open_seq`
    pub windows: VecDeque<Window>,
}

impl QueryWindows {
    /// Should a new window open on this event?
    pub fn should_open(&self, cq: &CompiledQuery, e: &Event) -> bool {
        match &cq.query.open {
            OpenPolicy::OnMatch(spec) => {
                // predicate evaluated against a keyless dummy PM
                let dummy = PartialMatch::seed(u64::MAX, e.seq);
                crate::nfa::machine::matches_spec(spec, e, &dummy)
            }
            OpenPolicy::EveryK(k) => e.seq % k == 0,
        }
    }

    /// Open a window seeded with one initial-state PM.
    pub fn open(&mut self, e: &Event, next_pm_id: &mut u64) -> &mut Window {
        let mut w = Window {
            open_seq: e.seq,
            open_ts: e.ts_ms,
            pms: Vec::with_capacity(4),
            claimed: Vec::new(),
            counts: StateCounts::default(),
        };
        w.pms.push(PartialMatch::seed(*next_pm_id, e.seq));
        w.counts.inc(0);
        *next_pm_id += 1;
        self.windows.push_back(w);
        self.windows.back_mut().expect("just pushed")
    }

    /// Close all windows that have expired at the given stream position
    /// and return how many windows / PMs were retired.  Windows are
    /// FIFO by `open_seq`, so expiry pops from the front; the common
    /// nothing-expired case touches no memory beyond the front peek.
    pub fn expire(&mut self, spec: WindowSpec, cur_seq: u64, cur_ts: u64) -> Expired {
        let mut out = Expired::default();
        while let Some(front) = self.windows.front() {
            let dead = match spec {
                WindowSpec::Count(ws) => cur_seq >= front.open_seq + ws,
                WindowSpec::TimeMs(ms) => cur_ts > front.open_ts + ms,
            };
            if dead {
                let w = self.windows.pop_front().expect("front checked");
                out.windows += 1;
                out.pms += w.pms.len();
            } else {
                break;
            }
        }
        out
    }

    /// Total PMs across all open windows.
    pub fn pm_count(&self) -> usize {
        self.windows.iter().map(|w| w.pms.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::{q1, q4};

    fn quote(seq: u64, sym: f64) -> Event {
        Event::new(seq, seq * 2, 0, &[sym, 100.0, 1.0])
    }

    #[test]
    fn opens_on_leader_only() {
        let cq = CompiledQuery::compile(q1(100).queries.remove(0));
        let qw = QueryWindows::default();
        assert!(qw.should_open(&cq, &quote(0, 0.0)));
        assert!(qw.should_open(&cq, &quote(1, 3.0)));
        assert!(!qw.should_open(&cq, &quote(2, 7.0))); // not a leader
    }

    #[test]
    fn opens_every_k() {
        let cq = CompiledQuery::compile(q4(3, 1000, 500).queries.remove(0));
        let qw = QueryWindows::default();
        let bus = |seq| Event::new(seq, seq, 0, &[1.0, 2.0, 0.0, 0.0]);
        assert!(qw.should_open(&cq, &bus(0)));
        assert!(!qw.should_open(&cq, &bus(499)));
        assert!(qw.should_open(&cq, &bus(500)));
    }

    #[test]
    fn count_expiry_is_exact() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(10, 0.0), &mut id);
        // window [10, 10+50): last contained seq is 59
        assert_eq!(qw.expire(WindowSpec::Count(50), 59, 0), Expired::default());
        let closed = qw.expire(WindowSpec::Count(50), 60, 0);
        assert_eq!((closed.windows, closed.pms), (1, 1));
        assert!(qw.windows.is_empty());
    }

    #[test]
    fn time_expiry() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id); // open_ts = 0
        assert_eq!(
            qw.expire(WindowSpec::TimeMs(100), 5, 100),
            Expired::default()
        );
        let closed = qw.expire(WindowSpec::TimeMs(100), 6, 101);
        assert_eq!((closed.windows, closed.pms), (1, 1));
    }

    #[test]
    fn remaining_events_count_and_time() {
        let w = Window {
            open_seq: 100,
            open_ts: 1000,
            pms: Vec::new(),
            claimed: Vec::new(),
            counts: StateCounts::default(),
        };
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 120, 0, 0.0),
            30
        );
        assert_eq!(
            w.remaining_events(WindowSpec::Count(50), 200, 0, 0.0),
            0
        );
        // 500 ms left at 2 events/ms -> 1000 events
        assert_eq!(
            w.remaining_events(WindowSpec::TimeMs(1000), 0, 1500, 2.0),
            1000
        );
    }

    #[test]
    fn pm_count_sums_windows() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        qw.open(&quote(5, 1.0), &mut id);
        assert_eq!(qw.pm_count(), 2);
    }

    #[test]
    fn state_counts_track_inc_dec_advance() {
        let mut c = StateCounts::default();
        assert_eq!(c.get(3), 0);
        c.inc(0);
        c.inc(0);
        c.inc(2);
        assert_eq!(c.get(0), 2);
        assert_eq!(c.get(1), 0);
        assert_eq!(c.get(2), 1);
        c.advance(0, 1);
        assert_eq!(c.get(0), 1);
        assert_eq!(c.get(1), 1);
        c.dec(2);
        assert_eq!(c.get(2), 0);
        let cells: Vec<(u32, u32)> = c.iter_nonzero().collect();
        assert_eq!(cells, vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn retain_pms_keeps_cell_index_in_step() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        let w = &mut qw.windows[0];
        for s in [0u32, 1, 1, 2] {
            let mut pm = PartialMatch::seed(id, 0);
            id += 1;
            pm.state = s;
            w.pms.push(pm);
            w.counts.inc(s);
        }
        assert!(w.counts.matches(&w.pms));
        let removed = w.retain_pms(|pm| pm.state != 1);
        assert_eq!(removed, 2);
        assert!(w.counts.matches(&w.pms));
        assert_eq!(w.counts.get(1), 0);
        assert_eq!(w.counts.get(0), 2); // the seed + the pushed state-0 PM
    }

    #[test]
    fn claims_stay_sorted_and_binary_search() {
        let mut qw = QueryWindows::default();
        let mut id = 0;
        qw.open(&quote(0, 0.0), &mut id);
        let w = &mut qw.windows[0];
        for key in [9u64, 3, 7, 3, 1] {
            w.claim(key);
        }
        assert_eq!(w.claimed, vec![1, 3, 7, 9]);
        assert!(w.has_claim(7));
        assert!(!w.has_claim(2));
    }
}
