//! The engine façade: a fluent [`PipelineBuilder`] producing a
//! [`Pipeline`] that owns the whole measurement machinery — operator
//! state (single-threaded or sharded), shedding strategy, overload
//! detector, virtual clock, latency accounting, and drift-triggered
//! model retraining (paper §III-D).
//!
//! ```no_run
//! use pspice::pipeline::Pipeline;
//! use pspice::query::builtin::q4;
//! use pspice::shedding::ShedderKind;
//!
//! let mut pipe = Pipeline::builder()
//!     .queries(q4(4, 2_000, 250).queries)
//!     .shedder(ShedderKind::PSpice)
//!     .latency_bound_ms(0.5)
//!     .shards(4)
//!     .batch(256)
//!     .build()
//!     .unwrap();
//! // … then pipe.prime(..), pipe.feed(..) or pipe.run_to_end()
//! ```
//!
//! Two consumption styles:
//!
//! * **Batch** — give the builder the measurement trace via
//!   [`PipelineBuilder::source`] and call [`Pipeline::run_to_end`];
//!   this is what [`crate::harness::run_experiment`] does.
//! * **Incremental** — call [`Pipeline::feed`] with event slices as
//!   they become available (embedding the engine in a host system);
//!   each call returns the complex events it detected.
//!
//! The single-threaded backend (`shards == 1`) dispatches batches of
//! one event, which reproduces the classic per-event operator loop
//! exactly; `shards > 1` dispatches `batch`-sized micro-batches to the
//! sharded runtime.  Either way there is exactly one measurement loop.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::events::Event;
use crate::ingest::{IngestQueue, OverflowPolicy, Source, SourcePoll};
use crate::metrics::{LatencyTracker, Throughput};
use crate::model::plane::{KeyUtilityTable, ModelController, ModelKind, TableSet};
use crate::model::UtilityTable;
use crate::operator::{BatchResult, ComplexEvent, Operator, OperatorState};
use crate::query::Query;
use crate::runtime::{FaultPlan, RecoveryConfig, ShardedOperator};
use crate::shedding::{
    MeasuredDetector, OverloadDetector, OverloadGauge, OverloadKind, ShedReport, Shedder,
    ShedderKind,
};
use crate::sim::{Clock, RateSource, SimClock};

/// The operator state behind a pipeline: the classic single-threaded
/// operator, or the sharded multi-worker runtime.
enum Backend {
    /// one operator, per-event dispatch
    Single(Operator),
    /// query-partitioned worker shards, micro-batch dispatch
    Sharded(ShardedOperator),
}

impl Backend {
    fn state(&mut self) -> &mut dyn OperatorState {
        match self {
            Backend::Single(op) => op,
            Backend::Sharded(sop) => sop,
        }
    }

    fn state_ref(&self) -> &dyn OperatorState {
        match self {
            Backend::Single(op) => op,
            Backend::Sharded(sop) => sop,
        }
    }
}

/// Fluent configuration for a [`Pipeline`].  Obtain via
/// [`Pipeline::builder`]; every setter returns `self`.
pub struct PipelineBuilder {
    queries: Vec<Query>,
    shedder: ShedderKind,
    custom: Option<Box<dyn Shedder>>,
    lb_ms: f64,
    shards: usize,
    batch: usize,
    seed: u64,
    key_slot: usize,
    detector: Option<OverloadDetector>,
    tables: Vec<UtilityTable>,
    cost_factors: Vec<f64>,
    arrivals: Option<RateSource>,
    source: Option<Vec<Event>>,
    retrain_every: u64,
    drift_threshold: f64,
    model_kind: ModelKind,
    latency_stride: u64,
    type_routing: bool,
    clock: Option<Box<dyn Clock>>,
    overload: OverloadKind,
    ingest: Option<Box<dyn Source>>,
    ingest_capacity: usize,
    ingest_policy: OverflowPolicy,
    fault_plan: Option<FaultPlan>,
    recovery: RecoveryConfig,
    stop: Option<Arc<AtomicBool>>,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        PipelineBuilder {
            queries: Vec::new(),
            shedder: ShedderKind::None,
            custom: None,
            lb_ms: 1.0,
            shards: 1,
            batch: 256,
            seed: 42,
            key_slot: 0,
            detector: None,
            tables: Vec::new(),
            cost_factors: Vec::new(),
            arrivals: None,
            source: None,
            retrain_every: 0,
            drift_threshold: 0.01,
            model_kind: ModelKind::Markov,
            latency_stride: 1,
            type_routing: true,
            clock: None,
            overload: OverloadKind::Predicted,
            ingest: None,
            ingest_capacity: 8_192,
            ingest_policy: OverflowPolicy::DropOldest,
            fault_plan: None,
            recovery: RecoveryConfig::default(),
            stop: None,
        }
    }
}

impl PipelineBuilder {
    /// The query set the pipeline evaluates (required, non-empty).
    pub fn queries(mut self, queries: Vec<Query>) -> Self {
        self.queries = queries;
        self
    }

    /// Shedding strategy selector (default: [`ShedderKind::None`]).
    pub fn shedder(mut self, kind: ShedderKind) -> Self {
        self.shedder = kind;
        self
    }

    /// Plug a custom [`Shedder`] implementation (e.g. an hSPICE-style
    /// strategy) instead of a built-in kind.  The pipeline still
    /// installs [`PipelineBuilder::tables`] on the state, so custom
    /// strategies may use [`OperatorState::shed_lowest`].  Custom
    /// strategies report the closest built-in [`Shedder::kind`]
    /// (usually [`ShedderKind::None`]) and may override
    /// [`Shedder::name`]; the kind also selects the model
    /// configuration used for drift retraining.
    pub fn custom_shedder(mut self, shedder: Box<dyn Shedder>) -> Self {
        self.custom = Some(shedder);
        self
    }

    /// Latency bound LB in virtual milliseconds (default 1.0).
    pub fn latency_bound_ms(mut self, lb_ms: f64) -> Self {
        self.lb_ms = lb_ms;
        self
    }

    /// Worker shards (default 1 = the classic single-threaded
    /// operator; >1 = the sharded runtime, capped at the query count).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Events per dispatched micro-batch in sharded mode (default 256;
    /// the single-threaded backend always dispatches per event).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Experiment seed feeding the per-strategy RNG schedule.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attribute slot holding the correlation key (E-BL's type
    /// utilities; see [`crate::datasets::DatasetKind::key_slot`]).
    pub fn key_slot(mut self, slot: usize) -> Self {
        self.key_slot = slot;
        self
    }

    /// A calibrated overload detector (untrained by default — an
    /// untrained detector never sheds).
    pub fn detector(mut self, detector: OverloadDetector) -> Self {
        self.detector = Some(detector);
        self
    }

    /// Utility tables for white-box shedding (one per query, global
    /// order); installed on the operator state when the strategy ranks
    /// PMs by utility.
    pub fn tables(mut self, tables: Vec<UtilityTable>) -> Self {
        self.tables = tables;
        self
    }

    /// Per-query check-cost factors (the paper's Fig. 8 τ ratios).
    pub fn cost_factors(mut self, factors: Vec<f64>) -> Self {
        self.cost_factors = factors;
        self
    }

    /// Deterministic arrival schedule driving queueing latency.
    /// Without one, events are treated as arriving the moment they are
    /// fed (`l_q = 0`, no latency accounting) — the embedding mode.
    pub fn arrivals(mut self, src: RateSource) -> Self {
        self.arrivals = Some(src);
        self
    }

    /// The measurement trace consumed by [`Pipeline::run_to_end`]
    /// (incremental users call [`Pipeline::feed`] instead).
    pub fn source(mut self, events: Vec<Event>) -> Self {
        self.source = Some(events);
        self
    }

    /// Drift-triggered model retraining (paper §III-D): check the
    /// transition-matrix drift every `every` events and rebuild the
    /// utility tables past `threshold` (0 disables).  Works on every
    /// backend: at `shards > 1` the [`ModelController`] merges each
    /// worker's harvested observations and broadcasts the fresh
    /// [`TableSet`] epoch to all of them.
    pub fn retrain(mut self, every: u64, threshold: f64) -> Self {
        self.retrain_every = every;
        self.drift_threshold = threshold;
        self
    }

    /// Which [`crate::model::UtilityModel`] backend drift retraining
    /// rebuilds tables with (default [`ModelKind::Markov`], the paper's
    /// Markov-reward model; [`ModelKind::Freq`] swaps in the cheap
    /// frequency-only predictor).
    pub fn model(mut self, kind: ModelKind) -> Self {
        self.model_kind = kind;
        self
    }

    /// Keep every `stride`-th latency sample in the plot trace.
    pub fn latency_stride(mut self, stride: u64) -> Self {
        self.latency_stride = stride;
        self
    }

    /// Enable/disable type-routed dispatch on the operator state
    /// (default on): events whose type a query cannot consume take the
    /// bulk-accounted skim path, and the sharded coordinator skips
    /// sending provably-irrelevant batches to inert shards.  Results
    /// are equivalent either way; disabling pins the PR 3 behavior for
    /// comparison runs.
    pub fn type_routing(mut self, enabled: bool) -> Self {
        self.type_routing = enabled;
        self
    }

    /// The time plane the pipeline runs on (default: a fresh virtual
    /// [`SimClock`]).  Pass a [`crate::sim::WallClock`] to run the same
    /// measurement loop against monotonic wall time — see
    /// [`Pipeline::run_realtime`].
    pub fn clock(mut self, clock: Box<dyn Clock>) -> Self {
        self.clock = Some(clock);
        self
    }

    /// Shorthand for `.clock(Box::new(WallClock::new()))`.
    pub fn wall_clock(self) -> Self {
        self.clock(Box::new(crate::sim::WallClock::new()))
    }

    /// Which overload detector drives shedding (default
    /// [`OverloadKind::Predicted`], the paper's Alg. 1 regressions;
    /// [`OverloadKind::Measured`] swaps in the model-free
    /// [`MeasuredDetector`] fed by observed batch latencies).
    pub fn overload(mut self, kind: OverloadKind) -> Self {
        self.overload = kind;
        self
    }

    /// Attach a real-time ingest [`Source`] for
    /// [`Pipeline::run_realtime`] (trace replay, file tail, TCP socket,
    /// or a synthetic overload generator).
    pub fn ingest_source(mut self, source: Box<dyn Source>) -> Self {
        self.ingest = Some(source);
        self
    }

    /// Capacity of the bounded ingest queue (default 8192 events).
    pub fn ingest_capacity(mut self, capacity: usize) -> Self {
        self.ingest_capacity = capacity;
        self
    }

    /// What the ingest queue does when full (default
    /// [`OverflowPolicy::DropOldest`]; [`OverflowPolicy::Block`]
    /// backpressures the source instead of losing events).
    pub fn ingest_policy(mut self, policy: OverflowPolicy) -> Self {
        self.ingest_policy = policy;
        self
    }

    /// Seeded chaos schedule for the sharded runtime (requires
    /// `shards > 1`): each [`crate::runtime::FaultSpec`] kills, delays
    /// or poisons one worker at a fixed dispatch count, and the
    /// coordinator recovers by respawning the shard and accounting its
    /// lost PMs as an involuntary shed
    /// ([`ShedReport::dropped_pms_failure`]).  An empty plan is exactly
    /// the unfaulted pipeline.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Take a per-shard state snapshot every `every` batch dispatches
    /// (sharded runtime; default 0 = off).  With checkpointing on, a
    /// crashed worker is restored from its last snapshot plus a journal
    /// replay instead of PR 8's lossy respawn: recovered PMs are booked
    /// as [`ShedReport::recovered_pms`], not
    /// [`ShedReport::dropped_pms_failure`].
    pub fn checkpoint_every(mut self, every: u64) -> Self {
        self.recovery.checkpoint_every = every;
        self
    }

    /// Per-shard journal capacity in events (default 8192).  A shard
    /// whose journal outgrows this between checkpoints degrades to
    /// lossy recovery until the next completed checkpoint re-arms it.
    pub fn journal_cap(mut self, cap: usize) -> Self {
        self.recovery.journal_cap = cap;
        self
    }

    /// Deadline for any single worker response, in wall milliseconds
    /// (0 = derive: wall-clock runs get `100 × LB` clamped to
    /// [50 ms, 1000 ms]; virtual-clock runs block forever, the PR 8
    /// behavior).  A worker that misses the deadline is treated as
    /// hung — marked dead, its thread detached — and the shard is
    /// recovered like a crash.
    pub fn worker_deadline_ms(mut self, ms: f64) -> Self {
        self.recovery.worker_deadline_ms = ms;
        self
    }

    /// Cooperative stop flag for [`Pipeline::run_realtime`]: when the
    /// flag goes `true` (e.g. from a SIGINT handler) the loop finishes
    /// the in-flight batch, marks the run interrupted and returns its
    /// summary instead of spinning to the deadline.
    pub fn stop_flag(mut self, flag: Arc<AtomicBool>) -> Self {
        self.stop = Some(flag);
        self
    }

    /// Validate and assemble the [`Pipeline`].
    pub fn build(self) -> crate::Result<Pipeline> {
        anyhow::ensure!(!self.queries.is_empty(), "pipeline needs queries");
        anyhow::ensure!(self.shards >= 1, "shards must be at least 1");
        anyhow::ensure!(
            self.shards <= crate::operator::MAX_SHARDS,
            "shards must be at most {}",
            crate::operator::MAX_SHARDS
        );
        anyhow::ensure!(self.batch >= 1, "batch must be at least 1");
        let lb_ns = self.lb_ms * 1e6;
        let detector = self
            .detector
            .unwrap_or_else(|| OverloadDetector::new(lb_ns, 0.02 * lb_ns));
        // the overload switch: strategies hold a gauge and never know
        // which plane they run on
        let gauge = match self.overload {
            OverloadKind::Predicted => OverloadGauge::Predicted(detector),
            OverloadKind::Measured => {
                OverloadGauge::Measured(MeasuredDetector::new(lb_ns, 0.02 * lb_ns))
            }
        };
        let n = self.queries.len();
        let weights: Vec<f64> = self.queries.iter().map(|q| q.weight).collect();
        // E-BL's key-slot table is built once and Arc-shared between
        // the strategy and the TableSet snapshot — one model plane for
        // black-box and white-box strategies alike
        let key_table = (self.custom.is_none()
            && matches!(self.shedder, ShedderKind::EventBaseline))
        .then(|| Arc::new(KeyUtilityTable::from_queries(&self.queries, self.key_slot)));
        let shedder = match self.custom {
            Some(s) => s,
            None => self
                .shedder
                .build_from_gauge(&gauge, key_table.as_ref(), self.seed),
        };
        anyhow::ensure!(
            self.tables.is_empty() || self.tables.len() == n,
            "{} utility tables for {n} queries",
            self.tables.len()
        );
        let check_factors = if self.cost_factors.is_empty() {
            vec![1.0; n]
        } else {
            anyhow::ensure!(
                self.cost_factors.len() == n,
                "{} cost factors for {n} queries",
                self.cost_factors.len()
            );
            self.cost_factors
        };
        let faults = self.fault_plan.unwrap_or_else(FaultPlan::none);
        anyhow::ensure!(
            faults.is_empty() || self.shards > 1,
            "fault injection targets the sharded runtime; set shards > 1"
        );
        if let Some(max) = faults.max_shard() {
            // the runtime caps the shard count at the query count
            let running = self.shards.min(n);
            anyhow::ensure!(
                max < running,
                "fault plan targets shard {max}, but the run has {running} shards"
            );
        }
        anyhow::ensure!(
            self.recovery.worker_deadline_ms >= 0.0
                && self.recovery.worker_deadline_ms.is_finite(),
            "worker_deadline_ms must be a finite non-negative ms value"
        );
        let mut recovery = self.recovery;
        // wall-clock runs get a hang deadline by default: generous
        // relative to the latency bound (a healthy worker answers a
        // dispatch in a small fraction of LB), clamped so thread-spawn
        // jitter cannot trip it and a huge LB cannot disable it.
        // Virtual-clock runs keep 0 (block forever): wall stalls there
        // are scheduler noise, not modeled behavior.
        if recovery.worker_deadline_ms == 0.0
            && self.clock.as_ref().is_some_and(|c| c.is_wall())
        {
            recovery.worker_deadline_ms = (100.0 * self.lb_ms).clamp(50.0, 1000.0);
        }
        let mut backend = if self.shards > 1 {
            Backend::Sharded(ShardedOperator::with_recovery(
                self.queries,
                self.shards,
                faults,
                recovery,
            ))
        } else {
            Backend::Single(Operator::new(self.queries))
        };
        if !self.type_routing {
            match &mut backend {
                Backend::Single(op) => op.set_type_routing(false),
                Backend::Sharded(sop) => sop.set_type_routing(false),
            }
        }
        // the whole model snapshot installs as ONE epoch-0 TableSet —
        // utility tables, check-cost factors and the key-slot table in
        // a single atomic swap (strategies that never call shed_lowest
        // simply ignore the tables, and custom shedders get them
        // regardless of which kind they report as)
        let initial = Arc::new(TableSet::initial(self.tables, check_factors, key_table));
        backend.state().install_table_set(Arc::clone(&initial));
        let retraining = self.retrain_every > 0;
        // without retraining, sharded workers never need observations;
        // with it, they keep capturing through prime() exactly like the
        // single backend, feeding the harvested training view
        if matches!(backend, Backend::Sharded(_)) && !retraining {
            backend.state().set_obs_enabled(false);
        }
        let dispatch = match &backend {
            Backend::Single(_) => 1,
            Backend::Sharded(_) => self.batch,
        };
        let controller = retraining.then(|| {
            ModelController::new(
                self.model_kind.build(shedder.kind().model_config()),
                self.drift_threshold,
                weights,
                initial,
            )
        });
        Ok(Pipeline {
            backend,
            shedder,
            clock: self.clock.unwrap_or_else(|| Box::new(SimClock::new())),
            arrivals: self.arrivals,
            latency: LatencyTracker::new(lb_ns, self.latency_stride),
            dispatch,
            idx: 0,
            totals: ShedReport::default(),
            busy_ns: 0.0,
            peak_pms: 0,
            retrains: 0,
            retrain_every: self.retrain_every,
            next_retrain_due: self.retrain_every,
            controller,
            batch_out: BatchResult::default(),
            started: false,
            wall: Throughput::new(),
            source: self.source,
            ingest: self
                .ingest
                .map(|s| (s, IngestQueue::new(self.ingest_capacity, self.ingest_policy))),
            queue_dropped: 0,
            recoveries: 0,
            stop: self.stop,
            interrupted: false,
        })
    }
}

/// Summary of a pipeline run (plus every complex event it detected).
#[derive(Debug, Clone)]
pub struct PipelineRun {
    /// complex events detected during the run, in detection order
    pub completions: Vec<ComplexEvent>,
    /// latency trace against the bound
    pub latency: LatencyTracker,
    /// shed time / operator busy time
    pub shed_overhead: f64,
    /// accumulated shed totals (PMs, events, cost)
    pub totals: ShedReport,
    /// peak live PM count seen
    pub peak_pms: usize,
    /// drift-triggered model rebuilds
    pub retrains: u32,
    /// epoch of the model snapshot the state ended on (0 = the initial
    /// install; every retrain bumps it)
    pub table_epoch: u64,
    /// strategy name
    pub shedder: &'static str,
    /// worker shards that actually ran (the runtime caps the requested
    /// count at the query count)
    pub shards: usize,
    /// wall-clock events/s across all feeds (not virtual time)
    pub wall_events_per_sec: f64,
    /// events lost at the ingest queue (real-time runs with a full
    /// queue under [`OverflowPolicy::DropOldest`]; 0 in batch runs)
    pub queue_dropped: u64,
    /// shard workers respawned after a failure (sharded runs under a
    /// [`FaultPlan`], or real crashes; lost PMs are accounted in
    /// [`ShedReport::dropped_pms_failure`])
    pub recoveries: u64,
    /// a stop flag ended [`Pipeline::run_realtime`] before its deadline
    /// (the in-flight batch still completed; totals are valid)
    pub interrupted: bool,
}

/// The assembled engine: one measurement loop for every strategy and
/// every backend.  See the [module docs](self) for the two consumption
/// styles.
pub struct Pipeline {
    backend: Backend,
    shedder: Box<dyn Shedder>,
    clock: Box<dyn Clock>,
    arrivals: Option<RateSource>,
    latency: LatencyTracker,
    /// events per dispatch unit (1 on the single-threaded backend)
    dispatch: usize,
    /// measurement events fed so far (arrival index)
    idx: u64,
    totals: ShedReport,
    busy_ns: f64,
    peak_pms: usize,
    retrains: u32,
    retrain_every: u64,
    /// next event index at which the drift check runs (advances in
    /// `retrain_every` strides, robust to multi-event dispatch units)
    next_retrain_due: u64,
    /// the train→snapshot→publish loop (None = retraining disabled)
    controller: Option<ModelController>,
    /// recycled batch outcome: completions reuse one buffer across
    /// every dispatch (the into-buffer API at the coordinator boundary)
    batch_out: BatchResult,
    started: bool,
    wall: Throughput,
    source: Option<Vec<Event>>,
    /// the real-time plane: ingest source + bounded queue (None in
    /// batch/virtual mode)
    ingest: Option<(Box<dyn Source>, IngestQueue)>,
    /// events lost at the ingest queue so far
    queue_dropped: u64,
    /// shard respawns folded in from the backend's failure drain
    recoveries: u64,
    /// cooperative early-exit flag for [`Pipeline::run_realtime`]
    stop: Option<Arc<AtomicBool>>,
    /// the stop flag fired during a real-time run
    interrupted: bool,
}

impl Pipeline {
    /// Start configuring a pipeline.
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::default()
    }

    /// Worker shards actually running (1 on the single-threaded
    /// backend; the sharded runtime caps the request at the query
    /// count).
    pub fn shards(&self) -> usize {
        match &self.backend {
            Backend::Single(_) => 1,
            Backend::Sharded(sop) => sop.n_shards(),
        }
    }

    /// The operator state, for introspection or direct driving.
    pub fn state(&mut self) -> &mut dyn OperatorState {
        self.backend.state()
    }

    /// Global live PM count.
    pub fn pm_count(&self) -> usize {
        self.backend.state_ref().pm_count()
    }

    /// The pipeline clock's current time (ns) — virtual on a
    /// [`SimClock`], monotonic-plus-offset on a
    /// [`crate::sim::WallClock`].  Deadlines for
    /// [`Pipeline::run_realtime`] are expressed on this timeline.
    pub fn now_ns(&self) -> f64 {
        self.clock.now_ns()
    }

    /// Accumulated shed totals so far.
    pub fn totals(&self) -> ShedReport {
        self.totals
    }

    /// Shard workers respawned after a failure so far.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    /// Fold the backend's failure drain into the run accounting: PMs
    /// lost to a crashed shard are an involuntary shed
    /// ([`ShedReport::dropped_pms_failure`]), PMs a checkpointed
    /// respawn restored are [`ShedReport::recovered_pms`], PMs dropped
    /// by replaying unacked shed directives are ordinary voluntary
    /// shedding, every respawn counts as a recovery, and the replay's
    /// processing cost is charged to the clock so recovery cannot hide
    /// work from the latency accounting.  No-op on the single-threaded
    /// backend and on healthy sharded runs.
    fn drain_failures(&mut self) {
        let d = self.backend.state().drain_failures();
        self.totals.dropped_pms_failure += d.dropped_pms;
        self.totals.dropped_pms += d.replayed_drop_pms;
        self.totals.recovered_pms += d.recovered_pms;
        self.totals.replayed_events += d.replayed_events;
        self.totals.hangs_detected += d.hangs_detected;
        self.recoveries += d.recoveries;
        if d.replay_cost_ns > 0.0 {
            self.clock.advance(d.replay_cost_ns);
            self.busy_ns += d.replay_cost_ns;
        }
    }

    /// Epoch of the model snapshot the backend is currently reading
    /// (0 until a retrain publishes a successor [`TableSet`]; on the
    /// sharded runtime every worker reads the same broadcast epoch).
    pub fn table_epoch(&self) -> u64 {
        self.backend.state_ref().table_epoch()
    }

    /// Warm the operator state below capacity (no arrival schedule, no
    /// latency accounting, no shedding): the calibration prefix of an
    /// experiment, or historical state for an embedding.  Must be
    /// called before the first [`Pipeline::feed`].  Returns the
    /// complex events the warm-up detected.
    pub fn prime(&mut self, events: &[Event]) -> Vec<ComplexEvent> {
        assert!(!self.started, "prime() must run before feed()");
        let mut ces = Vec::new();
        let mut out = std::mem::take(&mut self.batch_out);
        for chunk in events.chunks(self.dispatch) {
            self.backend.state().process_batch_into(chunk, None, &mut out);
            ces.extend_from_slice(&out.completions);
        }
        self.batch_out = out;
        ces
    }

    /// First-feed transition: freeze calibration-time observation
    /// capture (unless retraining keeps consuming it) and snapshot the
    /// drift baseline from the harvested statistics — on the sharded
    /// backend that is the merged per-worker harvest.
    fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        let retraining = self.controller.is_some();
        self.backend.state().set_obs_enabled(retraining);
        if let Some(c) = &mut self.controller {
            c.begin(self.backend.state_ref());
        }
    }

    /// §III-D: periodic drift check → rebuild the model, on any
    /// backend.  The [`ModelController`] harvests the state's
    /// observations (merged across workers when sharded), drift-checks
    /// the candidate matrices (cheap — counts → probabilities), and
    /// only on actual drift trains a fresh [`TableSet`] epoch and
    /// publishes it (an `UpdateTables` broadcast when sharded).
    fn maybe_retrain(&mut self) -> crate::Result<()> {
        let Some(c) = &mut self.controller else {
            return Ok(());
        };
        if self.idx < self.next_retrain_due {
            return Ok(());
        }
        while self.next_retrain_due <= self.idx {
            self.next_retrain_due += self.retrain_every;
        }
        if c.check_and_retrain(self.backend.state())? {
            self.retrains += 1;
        }
        Ok(())
    }

    /// Feed measurement events through the shed-then-process loop in
    /// dispatch units, advancing the virtual clock by shed cost plus
    /// the batch makespan.  Returns the complex events detected.
    pub fn feed(&mut self, events: &[Event]) -> crate::Result<Vec<ComplexEvent>> {
        self.start();
        // audit:allow(wall-clock): wall throughput instrumentation only — feeds
        // wall_secs in the run report, never the virtual timeline
        let wall_start = Instant::now();
        let mut ces = Vec::new();
        for chunk in events.chunks(self.dispatch) {
            // the batch starts service once its last event has arrived
            // (or later if the operator is still busy); l_q is measured
            // from the batch's first arrival
            let l_q = match &self.arrivals {
                Some(src) => {
                    let first = src.arrival_ns(self.idx);
                    let last = src.arrival_ns(self.idx + chunk.len() as u64 - 1);
                    self.clock.begin_service(last);
                    (self.clock.now_ns() - first).max(0.0)
                }
                None => 0.0,
            };
            let rep = self.shedder.on_batch(chunk, l_q, self.backend.state());
            self.clock.advance(rep.cost_ns);
            self.busy_ns += rep.cost_ns;
            self.totals += rep;
            let mask = self.shedder.event_mask();
            let mut out = std::mem::take(&mut self.batch_out);
            self.backend.state().process_batch_into(chunk, mask, &mut out);
            // virtual time advances by the batch makespan (the slowest
            // shard; on the single backend, the event's cost)
            self.clock.advance(out.cost_ns_max);
            self.busy_ns += out.cost_ns_max;
            // feed the gauge what the batch actually cost (no-op on the
            // predicted plane)
            self.shedder.observe_batch(
                self.backend.state_ref().pm_count(),
                chunk.len(),
                out.cost_ns_max,
            );
            ces.extend_from_slice(&out.completions);
            self.batch_out = out;
            self.drain_failures();
            if let Some(src) = &self.arrivals {
                let end = self.clock.now_ns();
                for j in 0..chunk.len() as u64 {
                    self.latency.record(end, end - src.arrival_ns(self.idx + j));
                }
            }
            self.peak_pms = self.peak_pms.max(self.backend.state_ref().pm_count());
            self.idx += chunk.len() as u64;
            self.maybe_retrain()?;
        }
        self.wall
            .record(events.len() as u64, wall_start.elapsed().as_secs_f64());
        Ok(ces)
    }

    /// Drain the trace given to [`PipelineBuilder::source`] through
    /// [`Pipeline::feed`] and summarize the run.
    pub fn run_to_end(&mut self) -> crate::Result<PipelineRun> {
        let trace = self
            .source
            .take()
            .ok_or_else(|| anyhow::anyhow!("run_to_end needs a .source(..) trace"))?;
        let completions = self.feed(&trace)?;
        Ok(self.summary(completions))
    }

    /// Summarize the run so far (for [`Pipeline::feed`]-style users;
    /// `completions` become part of the summary).
    pub fn summary(&self, completions: Vec<ComplexEvent>) -> PipelineRun {
        PipelineRun {
            completions,
            latency: self.latency.clone(),
            shed_overhead: if self.busy_ns > 0.0 {
                self.totals.cost_ns / self.busy_ns
            } else {
                0.0
            },
            totals: self.totals,
            peak_pms: self.peak_pms,
            retrains: self.retrains,
            table_epoch: self.table_epoch(),
            shedder: self.shedder.name(),
            shards: self.shards(),
            wall_events_per_sec: self.wall.events_per_sec(),
            queue_dropped: self.queue_dropped,
            recoveries: self.recoveries,
            interrupted: self.interrupted,
        }
    }

    /// Drive the pipeline against its ingest plane until the clock
    /// reaches `deadline_ns` or the source is exhausted: poll the
    /// [`Source`], pass arrivals through the bounded [`IngestQueue`]
    /// (measuring *real* queueing delay from its arrival stamps), and
    /// run the same shed-then-process loop as [`Pipeline::feed`].
    ///
    /// On a [`crate::sim::SimClock`] the loop fast-forwards across
    /// arrival gaps (deterministic replay); on a
    /// [`crate::sim::WallClock`] gaps with no known next arrival are
    /// idled in real time, so external sources (tail, socket) are
    /// polled at millisecond cadence.  Needs
    /// [`PipelineBuilder::ingest_source`].
    pub fn run_realtime(&mut self, deadline_ns: f64) -> crate::Result<PipelineRun> {
        let (mut source, mut queue) = self
            .ingest
            .take()
            .ok_or_else(|| anyhow::anyhow!("run_realtime needs an .ingest_source(..)"))?;
        self.start();
        // audit:allow(wall-clock): wall throughput instrumentation only — the
        // real-time loop's timeline comes from self.clock, not this stopwatch
        let wall_start = Instant::now();
        let mut completions = Vec::new();
        let mut batch_events: Vec<Event> = Vec::with_capacity(self.dispatch);
        let mut batch_arrivals: Vec<f64> = Vec::with_capacity(self.dispatch);
        let mut poll_buf: Vec<(Event, f64)> = Vec::new();
        let mut processed = 0u64;
        let mut exhausted = false;
        let result = loop {
            // cooperative shutdown: the previous iteration finished its
            // in-flight batch, so stopping here loses nothing
            if self
                .stop
                .as_ref()
                .is_some_and(|f| f.load(Ordering::Relaxed))
            {
                self.interrupted = true;
                break Ok(());
            }
            let now = self.clock.now_ns();
            if now >= deadline_ns {
                break Ok(());
            }
            // 1. pull arrivals into the queue.  Block policy polls only
            // what fits (true backpressure); DropOldest polls freely
            // and lets the queue evict.
            let mut next_arrival: Option<f64> = None;
            if !exhausted && !queue.pull_paused() {
                let room = match queue.policy() {
                    OverflowPolicy::Block => queue.capacity() - queue.len(),
                    OverflowPolicy::DropOldest => queue.capacity(),
                };
                if room > 0 {
                    poll_buf.clear();
                    match source.poll_into(now, room, &mut poll_buf) {
                        SourcePoll::Ready => {
                            for (e, arrival_ns) in poll_buf.drain(..) {
                                queue.push(e, arrival_ns);
                            }
                        }
                        SourcePoll::Pending { next_arrival_ns } => next_arrival = next_arrival_ns,
                        SourcePoll::Exhausted => exhausted = true,
                    }
                }
            }
            // 2. nothing buffered: wait for the next arrival (or give
            // external sources a beat) and try again
            if queue.is_empty() {
                if exhausted {
                    break Ok(());
                }
                match next_arrival {
                    Some(t) => self.clock.wait_until(t.min(deadline_ns)),
                    // no schedule: 1ms — virtual jump or real sleep
                    None => self.clock.idle(1e6),
                }
                continue;
            }
            // 3. the shed-then-process loop of feed(), with l_q
            // measured from the queue's arrival stamps
            let n = queue.pop_into(self.dispatch, &mut batch_events, &mut batch_arrivals);
            let first = batch_arrivals[0];
            let last = batch_arrivals[n - 1];
            self.clock.begin_service(last);
            let l_q = (self.clock.now_ns() - first).max(0.0);
            let rep = self.shedder.on_batch(&batch_events, l_q, self.backend.state());
            self.clock.advance(rep.cost_ns);
            self.busy_ns += rep.cost_ns;
            self.totals += rep;
            let mask = self.shedder.event_mask();
            let mut out = std::mem::take(&mut self.batch_out);
            self.backend
                .state()
                .process_batch_into(&batch_events, mask, &mut out);
            self.clock.advance(out.cost_ns_max);
            self.busy_ns += out.cost_ns_max;
            self.shedder
                .observe_batch(self.backend.state_ref().pm_count(), n, out.cost_ns_max);
            completions.extend_from_slice(&out.completions);
            self.batch_out = out;
            self.drain_failures();
            let end = self.clock.now_ns();
            for &arrival_ns in batch_arrivals.iter() {
                self.latency.record(end, (end - arrival_ns).max(0.0));
            }
            self.peak_pms = self.peak_pms.max(self.backend.state_ref().pm_count());
            self.idx += n as u64;
            processed += n as u64;
            if let Err(e) = self.maybe_retrain() {
                break Err(e);
            }
        };
        self.wall
            .record(processed, wall_start.elapsed().as_secs_f64());
        self.queue_dropped = queue.dropped();
        // restow so a later call picks up where this one stopped
        self.ingest = Some((source, queue));
        result?;
        Ok(self.summary(completions))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::query::builtin::q4;

    fn bus_queries() -> Vec<Query> {
        q4(4, 2_000, 250).queries
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(Pipeline::builder().build().is_err(), "no queries");
        assert!(Pipeline::builder()
            .queries(bus_queries())
            .shards(0)
            .build()
            .is_err());
        assert!(Pipeline::builder()
            .queries(bus_queries())
            .batch(0)
            .build()
            .is_err());
        // cost factors must match the query count (q4 is one query)
        assert!(Pipeline::builder()
            .queries(bus_queries())
            .cost_factors(vec![1.0, 2.0])
            .build()
            .is_err());
        // retraining at shards > 1 is supported since the model-plane
        // redesign — the old rejection is gone
        assert!(Pipeline::builder()
            .queries(bus_queries())
            .shards(2)
            .retrain(1_000, 0.01)
            .build()
            .is_ok());
    }

    #[test]
    fn sharded_retraining_bumps_the_broadcast_epoch() {
        // two q4 copies so a 2-shard split actually distributes; a
        // threshold of ~0 makes every due check a retrain
        let mut queries = bus_queries();
        queries.extend(q4(3, 1_500, 300).queries);
        let events = BusGen::with_seed(9).take_events(24_000);
        let mut pipe = Pipeline::builder()
            .queries(queries)
            .shards(2)
            .batch(500)
            .retrain(2_000, 1e-12)
            .build()
            .unwrap();
        assert_eq!(pipe.shards(), 2);
        pipe.prime(&events[..8_000]);
        assert_eq!(pipe.table_epoch(), 0);
        pipe.feed(&events[8_000..]).unwrap();
        let run = pipe.summary(Vec::new());
        assert!(run.retrains >= 1, "tight threshold must retrain");
        assert_eq!(run.retrains as u64, pipe.table_epoch());
        assert!(pipe.table_epoch() > 0);
    }

    #[test]
    fn feed_without_shedding_matches_plain_operator() {
        let events = BusGen::with_seed(3).take_events(8_000);
        let mut op = Operator::new(bus_queries());
        let mut expected = Vec::new();
        for e in &events {
            expected.extend(op.process_event(e).completions);
        }

        let mut pipe = Pipeline::builder()
            .queries(bus_queries())
            .build()
            .unwrap();
        let mut got = pipe.prime(&events[..4_000]);
        got.extend(pipe.feed(&events[4_000..]).unwrap());
        assert_eq!(got, expected);
        assert_eq!(pipe.pm_count(), op.pm_count());
        assert_eq!(pipe.totals(), ShedReport::default());
        assert_eq!(pipe.shards(), 1);
    }

    #[test]
    fn sharded_feed_matches_single_feed() {
        // two q4 copies so a 2-shard split actually distributes
        let mut queries = bus_queries();
        queries.extend(q4(3, 1_500, 300).queries);
        let events = BusGen::with_seed(3).take_events(20_000);

        let run = |shards: usize| {
            let mut pipe = Pipeline::builder()
                .queries(queries.clone())
                .shards(shards)
                .batch(512)
                .build()
                .unwrap();
            let mut ces = pipe.prime(&events[..2_000]);
            ces.extend(pipe.feed(&events[2_000..]).unwrap());
            crate::runtime::sharded::sort_completions(&mut ces);
            (ces, pipe.pm_count())
        };
        let (ces1, pms1) = run(1);
        let (ces2, pms2) = run(2);
        assert!(!ces1.is_empty(), "scenario must detect something");
        assert_eq!(ces1, ces2);
        assert_eq!(pms1, pms2);
    }

    #[test]
    fn incremental_feed_equals_one_shot_feed() {
        let events = BusGen::with_seed(5).take_events(6_000);
        let mk = || {
            Pipeline::builder()
                .queries(bus_queries())
                .arrivals(RateSource::from_capacity(1_000.0, 1.2, 0.0))
                .build()
                .unwrap()
        };
        let mut one = mk();
        let a = one.feed(&events).unwrap();
        let mut inc = mk();
        let mut b = Vec::new();
        for chunk in events.chunks(777) {
            b.extend(inc.feed(chunk).unwrap());
        }
        assert_eq!(a, b);
        assert_eq!(
            one.summary(Vec::new()).latency.stats.count(),
            inc.summary(Vec::new()).latency.stats.count()
        );
    }
}
