//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: quoted strings, numbers, booleans, flat numeric arrays.

use std::collections::HashMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// quoted string
    Str(String),
    /// number (all numerics are f64)
    Num(f64),
    /// boolean
    Bool(bool),
    /// flat numeric array
    Array(Vec<f64>),
}

/// A parsed document: `(section, key) -> value`.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    values: HashMap<(String, String), TomlValue>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // only strip comments outside quotes (strings here never
                // contain '#' in our configs; keep it simple but safe)
                Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => &raw[..pos],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                anyhow::ensure!(
                    line.ends_with(']'),
                    "line {}: bad section header {line:?}",
                    no + 1
                );
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("line {}: expected key = value, got {line:?}", no + 1)
            })?;
            let key = key.trim().to_string();
            let value = Self::parse_value(value.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", no + 1))?;
            doc.values.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    fn parse_value(s: &str) -> crate::Result<TomlValue> {
        if let Some(inner) = s.strip_prefix('"') {
            let inner = inner
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("unterminated string {s:?}"))?;
            return Ok(TomlValue::Str(inner.to_string()));
        }
        if s == "true" {
            return Ok(TomlValue::Bool(true));
        }
        if s == "false" {
            return Ok(TomlValue::Bool(false));
        }
        if let Some(inner) = s.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("unterminated array {s:?}"))?;
            let items: Result<Vec<f64>, _> = inner
                .split(',')
                .map(str::trim)
                .filter(|x| !x.is_empty())
                .map(str::parse::<f64>)
                .collect();
            return Ok(TomlValue::Array(items?));
        }
        Ok(TomlValue::Num(s.parse::<f64>()?))
    }

    /// Raw lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    /// String value.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn get_num(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Num(n)) => Some(*n),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Array value.
    pub fn get_array(&self, section: &str, key: &str) -> Option<Vec<f64>> {
        match self.get(section, key) {
            Some(TomlValue::Array(a)) => Some(a.clone()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let doc = TomlDoc::parse(
            r#"
            [a]
            s = "hello"   # comment
            n = 3.5
            b = true
            arr = [1, 2, 3.5]
            [b]
            n = 7
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_num("a", "n"), Some(3.5));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_array("a", "arr"), Some(vec![1.0, 2.0, 3.5]));
        assert_eq!(doc.get_num("b", "n"), Some(7.0));
        assert_eq!(doc.get("a", "missing"), None);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("[a\n").is_err());
        assert!(TomlDoc::parse("[a]\njust a line\n").is_err());
        assert!(TomlDoc::parse("[a]\nx = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("[a]\nx = [1, 2\n").is_err());
        assert!(TomlDoc::parse("[a]\nx = notanumber\n").is_err());
    }

    #[test]
    fn empty_and_comment_lines_ok() {
        let doc = TomlDoc::parse("# top comment\n\n[s]\nk = 1\n").unwrap();
        assert_eq!(doc.get_num("s", "k"), Some(1.0));
    }
}
