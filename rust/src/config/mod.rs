//! Experiment configuration: a TOML-subset parser (offline stand-in for
//! `serde`+`toml`, which are not in the vendored crate set) plus the
//! typed [`ExperimentConfig`] the launcher consumes.
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! number, boolean and flat-array values, `#` comments.

pub mod toml_lite;

pub use toml_lite::{TomlDoc, TomlValue};

use crate::datasets::DatasetKind;
use crate::ingest::{OverflowPolicy, SourceKind, WireCodec};
use crate::model::ModelKind;
use crate::shedding::{OverloadKind, ShedderKind};

/// Fully resolved experiment configuration (see `examples/configs/`).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// built-in query name: q1..q4
    pub query: String,
    /// window size (events for q1/q2/q4, ms for q3)
    pub window: u64,
    /// pattern size n (q3/q4 only)
    pub pattern_n: usize,
    /// slide for q4
    pub slide: u64,
    /// dataset
    pub dataset: DatasetKind,
    /// dataset seed
    pub seed: u64,
    /// total events to stream (excluding warm-up)
    pub events: u64,
    /// warm-up events (model + regression calibration)
    pub warmup: u64,
    /// input rate as a multiple of measured capacity (1.2 = 120%)
    pub rate: f64,
    /// latency bound LB in virtual ms
    pub lb_ms: f64,
    /// shedding strategy
    pub shedder: ShedderKind,
    /// utility-model backend (`markov` = the paper's Markov-reward
    /// model, `freq` = the frequency-only predictor)
    pub model: ModelKind,
    /// per-query weights override (empty = all 1.0)
    pub weights: Vec<f64>,
    /// per-query check-cost factors (Fig. 8's τ ratios; empty = 1.0)
    pub cost_factors: Vec<f64>,
    /// check transition-matrix drift every this many events during the
    /// measurement phase and rebuild the model when it exceeds
    /// `drift_threshold` (paper §III-D); 0 disables retraining
    pub retrain_every: u64,
    /// MSE threshold for drift-triggered retraining
    pub drift_threshold: f64,
    /// worker shards for the measurement phase (1 = the classic
    /// single-threaded operator; >1 = the sharded runtime)
    pub shards: usize,
    /// events per dispatched batch in sharded mode
    pub batch: usize,
    /// which overload detector drives shedding (`predicted` = Alg. 1
    /// regressions, `measured` = latency EWMAs)
    pub overload: OverloadKind,
    /// ingest source for real-time runs (`trace` replays the dataset)
    pub source: SourceKind,
    /// wire framing for `--source socket` (`lines` or strict `csv`)
    pub codec: WireCodec,
    /// bounded ingest-queue capacity (events)
    pub ingest_capacity: usize,
    /// what the full ingest queue does (`drop-oldest` or `block`)
    pub ingest_policy: OverflowPolicy,
    /// real-time run duration in clock ms (0 = until the source ends)
    pub duration_ms: f64,
    /// seeded chaos schedule for the sharded runtime, as a
    /// comma-separated [`crate::runtime::FaultPlan`] spec
    /// (`"kill:1@10,delay:0@5:2.5,poison:2@30"`; empty = no injection)
    pub faults: String,
    /// take a per-shard state snapshot every this many batch dispatches
    /// (sharded runtime; 0 = checkpointing off, worker death falls back
    /// to lossy recovery)
    pub checkpoint_every: u64,
    /// per-shard journal capacity in events; a shard whose journal
    /// outgrows this between checkpoints degrades to lossy recovery
    /// until the next completed checkpoint
    pub journal_cap: usize,
    /// deadline for any single worker response in wall ms (0 = derive:
    /// wall-clock runs get one from the latency bound, virtual runs
    /// block forever); a worker that misses it is treated as hung
    pub worker_deadline_ms: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            query: "q1".into(),
            window: 5_000,
            pattern_n: 4,
            slide: 500,
            dataset: DatasetKind::Stock,
            seed: 42,
            events: 200_000,
            warmup: 100_000,
            rate: 1.2,
            lb_ms: 1.0,
            shedder: ShedderKind::PSpice,
            model: ModelKind::Markov,
            weights: Vec::new(),
            cost_factors: Vec::new(),
            retrain_every: 0,
            drift_threshold: 0.01,
            shards: 1,
            batch: 256,
            overload: OverloadKind::Predicted,
            source: SourceKind::Trace,
            codec: WireCodec::Lines,
            ingest_capacity: 8_192,
            ingest_policy: OverflowPolicy::DropOldest,
            duration_ms: 0.0,
            faults: String::new(),
            checkpoint_every: 0,
            journal_cap: 8_192,
            worker_deadline_ms: 0.0,
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML-subset text (section `[experiment]`, all keys
    /// optional).
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        let section = "experiment";
        if let Some(v) = doc.get_str(section, "query") {
            cfg.query = v.to_string();
        }
        if let Some(v) = doc.get_num(section, "window") {
            cfg.window = v as u64;
        }
        if let Some(v) = doc.get_num(section, "pattern_n") {
            cfg.pattern_n = v as usize;
        }
        if let Some(v) = doc.get_num(section, "slide") {
            cfg.slide = v as u64;
        }
        if let Some(v) = doc.get_str(section, "dataset") {
            cfg.dataset = v.parse()?;
        }
        if let Some(v) = doc.get_num(section, "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_num(section, "events") {
            cfg.events = v as u64;
        }
        if let Some(v) = doc.get_num(section, "warmup") {
            cfg.warmup = v as u64;
        }
        if let Some(v) = doc.get_num(section, "rate") {
            cfg.rate = v;
        }
        if let Some(v) = doc.get_num(section, "lb_ms") {
            cfg.lb_ms = v;
        }
        if let Some(v) = doc.get_str(section, "shedder") {
            cfg.shedder = v.parse()?;
        }
        if let Some(v) = doc.get_str(section, "model") {
            cfg.model = v.parse()?;
        }
        if let Some(v) = doc.get_array(section, "weights") {
            cfg.weights = v;
        }
        if let Some(v) = doc.get_array(section, "cost_factors") {
            cfg.cost_factors = v;
        }
        if let Some(v) = doc.get_num(section, "retrain_every") {
            cfg.retrain_every = v as u64;
        }
        if let Some(v) = doc.get_num(section, "drift_threshold") {
            cfg.drift_threshold = v;
        }
        if let Some(v) = doc.get_num(section, "shards") {
            cfg.shards = v as usize;
        }
        if let Some(v) = doc.get_num(section, "batch") {
            cfg.batch = v as usize;
        }
        if let Some(v) = doc.get_str(section, "overload") {
            cfg.overload = v.parse()?;
        }
        if let Some(v) = doc.get_str(section, "source") {
            cfg.source = v.parse()?;
        }
        if let Some(v) = doc.get_str(section, "codec") {
            cfg.codec = v.parse()?;
        }
        if let Some(v) = doc.get_num(section, "ingest_capacity") {
            cfg.ingest_capacity = v as usize;
        }
        if let Some(v) = doc.get_str(section, "ingest_policy") {
            cfg.ingest_policy = v.parse()?;
        }
        if let Some(v) = doc.get_num(section, "duration_ms") {
            cfg.duration_ms = v;
        }
        if let Some(v) = doc.get_str(section, "faults") {
            // parse eagerly so a bad spec fails at load, not mid-run
            crate::runtime::FaultPlan::parse(v)?;
            cfg.faults = v.to_string();
        }
        if let Some(v) = doc.get_num(section, "checkpoint_every") {
            cfg.checkpoint_every = v as u64;
        }
        if let Some(v) = doc.get_num(section, "journal_cap") {
            cfg.journal_cap = v as usize;
        }
        if let Some(v) = doc.get_num(section, "worker_deadline_ms") {
            cfg.worker_deadline_ms = v;
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }
}

/// Scoreboard protocol settings (section `[scorecard]`, all keys
/// optional): how many repeated seeds back each grid cell's confidence
/// interval, and how much release-over-release regression the trend
/// gates tolerate (see `rust/src/scorecard/`).
#[derive(Debug, Clone)]
pub struct ScorecardConfig {
    /// repeated seeds per grid cell (`base_seed .. base_seed + reps`)
    pub reps: usize,
    /// first dataset seed of the repetition sweep
    pub base_seed: u64,
    /// default gate: fail on more than this % regression on any
    /// primary metric vs the previous ledger entry
    pub max_regression_pct: f64,
    /// per-metric override for `p95_ms`
    pub gate_p95_ms_pct: Option<f64>,
    /// per-metric override for `fn_percent`
    pub gate_fn_percent_pct: Option<f64>,
    /// per-metric override for `throughput_at_slo_eps`
    pub gate_throughput_pct: Option<f64>,
}

impl Default for ScorecardConfig {
    fn default() -> Self {
        ScorecardConfig {
            reps: 3,
            base_seed: 42,
            max_regression_pct: 5.0,
            gate_p95_ms_pct: None,
            gate_fn_percent_pct: None,
            gate_throughput_pct: None,
        }
    }
}

impl ScorecardConfig {
    /// Parse from TOML-subset text (section `[scorecard]`).
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ScorecardConfig::default();
        let section = "scorecard";
        if let Some(v) = doc.get_num(section, "reps") {
            cfg.reps = v as usize;
        }
        if let Some(v) = doc.get_num(section, "base_seed") {
            cfg.base_seed = v as u64;
        }
        if let Some(v) = doc.get_num(section, "max_regression_pct") {
            cfg.max_regression_pct = v;
        }
        if let Some(v) = doc.get_num(section, "gate_p95_ms_pct") {
            cfg.gate_p95_ms_pct = Some(v);
        }
        if let Some(v) = doc.get_num(section, "gate_fn_percent_pct") {
            cfg.gate_fn_percent_pct = Some(v);
        }
        if let Some(v) = doc.get_num(section, "gate_throughput_pct") {
            cfg.gate_throughput_pct = Some(v);
        }
        anyhow::ensure!(cfg.reps >= 1, "scorecard.reps must be at least 1");
        Ok(cfg)
    }

    /// Load from a file (missing file = defaults, so `scoreboard` runs
    /// without a config).
    pub fn from_file_or_default(path: &std::path::Path) -> crate::Result<Self> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::from_toml(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Ok(ScorecardConfig::default())
            }
            Err(e) => Err(e.into()),
        }
    }

    /// The regression tolerance (in %) gating `metric` (canonical
    /// primary-metric names; unknown metrics get the default).
    pub fn limit_pct_for(&self, metric: &str) -> f64 {
        let over = match metric {
            "p95_ms" => self.gate_p95_ms_pct,
            "fn_percent" => self.gate_fn_percent_pct,
            "throughput_at_slo_eps" => self.gate_throughput_pct,
            _ => None,
        };
        over.unwrap_or(self.max_regression_pct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            # pSPICE experiment
            [experiment]
            query = "q3"
            window = 1500
            pattern_n = 5
            dataset = "soccer"
            seed = 7
            events = 50000
            warmup = 20000
            rate = 1.4
            lb_ms = 1.0
            shedder = "pm-bl"
            weights = [1.0, 2.0]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.query, "q3");
        assert_eq!(cfg.pattern_n, 5);
        assert_eq!(cfg.dataset, DatasetKind::Soccer);
        assert_eq!(cfg.shedder, ShedderKind::PmBaseline);
        assert_eq!(cfg.weights, vec![1.0, 2.0]);
        assert!((cfg.rate - 1.4).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let cfg = ExperimentConfig::from_toml("[experiment]\nquery = \"q2\"\n").unwrap();
        assert_eq!(cfg.query, "q2");
        assert_eq!(cfg.rate, 1.2);
        assert_eq!(cfg.shedder, ShedderKind::PSpice);
        assert_eq!(cfg.model, ModelKind::Markov);
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.batch, 256);
    }

    #[test]
    fn model_kind_parses() {
        let cfg =
            ExperimentConfig::from_toml("[experiment]\nmodel = \"freq\"\n").unwrap();
        assert_eq!(cfg.model, ModelKind::Freq);
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel = \"magic\"\n").is_err());
    }

    #[test]
    fn shards_and_batch_parse() {
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\nshards = 4\nbatch = 128\n",
        )
        .unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.batch, 128);
    }

    #[test]
    fn rejects_bad_shedder() {
        assert!(
            ExperimentConfig::from_toml("[experiment]\nshedder = \"magic\"\n").is_err()
        );
    }

    #[test]
    fn realtime_keys_parse() {
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\noverload = \"measured\"\nsource = \"burst\"\n\
             ingest_capacity = 512\ningest_policy = \"block\"\nduration_ms = 250\n",
        )
        .unwrap();
        assert_eq!(cfg.overload, OverloadKind::Measured);
        assert_eq!(cfg.source, SourceKind::Burst);
        assert_eq!(cfg.ingest_capacity, 512);
        assert_eq!(cfg.ingest_policy, OverflowPolicy::Block);
        assert!((cfg.duration_ms - 250.0).abs() < 1e-12);
        // and the defaults stay on the batch plane
        let d = ExperimentConfig::default();
        assert_eq!(d.overload, OverloadKind::Predicted);
        assert_eq!(d.source, SourceKind::Trace);
        assert_eq!(d.ingest_policy, OverflowPolicy::DropOldest);
        assert!(ExperimentConfig::from_toml("[experiment]\noverload = \"psychic\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nsource = \"warp\"\n").is_err());
    }

    #[test]
    fn codec_key_parses() {
        let cfg = ExperimentConfig::from_toml("[experiment]\ncodec = \"csv\"\n").unwrap();
        assert_eq!(cfg.codec, WireCodec::Csv);
        assert_eq!(ExperimentConfig::default().codec, WireCodec::Lines);
        assert!(ExperimentConfig::from_toml("[experiment]\ncodec = \"json\"\n").is_err());
    }

    #[test]
    fn faults_key_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\nshards = 2\nfaults = \"kill:1@10,delay:0@5:2.5\"\n",
        )
        .unwrap();
        assert_eq!(cfg.faults, "kill:1@10,delay:0@5:2.5");
        assert_eq!(ExperimentConfig::default().faults, "");
        // a malformed spec fails at config load, not mid-run
        assert!(
            ExperimentConfig::from_toml("[experiment]\nfaults = \"kill:1\"\n").is_err()
        );
    }

    #[test]
    fn recovery_keys_parse() {
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\nshards = 4\ncheckpoint_every = 16\n\
             journal_cap = 20000\nworker_deadline_ms = 250\n",
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 16);
        assert_eq!(cfg.journal_cap, 20_000);
        assert!((cfg.worker_deadline_ms - 250.0).abs() < 1e-12);
        // defaults: checkpointing off, a bounded journal, no deadline
        let d = ExperimentConfig::default();
        assert_eq!(d.checkpoint_every, 0);
        assert_eq!(d.journal_cap, 8_192);
        assert_eq!(d.worker_deadline_ms, 0.0);
    }

    #[test]
    fn scorecard_section_parses() {
        let sc = ScorecardConfig::from_toml(
            "[scorecard]\nreps = 5\nbase_seed = 7\nmax_regression_pct = 3\n\
             gate_p95_ms_pct = 10\n",
        )
        .unwrap();
        assert_eq!(sc.reps, 5);
        assert_eq!(sc.base_seed, 7);
        assert!((sc.max_regression_pct - 3.0).abs() < 1e-12);
        // the override applies only to its metric
        assert!((sc.limit_pct_for("p95_ms") - 10.0).abs() < 1e-12);
        assert!((sc.limit_pct_for("fn_percent") - 3.0).abs() < 1e-12);
        assert!((sc.limit_pct_for("throughput_at_slo_eps") - 3.0).abs() < 1e-12);
        // defaults without a [scorecard] section
        let d = ScorecardConfig::from_toml("[experiment]\nquery = \"q1\"\n").unwrap();
        assert_eq!(d.reps, 3);
        assert!((d.limit_pct_for("p95_ms") - 5.0).abs() < 1e-12);
        assert!(ScorecardConfig::from_toml("[scorecard]\nreps = 0\n").is_err());
    }
}
