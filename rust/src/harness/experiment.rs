//! The three-phase experiment runner (paper §IV-A/§IV-B).
//!
//! 1. **Ground truth** — a fresh operator processes the entire trace
//!    without shedding or throttling; its complex events are the truth
//!    set and its mean per-event cost is the operator's capacity.
//! 2. **Calibrate + train** — a second operator streams the warm-up
//!    prefix below capacity ("we first stream events at event input
//!    rates which are less or equal to the maximum operator throughput
//!    until the model is built"): the latency regressions `f`/`g` are
//!    fitted and the Markov model is built through the model engine
//!    (AOT/PJRT or rust fallback).
//! 3. **Overloaded measurement** — the remaining events arrive at
//!    `rate × capacity` in virtual time; the shedder keeps the latency
//!    bound; completions are compared against the truth set.
//!
//! The measurement phase runs on a [`Pipeline`]: a single loop drives
//! every strategy through the batch-first
//! [`Shedder`](crate::shedding::Shedder) trait against the
//! [`OperatorState`](crate::operator::OperatorState) abstraction.
//! `shards = 1` uses the classic single-threaded operator with
//! per-event dispatch; `shards > 1` dispatches micro-batches of
//! `batch` events to the sharded worker runtime
//! ([`crate::runtime::sharded`]), the virtual clock advancing by the
//! slowest shard's batch cost (the parallel makespan).  Completions
//! merge deterministically, so QoR accounting is identical across
//! shard counts.

use crate::config::ExperimentConfig;
use crate::datasets::{BusGen, DatasetKind, SoccerGen, StockGen};
use crate::events::{Event, EventStream};
use crate::metrics::{LatencyTracker, QorAccounting};
use crate::model::plane::train_from_operator;
use crate::model::{ModelConfig, UtilityModel};
use crate::operator::Operator;
use crate::pipeline::Pipeline;
use crate::query::builtin;
use crate::query::Query;
use crate::shedding::OverloadDetector;
use crate::sim::RateSource;

/// Everything a figure driver needs from one run.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// configuration echo
    pub query: String,
    /// shedder used
    pub shedder: &'static str,
    /// worker shards used in the measurement phase
    pub shards: usize,
    /// weighted FN percentage vs ground truth
    pub fn_percent: f64,
    /// detected-but-not-true complex events (must be 0 for PM shedding)
    pub false_positives: usize,
    /// ground-truth complex events in scope
    pub truth_total: usize,
    /// ground-truth match probability (completions / PMs created)
    pub match_probability: f64,
    /// measured capacity (mean ns per event at steady state)
    pub capacity_ns: f64,
    /// latency trace of the measurement phase
    pub latency: LatencyTracker,
    /// shed time / operator busy time during measurement
    pub shed_overhead: f64,
    /// PMs dropped during measurement
    pub dropped_pms: u64,
    /// PMs lost to crashed shard workers (involuntary shed; 0 on
    /// healthy runs)
    pub dropped_pms_failure: u64,
    /// shard workers respawned after a failure during measurement
    pub recoveries: u64,
    /// PMs restored by checkpointed (snapshot + journal replay)
    /// recovery instead of being lost to `dropped_pms_failure`
    pub recovered_pms: u64,
    /// journaled events replayed into respawned workers
    pub replayed_events: u64,
    /// worker hangs detected by the dispatch deadline
    pub hangs_detected: u64,
    /// events dropped during measurement (E-BL)
    pub dropped_events: u64,
    /// model build wall-clock seconds (phase 2)
    pub model_build_secs: f64,
    /// model engine used ("pjrt-aot" or "rust-fallback")
    pub engine: &'static str,
    /// peak live PM count seen during measurement
    pub peak_pms: usize,
    /// drift-triggered model rebuilds during measurement (§III-D)
    pub retrains: u32,
    /// wall-clock events/s of the measurement phase (not virtual time)
    pub wall_events_per_sec: f64,
}

/// Build the query set for a configuration.
pub fn build_queries(cfg: &ExperimentConfig) -> crate::Result<Vec<Query>> {
    let mut queries = match cfg.query.as_str() {
        "q1" => builtin::q1(cfg.window).queries,
        "q2" => builtin::q2(cfg.window).queries,
        "q3" => builtin::q3(cfg.pattern_n, cfg.window).queries,
        "q4" => builtin::q4(cfg.pattern_n, cfg.window, cfg.slide).queries,
        "q1+q2" => {
            let mut qs = builtin::q1(cfg.window).queries;
            qs.extend(builtin::q2(cfg.window).queries);
            qs
        }
        other => anyhow::bail!("unknown query {other:?}"),
    };
    if !cfg.weights.is_empty() {
        anyhow::ensure!(
            cfg.weights.len() == queries.len(),
            "{} weights for {} queries",
            cfg.weights.len(),
            queries.len()
        );
        for (q, &w) in queries.iter_mut().zip(&cfg.weights) {
            q.weight = w;
        }
    }
    Ok(queries)
}

/// Generate the full event trace for a configuration.
pub fn build_trace(cfg: &ExperimentConfig) -> Vec<Event> {
    let total = (cfg.warmup + cfg.events) as usize;
    match cfg.dataset {
        DatasetKind::Stock => StockGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Soccer => SoccerGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Bus => BusGen::with_seed(cfg.seed).take_events(total),
    }
}

pub(crate) fn apply_cost_factors(op: &mut Operator, cfg: &ExperimentConfig) {
    if cfg.cost_factors.is_empty() {
        return;
    }
    assert_eq!(
        cfg.cost_factors.len(),
        op.cost.check_factor.len(),
        "cost_factors must match query count"
    );
    op.cost.check_factor.clone_from(&cfg.cost_factors);
}

/// Phase 1: ground truth + capacity.  Returns (truth accounting shell,
/// capacity ns/event, match probability).
fn ground_truth(
    cfg: &ExperimentConfig,
    queries: &[Query],
    trace: &[Event],
) -> (QorAccounting, f64, f64) {
    let mut op = Operator::new(queries.to_vec());
    apply_cost_factors(&mut op, cfg);
    op.obs.enabled = false; // no model learning on the truth run
    let weights: Vec<f64> = queries.iter().map(|q| q.weight).collect();
    let mut qor = QorAccounting::new(weights, cfg.warmup);
    let mut cost_sum = 0.0;
    let mut cost_n = 0u64;
    let skip = trace.len() / 10; // settle before measuring capacity
    for (i, e) in trace.iter().enumerate() {
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_truth(ce);
        }
        if i >= skip {
            cost_sum += out.cost_ns;
            cost_n += 1;
        }
    }
    let capacity = cost_sum / cost_n.max(1) as f64;
    (qor, capacity, op.match_probability())
}

/// Phase 2: calibrate the overload detector on the warm-up prefix and
/// build the utility model.  Returns the trained detector plus the
/// calibrated operator (whose observations feed the model builder).
pub(crate) fn calibrate(
    cfg: &ExperimentConfig,
    queries: &[Query],
    trace: &[Event],
) -> crate::Result<(Operator, OverloadDetector)> {
    let lb_ns = cfg.lb_ms * 1e6;
    let mut op = Operator::new(queries.to_vec());
    apply_cost_factors(&mut op, cfg);
    let mut detector = OverloadDetector::new(lb_ns, 0.02 * lb_ns);
    let warmup = (cfg.warmup as usize).min(trace.len());
    for e in &trace[..warmup] {
        let n_before = op.pm_count();
        let out = op.process_event(e);
        detector.observe_processing(n_before, out.cost_ns);
    }
    anyhow::ensure!(detector.fit(), "latency regression needs more warm-up");
    // seed g() with the cost model's shed cost shape; the shed decision
    // scans *cells*, not PMs, so the PM count n converts to its
    // expected cell count before pricing the scan — keeping the seeded
    // regression on the same axis as live observe_shedding() feedback
    for n in [100usize, 1_000, 5_000, 20_000, 50_000] {
        let cells = (n as f64 / crate::operator::EST_PMS_PER_CELL) as usize;
        detector.observe_shedding(n, op.cost.shed_ns(cells, n / 10));
    }
    detector.fit();
    Ok((op, detector))
}

/// Run one full experiment: ground truth, calibration, then the
/// [`Pipeline`]-driven overloaded measurement (any strategy, any shard
/// count — one code path).
pub fn run_experiment(cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let queries = build_queries(cfg)?;
    let trace = build_trace(cfg);
    let warmup = (cfg.warmup as usize).min(trace.len());

    // ---- phase 1: ground truth ------------------------------------
    let (mut qor, capacity_ns, match_probability) =
        ground_truth(cfg, &queries, &trace);

    // ---- phase 2: calibrate + train --------------------------------
    let (op, detector) = calibrate(cfg, &queries, &trace)?;
    // train through the model plane: --model picks the UtilityModel
    // backend (markov = the paper's Markov-reward builder, freq = the
    // frequency-only predictor)
    let mut model = cfg.model.build(ModelConfig::default());
    let tables = train_from_operator(model.as_mut(), &op)?;
    let model_build_secs = model.last_train_secs();
    let engine = model.engine();
    // only utility-ranking strategies get tables installed on the
    // state, and pSPICE--'s differ from the reporting build (no
    // processing-time term)
    let strategy_tables = if !cfg.shedder.needs_tables() {
        Vec::new()
    } else if !cfg.shedder.model_config().use_tau {
        let mut ablation = cfg.model.build(cfg.shedder.model_config());
        train_from_operator(ablation.as_mut(), &op)?
    } else {
        tables
    };
    // The pipeline owns its state and re-primes it from the warm-up
    // prefix below: one extra warm-up pass (~1/7 of the total work on
    // the default config) buys a single measurement code path for
    // every backend and byte-identical state to the calibrated
    // operator (event processing is deterministic).
    drop(op);

    // ---- phase 3: measurement through the pipeline -----------------
    let faults = crate::runtime::FaultPlan::parse(&cfg.faults)?;
    let mut pipe = Pipeline::builder()
        .queries(queries)
        .shedder(cfg.shedder)
        .fault_plan(faults)
        .checkpoint_every(cfg.checkpoint_every)
        .journal_cap(cfg.journal_cap)
        .worker_deadline_ms(cfg.worker_deadline_ms)
        .detector(detector)
        .tables(strategy_tables)
        .latency_bound_ms(cfg.lb_ms)
        .latency_stride((cfg.events / 2_000).max(1))
        .shards(cfg.shards)
        .batch(cfg.batch)
        .seed(cfg.seed)
        .key_slot(cfg.dataset.key_slot())
        .cost_factors(cfg.cost_factors.clone())
        .model(cfg.model)
        .retrain(cfg.retrain_every, cfg.drift_threshold)
        .arrivals(RateSource::from_capacity(capacity_ns, cfg.rate, 0.0))
        .source(trace[warmup..].to_vec())
        .build()?;
    // warm-up prefix below capacity (no latency accounting; warm-up
    // windows are out of QoR scope anyway)
    for ce in pipe.prime(&trace[..warmup]) {
        qor.add_detected(&ce);
    }
    let run = pipe.run_to_end()?;
    for ce in &run.completions {
        qor.add_detected(ce);
    }

    Ok(ExperimentResult {
        query: cfg.query.clone(),
        shedder: run.shedder,
        shards: run.shards,
        fn_percent: qor.fn_percent(),
        false_positives: qor.false_positives(),
        truth_total: qor.truth_total(),
        match_probability,
        capacity_ns,
        latency: run.latency,
        shed_overhead: run.shed_overhead,
        dropped_pms: run.totals.dropped_pms,
        dropped_pms_failure: run.totals.dropped_pms_failure,
        recoveries: run.recoveries,
        recovered_pms: run.totals.recovered_pms,
        replayed_events: run.totals.replayed_events,
        hangs_detected: run.totals.hangs_detected,
        dropped_events: run.totals.dropped_events,
        model_build_secs,
        engine,
        peak_pms: run.peak_pms,
        retrains: run.retrains,
        wall_events_per_sec: run.wall_events_per_sec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shedding::ShedderKind;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            query: "q4".into(),
            window: 2_000,
            pattern_n: 4,
            slide: 250,
            dataset: DatasetKind::Bus,
            seed: 3,
            events: 20_000,
            warmup: 20_000,
            rate: 1.4,
            lb_ms: 0.05,
            shedder: ShedderKind::PSpice,
            model: crate::model::ModelKind::Markov,
            weights: Vec::new(),
            cost_factors: Vec::new(),
            retrain_every: 0,
            drift_threshold: 0.01,
            shards: 1,
            batch: 256,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn pspice_run_end_to_end() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(res.truth_total > 0, "ground truth has complex events");
        assert!((0.0..=100.0).contains(&res.fn_percent));
        assert_eq!(res.false_positives, 0, "white-box shedding never lies");
        assert!(res.capacity_ns > 0.0);
        assert!(res.match_probability > 0.0 && res.match_probability < 1.0);
    }

    #[test]
    fn no_shedding_misses_nothing_without_overload() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 0.5; // under capacity
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.fn_percent, 0.0);
        assert_eq!(res.dropped_pms, 0);
    }

    #[test]
    fn overload_without_shedding_violates_bound() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 1.5;
        let res = run_experiment(&cfg).unwrap();
        // queue grows unboundedly: the bound must blow through
        assert!(res.latency.violation_rate() > 0.3, "rate={}", res.latency.violation_rate());
    }

    #[test]
    fn pspice_holds_the_bound_under_overload() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(
            res.latency.violation_rate() < 0.05,
            "violations={} max={}ns",
            res.latency.violation_rate(),
            res.latency.stats.max()
        );
        assert!(res.dropped_pms > 0, "overload forces drops");
    }

    #[test]
    fn pm_baseline_drops_more_quality() {
        let pspice = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::PmBaseline;
        let pmbl = run_experiment(&cfg).unwrap();
        assert_eq!(pmbl.false_positives, 0);
        // the headline claim, on a small workload: informed ≤ random
        assert!(
            pspice.fn_percent <= pmbl.fn_percent + 5.0,
            "pspice={:.1}% pm-bl={:.1}%",
            pspice.fn_percent,
            pmbl.fn_percent
        );
    }

    #[test]
    fn sharded_runs_match_truth_without_overload() {
        // with 2 shards at an under-capacity rate and no shedding, the
        // sharded runtime must miss nothing and invent nothing
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 0.5;
        cfg.shards = 2; // q4 is one query, but the runtime caps shards
        cfg.batch = 64;
        cfg.lb_ms = 2.0;
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.fn_percent, 0.0, "sharded run missed truth events");
        assert_eq!(res.false_positives, 0);
        // q4 is one query: the runtime caps the worker count and the
        // result reports what actually ran, not what was requested
        assert_eq!(res.shards, 1);
    }

    #[test]
    fn sharding_absorbs_an_overload_one_worker_cannot() {
        // rate 1.5× one core's capacity: unsharded+no-shedding violates
        // the bound massively (see overload_without_shedding test); four
        // shards on the two-query q1 workload keep the queue bounded
        let mut cfg = tiny_cfg();
        cfg.query = "q1".into();
        cfg.dataset = DatasetKind::Stock;
        cfg.window = 2_000;
        cfg.shedder = ShedderKind::None;
        cfg.rate = 1.5;
        cfg.batch = 32;
        cfg.lb_ms = 2.0;
        cfg.shards = 2;
        let sharded = run_experiment(&cfg).unwrap();
        cfg.shards = 1;
        let single = run_experiment(&cfg).unwrap();
        assert!(
            sharded.latency.violation_rate() < single.latency.violation_rate(),
            "sharded={} single={}",
            sharded.latency.violation_rate(),
            single.latency.violation_rate()
        );
    }

    #[test]
    fn sharded_pspice_sheds_and_stays_sound() {
        let mut cfg = tiny_cfg();
        cfg.shards = 2;
        cfg.batch = 32;
        cfg.lb_ms = 0.5;
        cfg.rate = 3.0; // overload even a 2-way split of one query
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.false_positives, 0, "PM shedding must not invent CEs");
        assert!((0.0..=100.0).contains(&res.fn_percent));
    }

    #[test]
    fn sharded_pspice_minus_runs_too() {
        // the redesign lifted the old "pspice-- needs shards == 1"
        // restriction: the ablation's tables install like any others
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::PSpiceMinus;
        cfg.shards = 2;
        cfg.batch = 64;
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.shedder, "pspice--");
        assert_eq!(res.false_positives, 0);
        assert!((0.0..=100.0).contains(&res.fn_percent));
    }
}
