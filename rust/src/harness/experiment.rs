//! The three-phase experiment runner (paper §IV-A/§IV-B).
//!
//! 1. **Ground truth** — a fresh operator processes the entire trace
//!    without shedding or throttling; its complex events are the truth
//!    set and its mean per-event cost is the operator's capacity.
//! 2. **Calibrate + train** — a second operator streams the warm-up
//!    prefix below capacity ("we first stream events at event input
//!    rates which are less or equal to the maximum operator throughput
//!    until the model is built"): the latency regressions `f`/`g` are
//!    fitted and the Markov model is built through the model engine
//!    (AOT/PJRT or rust fallback).
//! 3. **Overloaded measurement** — the remaining events arrive at
//!    `rate × capacity` in virtual time; the shedder keeps the latency
//!    bound; completions are compared against the truth set.

use crate::config::ExperimentConfig;
use crate::datasets::{BusGen, DatasetKind, SoccerGen, StockGen};
use crate::events::{Event, EventStream};
use crate::metrics::{LatencyTracker, QorAccounting};
use crate::model::{ModelBuilder, ModelConfig};
use crate::operator::Operator;
use crate::query::builtin;
use crate::query::Query;
use crate::shedding::{
    EventBaselineShedder, NoShedder, OverloadDetector, PSpiceShedder,
    PmBaselineShedder, Shedder, ShedderKind,
};
use crate::sim::{RateSource, SimClock};

/// Everything a figure driver needs from one run.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// configuration echo
    pub query: String,
    /// shedder used
    pub shedder: &'static str,
    /// weighted FN percentage vs ground truth
    pub fn_percent: f64,
    /// detected-but-not-true complex events (must be 0 for PM shedding)
    pub false_positives: usize,
    /// ground-truth complex events in scope
    pub truth_total: usize,
    /// ground-truth match probability (completions / PMs created)
    pub match_probability: f64,
    /// measured capacity (mean ns per event at steady state)
    pub capacity_ns: f64,
    /// latency trace of the measurement phase
    pub latency: LatencyTracker,
    /// shed time / operator busy time during measurement
    pub shed_overhead: f64,
    /// PMs dropped during measurement
    pub dropped_pms: u64,
    /// events dropped during measurement (E-BL)
    pub dropped_events: u64,
    /// model build wall-clock seconds (phase 2)
    pub model_build_secs: f64,
    /// model engine used ("pjrt-aot" or "rust-fallback")
    pub engine: &'static str,
    /// peak live PM count seen during measurement
    pub peak_pms: usize,
    /// drift-triggered model rebuilds during measurement (§III-D)
    pub retrains: u32,
}

/// Build the query set + the E-BL key slot for a configuration.
pub fn build_queries(cfg: &ExperimentConfig) -> crate::Result<(Vec<Query>, usize)> {
    let (mut queries, key_slot) = match cfg.query.as_str() {
        "q1" => (builtin::q1(cfg.window).queries, crate::datasets::stock::A_SYMBOL),
        "q2" => (builtin::q2(cfg.window).queries, crate::datasets::stock::A_SYMBOL),
        "q3" => (
            builtin::q3(cfg.pattern_n, cfg.window).queries,
            crate::datasets::soccer::A_PLAYER,
        ),
        "q4" => (
            builtin::q4(cfg.pattern_n, cfg.window, cfg.slide).queries,
            crate::datasets::bus::A_BUS,
        ),
        "q1+q2" => {
            let mut qs = builtin::q1(cfg.window).queries;
            qs.extend(builtin::q2(cfg.window).queries);
            (qs, crate::datasets::stock::A_SYMBOL)
        }
        other => anyhow::bail!("unknown query {other:?}"),
    };
    if !cfg.weights.is_empty() {
        anyhow::ensure!(
            cfg.weights.len() == queries.len(),
            "{} weights for {} queries",
            cfg.weights.len(),
            queries.len()
        );
        for (q, &w) in queries.iter_mut().zip(&cfg.weights) {
            q.weight = w;
        }
    }
    Ok((queries, key_slot))
}

/// Generate the full event trace for a configuration.
pub fn build_trace(cfg: &ExperimentConfig) -> Vec<Event> {
    let total = (cfg.warmup + cfg.events) as usize;
    match cfg.dataset {
        DatasetKind::Stock => StockGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Soccer => SoccerGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Bus => BusGen::with_seed(cfg.seed).take_events(total),
    }
}

fn apply_cost_factors(op: &mut Operator, cfg: &ExperimentConfig) {
    if cfg.cost_factors.is_empty() {
        return;
    }
    assert_eq!(
        cfg.cost_factors.len(),
        op.cost.check_factor.len(),
        "cost_factors must match query count"
    );
    op.cost.check_factor.clone_from(&cfg.cost_factors);
}

/// Phase 1: ground truth + capacity.  Returns (truth accounting shell,
/// capacity ns/event, match probability).
fn ground_truth(
    cfg: &ExperimentConfig,
    queries: &[Query],
    trace: &[Event],
) -> (QorAccounting, f64, f64) {
    let mut op = Operator::new(queries.to_vec());
    apply_cost_factors(&mut op, cfg);
    op.obs.enabled = false; // no model learning on the truth run
    let weights: Vec<f64> = queries.iter().map(|q| q.weight).collect();
    let mut qor = QorAccounting::new(weights, cfg.warmup);
    let mut cost_sum = 0.0;
    let mut cost_n = 0u64;
    let skip = trace.len() / 10; // settle before measuring capacity
    for (i, e) in trace.iter().enumerate() {
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_truth(ce);
        }
        if i >= skip {
            cost_sum += out.cost_ns;
            cost_n += 1;
        }
    }
    let capacity = cost_sum / cost_n.max(1) as f64;
    (qor, capacity, op.match_probability())
}

/// Run one full experiment.
pub fn run_experiment(cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let (queries, key_slot) = build_queries(cfg)?;
    let trace = build_trace(cfg);
    let lb_ns = cfg.lb_ms * 1e6;

    // ---- phase 1: ground truth ------------------------------------
    let (mut qor, capacity_ns, match_probability) =
        ground_truth(cfg, &queries, &trace);

    // ---- phase 2: calibrate + train --------------------------------
    let mut op = Operator::new(queries.clone());
    apply_cost_factors(&mut op, cfg);
    let mut detector = OverloadDetector::new(lb_ns, 0.02 * lb_ns);
    let warmup = cfg.warmup as usize;
    for e in &trace[..warmup.min(trace.len())] {
        let n_before = op.pm_count();
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_detected(ce); // warm-up completions are out of scope anyway
        }
        detector.observe_processing(n_before, out.cost_ns);
    }
    anyhow::ensure!(detector.fit(), "latency regression needs more warm-up");
    // seed g() with the cost model's shed cost shape
    for n in [100usize, 1_000, 5_000, 20_000, 50_000] {
        detector.observe_shedding(n, op.cost.shed_ns(n, n / 10));
    }
    detector.fit();

    let mut builder = ModelBuilder::with_auto_engine(ModelConfig::default());
    let tables = builder.build(&op)?;
    let model_build_secs = builder.last_build_secs;
    let engine = builder.engine_name();
    // keep capturing observations only if drift-triggered retraining is
    // on (§III-D); otherwise stop paying for capture
    let retraining = cfg.retrain_every > 0;
    op.obs.enabled = retraining;
    let mut drift = retraining
        .then(|| crate::model::DriftDetector::snapshot(&op.obs, cfg.drift_threshold));

    let mut shedder: Box<dyn Shedder> = match cfg.shedder {
        ShedderKind::None => Box::new(NoShedder),
        ShedderKind::PSpice => Box::new(PSpiceShedder::new(detector.clone(), tables)),
        ShedderKind::PSpiceMinus => {
            let mut b = ModelBuilder::with_auto_engine(ModelConfig {
                use_tau: false,
                ..ModelConfig::default()
            });
            // rebuild tables without the processing-time term
            op.obs.enabled = true;
            let t = b.build(&op)?;
            op.obs.enabled = false;
            Box::new(PSpiceShedder::new(detector.clone(), t))
        }
        ShedderKind::PmBaseline => {
            Box::new(PmBaselineShedder::new(detector.clone(), cfg.seed ^ 0xBE11))
        }
        ShedderKind::EventBaseline => Box::new(EventBaselineShedder::new(
            detector.clone(),
            key_slot,
            &op.queries,
            cfg.seed ^ 0xEB1,
        )),
    };

    // ---- phase 3: overloaded measurement ---------------------------
    let mut clock = SimClock::new();
    let source = RateSource::from_capacity(capacity_ns, cfg.rate, 0.0);
    let mut latency = LatencyTracker::new(lb_ns, (cfg.events / 2_000).max(1));
    let mut shed_ns = 0.0;
    let mut busy_ns = 0.0;
    let mut dropped_pms = 0u64;
    let mut dropped_events = 0u64;
    let mut peak_pms = 0usize;
    let mut retrains = 0u32;

    for (i, e) in trace[warmup.min(trace.len())..].iter().enumerate() {
        let arrival = source.arrival_ns(i as u64);
        let l_q = clock.begin_service(arrival);
        let rep = shedder.on_event(e, l_q, &mut op);
        clock.advance(rep.cost_ns);
        shed_ns += rep.cost_ns;
        busy_ns += rep.cost_ns;
        dropped_pms += rep.dropped_pms as u64;
        let out = if rep.dropped_event {
            dropped_events += 1;
            op.process_bookkeeping(e)
        } else {
            op.process_event(e)
        };
        clock.advance(out.cost_ns);
        busy_ns += out.cost_ns;
        for ce in &out.completions {
            qor.add_detected(ce);
        }
        latency.record(clock.now_ns(), clock.now_ns() - arrival);
        peak_pms = peak_pms.max(op.pm_count());
        // §III-D: periodic drift check -> rebuild the model.  Building
        // the candidate matrix is cheap (counts -> probabilities); the
        // full table rebuild runs only on actual drift.
        if retraining && (i as u64 + 1) % cfg.retrain_every == 0 {
            if let Some(d) = &drift {
                let (_mse, drifted) = d.check(&op.obs);
                if drifted {
                    let fresh = builder.build(&op)?;
                    shedder.update_tables(fresh);
                    drift = Some(crate::model::DriftDetector::snapshot(
                        &op.obs,
                        cfg.drift_threshold,
                    ));
                    retrains += 1;
                }
            }
        }
    }

    Ok(ExperimentResult {
        query: cfg.query.clone(),
        shedder: shedder.name(),
        fn_percent: qor.fn_percent(),
        false_positives: qor.false_positives(),
        truth_total: qor.truth_total(),
        match_probability,
        capacity_ns,
        latency,
        shed_overhead: if busy_ns > 0.0 { shed_ns / busy_ns } else { 0.0 },
        dropped_pms,
        dropped_events,
        model_build_secs,
        engine,
        peak_pms,
        retrains,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            query: "q4".into(),
            window: 2_000,
            pattern_n: 4,
            slide: 250,
            dataset: DatasetKind::Bus,
            seed: 3,
            events: 20_000,
            warmup: 20_000,
            rate: 1.4,
            lb_ms: 0.05,
            shedder: ShedderKind::PSpice,
            weights: Vec::new(),
            cost_factors: Vec::new(),
            retrain_every: 0,
            drift_threshold: 0.01,
        }
    }

    #[test]
    fn pspice_run_end_to_end() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(res.truth_total > 0, "ground truth has complex events");
        assert!((0.0..=100.0).contains(&res.fn_percent));
        assert_eq!(res.false_positives, 0, "white-box shedding never lies");
        assert!(res.capacity_ns > 0.0);
        assert!(res.match_probability > 0.0 && res.match_probability < 1.0);
    }

    #[test]
    fn no_shedding_misses_nothing_without_overload() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 0.5; // under capacity
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.fn_percent, 0.0);
        assert_eq!(res.dropped_pms, 0);
    }

    #[test]
    fn overload_without_shedding_violates_bound() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 1.5;
        let res = run_experiment(&cfg).unwrap();
        // queue grows unboundedly: the bound must blow through
        assert!(res.latency.violation_rate() > 0.3, "rate={}", res.latency.violation_rate());
    }

    #[test]
    fn pspice_holds_the_bound_under_overload() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(
            res.latency.violation_rate() < 0.05,
            "violations={} max={}ns",
            res.latency.violation_rate(),
            res.latency.stats.max()
        );
        assert!(res.dropped_pms > 0, "overload forces drops");
    }

    #[test]
    fn pm_baseline_drops_more_quality() {
        let pspice = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::PmBaseline;
        let pmbl = run_experiment(&cfg).unwrap();
        assert_eq!(pmbl.false_positives, 0);
        // the headline claim, on a small workload: informed ≤ random
        assert!(
            pspice.fn_percent <= pmbl.fn_percent + 5.0,
            "pspice={:.1}% pm-bl={:.1}%",
            pspice.fn_percent,
            pmbl.fn_percent
        );
    }
}
