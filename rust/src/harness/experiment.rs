//! The three-phase experiment runner (paper §IV-A/§IV-B).
//!
//! 1. **Ground truth** — a fresh operator processes the entire trace
//!    without shedding or throttling; its complex events are the truth
//!    set and its mean per-event cost is the operator's capacity.
//! 2. **Calibrate + train** — a second operator streams the warm-up
//!    prefix below capacity ("we first stream events at event input
//!    rates which are less or equal to the maximum operator throughput
//!    until the model is built"): the latency regressions `f`/`g` are
//!    fitted and the Markov model is built through the model engine
//!    (AOT/PJRT or rust fallback).
//! 3. **Overloaded measurement** — the remaining events arrive at
//!    `rate × capacity` in virtual time; the shedder keeps the latency
//!    bound; completions are compared against the truth set.
//!
//! With `shards > 1` the measurement phase runs on the sharded operator
//! runtime ([`crate::runtime::sharded`]): events are dispatched in
//! micro-batches of `batch` events to every worker shard, the virtual
//! clock advances by the slowest shard's batch cost (the parallel
//! makespan), and the shedders use their shard-aware batch entry points
//! (one global ρ, k-way-merged victims).  Completions are merged
//! deterministically, so QoR accounting is identical to the
//! single-threaded path.

use crate::config::ExperimentConfig;
use crate::datasets::{BusGen, DatasetKind, SoccerGen, StockGen};
use crate::events::{Event, EventStream};
use crate::metrics::{LatencyTracker, QorAccounting, Throughput};
use crate::model::{ModelBuilder, ModelConfig, UtilityTable};
use crate::nfa::CompiledQuery;
use crate::operator::Operator;
use crate::query::builtin;
use crate::query::Query;
use crate::runtime::ShardedOperator;
use crate::shedding::{
    EventBaselineShedder, NoShedder, OverloadDetector, PSpiceShedder,
    PmBaselineShedder, ShedReport, Shedder, ShedderKind,
};
use crate::sim::{RateSource, SimClock};

/// Everything a figure driver needs from one run.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// configuration echo
    pub query: String,
    /// shedder used
    pub shedder: &'static str,
    /// worker shards used in the measurement phase
    pub shards: usize,
    /// weighted FN percentage vs ground truth
    pub fn_percent: f64,
    /// detected-but-not-true complex events (must be 0 for PM shedding)
    pub false_positives: usize,
    /// ground-truth complex events in scope
    pub truth_total: usize,
    /// ground-truth match probability (completions / PMs created)
    pub match_probability: f64,
    /// measured capacity (mean ns per event at steady state)
    pub capacity_ns: f64,
    /// latency trace of the measurement phase
    pub latency: LatencyTracker,
    /// shed time / operator busy time during measurement
    pub shed_overhead: f64,
    /// PMs dropped during measurement
    pub dropped_pms: u64,
    /// events dropped during measurement (E-BL)
    pub dropped_events: u64,
    /// model build wall-clock seconds (phase 2)
    pub model_build_secs: f64,
    /// model engine used ("pjrt-aot" or "rust-fallback")
    pub engine: &'static str,
    /// peak live PM count seen during measurement
    pub peak_pms: usize,
    /// drift-triggered model rebuilds during measurement (§III-D)
    pub retrains: u32,
    /// wall-clock events/s of the measurement phase (not virtual time)
    pub wall_events_per_sec: f64,
}

/// Build the query set + the E-BL key slot for a configuration.
pub fn build_queries(cfg: &ExperimentConfig) -> crate::Result<(Vec<Query>, usize)> {
    let (mut queries, key_slot) = match cfg.query.as_str() {
        "q1" => (builtin::q1(cfg.window).queries, crate::datasets::stock::A_SYMBOL),
        "q2" => (builtin::q2(cfg.window).queries, crate::datasets::stock::A_SYMBOL),
        "q3" => (
            builtin::q3(cfg.pattern_n, cfg.window).queries,
            crate::datasets::soccer::A_PLAYER,
        ),
        "q4" => (
            builtin::q4(cfg.pattern_n, cfg.window, cfg.slide).queries,
            crate::datasets::bus::A_BUS,
        ),
        "q1+q2" => {
            let mut qs = builtin::q1(cfg.window).queries;
            qs.extend(builtin::q2(cfg.window).queries);
            (qs, crate::datasets::stock::A_SYMBOL)
        }
        other => anyhow::bail!("unknown query {other:?}"),
    };
    if !cfg.weights.is_empty() {
        anyhow::ensure!(
            cfg.weights.len() == queries.len(),
            "{} weights for {} queries",
            cfg.weights.len(),
            queries.len()
        );
        for (q, &w) in queries.iter_mut().zip(&cfg.weights) {
            q.weight = w;
        }
    }
    Ok((queries, key_slot))
}

/// Generate the full event trace for a configuration.
pub fn build_trace(cfg: &ExperimentConfig) -> Vec<Event> {
    let total = (cfg.warmup + cfg.events) as usize;
    match cfg.dataset {
        DatasetKind::Stock => StockGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Soccer => SoccerGen::with_seed(cfg.seed).take_events(total),
        DatasetKind::Bus => BusGen::with_seed(cfg.seed).take_events(total),
    }
}

fn apply_cost_factors(op: &mut Operator, cfg: &ExperimentConfig) {
    if cfg.cost_factors.is_empty() {
        return;
    }
    assert_eq!(
        cfg.cost_factors.len(),
        op.cost.check_factor.len(),
        "cost_factors must match query count"
    );
    op.cost.check_factor.clone_from(&cfg.cost_factors);
}

/// Phase 1: ground truth + capacity.  Returns (truth accounting shell,
/// capacity ns/event, match probability).
fn ground_truth(
    cfg: &ExperimentConfig,
    queries: &[Query],
    trace: &[Event],
) -> (QorAccounting, f64, f64) {
    let mut op = Operator::new(queries.to_vec());
    apply_cost_factors(&mut op, cfg);
    op.obs.enabled = false; // no model learning on the truth run
    let weights: Vec<f64> = queries.iter().map(|q| q.weight).collect();
    let mut qor = QorAccounting::new(weights, cfg.warmup);
    let mut cost_sum = 0.0;
    let mut cost_n = 0u64;
    let skip = trace.len() / 10; // settle before measuring capacity
    for (i, e) in trace.iter().enumerate() {
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_truth(ce);
        }
        if i >= skip {
            cost_sum += out.cost_ns;
            cost_n += 1;
        }
    }
    let capacity = cost_sum / cost_n.max(1) as f64;
    (qor, capacity, op.match_probability())
}

/// Everything the measurement phase produces (both runtimes).
struct Measurement {
    latency: LatencyTracker,
    shed_overhead: f64,
    dropped_pms: u64,
    dropped_events: u64,
    peak_pms: usize,
    retrains: u32,
    shedder: &'static str,
    /// worker shards that actually ran (the runtime caps the requested
    /// count at the query count)
    shards: usize,
    wall_events_per_sec: f64,
}

/// Phase 3 on the sharded runtime.
#[allow(clippy::too_many_arguments)]
fn measure_sharded(
    cfg: &ExperimentConfig,
    queries: &[Query],
    trace: &[Event],
    warmup: usize,
    capacity_ns: f64,
    detector: &OverloadDetector,
    tables: &[UtilityTable],
    key_slot: usize,
    qor: &mut QorAccounting,
) -> crate::Result<Measurement> {
    anyhow::ensure!(
        cfg.retrain_every == 0,
        "drift retraining is not yet supported with shards > 1"
    );
    let lb_ns = cfg.lb_ms * 1e6;
    let batch = cfg.batch.max(1);
    let mut sop = ShardedOperator::new(queries.to_vec(), cfg.shards);
    if !cfg.cost_factors.is_empty() {
        sop.set_cost_factors(&cfg.cost_factors);
    }
    sop.set_obs_enabled(false);

    let mut pspice = None;
    let mut pmbl = None;
    let mut ebl = None;
    match cfg.shedder {
        ShedderKind::None => {}
        ShedderKind::PSpice => {
            sop.set_tables(tables);
            pspice = Some(PSpiceShedder::new(detector.clone(), Vec::new()));
        }
        ShedderKind::PSpiceMinus => {
            anyhow::bail!("pspice-- is not yet supported with shards > 1")
        }
        ShedderKind::PmBaseline => {
            pmbl = Some(PmBaselineShedder::new(detector.clone(), cfg.seed ^ 0xBE11));
        }
        ShedderKind::EventBaseline => {
            let compiled: Vec<CompiledQuery> = queries
                .iter()
                .cloned()
                .map(CompiledQuery::compile)
                .collect();
            ebl = Some(EventBaselineShedder::new(
                detector.clone(),
                key_slot,
                &compiled,
                cfg.seed ^ 0xEB1,
            ));
        }
    }

    // prime the sharded state with the warm-up prefix (below capacity,
    // no latency accounting; warm-up windows are out of QoR scope)
    for chunk in trace[..warmup.min(trace.len())].chunks(batch) {
        for ce in &sop.process_batch(chunk).completions {
            qor.add_detected(ce);
        }
    }

    let mut clock = SimClock::new();
    let source = RateSource::from_capacity(capacity_ns, cfg.rate, 0.0);
    let mut latency = LatencyTracker::new(lb_ns, (cfg.events / 2_000).max(1));
    let mut shed_ns = 0.0;
    let mut busy_ns = 0.0;
    let mut dropped_pms = 0u64;
    let mut dropped_events = 0u64;
    let mut peak_pms = 0usize;
    let measure = &trace[warmup.min(trace.len())..];
    let wall_start = std::time::Instant::now();
    let mut idx = 0u64;
    for chunk in measure.chunks(batch) {
        let first_arrival = source.arrival_ns(idx);
        let last_arrival = source.arrival_ns(idx + chunk.len() as u64 - 1);
        // micro-batching: the batch starts service once its last event
        // has arrived (or later if the shards are still busy)
        clock.begin_service(last_arrival);
        let l_q = (clock.now_ns() - first_arrival).max(0.0);
        let mut mask = None;
        let rep = if let Some(p) = pspice.as_mut() {
            p.on_batch(l_q, &mut sop)
        } else if let Some(b) = pmbl.as_mut() {
            b.on_batch(l_q, &mut sop)
        } else if let Some(e) = ebl.as_mut() {
            let (m, dropped, cost_ns) = e.decide_batch(l_q, &sop, chunk);
            dropped_events += dropped;
            mask = Some(m);
            ShedReport {
                dropped_pms: 0,
                dropped_event: false,
                cost_ns,
            }
        } else {
            ShedReport::default()
        };
        clock.advance(rep.cost_ns);
        shed_ns += rep.cost_ns;
        busy_ns += rep.cost_ns;
        dropped_pms += rep.dropped_pms as u64;
        let out = match &mask {
            Some(m) => sop.process_batch_masked(chunk, m),
            None => sop.process_batch(chunk),
        };
        // the shards run in parallel: virtual time advances by the
        // slowest shard's batch cost
        clock.advance(out.cost_ns_max);
        busy_ns += out.cost_ns_max;
        for ce in &out.completions {
            qor.add_detected(ce);
        }
        let end = clock.now_ns();
        for j in 0..chunk.len() as u64 {
            latency.record(end, end - source.arrival_ns(idx + j));
        }
        peak_pms = peak_pms.max(sop.pm_count());
        idx += chunk.len() as u64;
    }
    let mut wall = Throughput::new();
    wall.record(measure.len() as u64, wall_start.elapsed().as_secs_f64());

    Ok(Measurement {
        latency,
        shed_overhead: if busy_ns > 0.0 { shed_ns / busy_ns } else { 0.0 },
        dropped_pms,
        dropped_events,
        peak_pms,
        retrains: 0,
        shedder: cfg.shedder.name(),
        shards: sop.n_shards(),
        wall_events_per_sec: wall.events_per_sec(),
    })
}

/// Run one full experiment.
pub fn run_experiment(cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let (queries, key_slot) = build_queries(cfg)?;
    let trace = build_trace(cfg);
    let lb_ns = cfg.lb_ms * 1e6;

    // ---- phase 1: ground truth ------------------------------------
    let (mut qor, capacity_ns, match_probability) =
        ground_truth(cfg, &queries, &trace);

    // ---- phase 2: calibrate + train --------------------------------
    let mut op = Operator::new(queries.clone());
    apply_cost_factors(&mut op, cfg);
    let mut detector = OverloadDetector::new(lb_ns, 0.02 * lb_ns);
    let warmup = cfg.warmup as usize;
    for e in &trace[..warmup.min(trace.len())] {
        let n_before = op.pm_count();
        let out = op.process_event(e);
        for ce in &out.completions {
            qor.add_detected(ce); // warm-up completions are out of scope anyway
        }
        detector.observe_processing(n_before, out.cost_ns);
    }
    anyhow::ensure!(detector.fit(), "latency regression needs more warm-up");
    // seed g() with the cost model's shed cost shape
    for n in [100usize, 1_000, 5_000, 20_000, 50_000] {
        detector.observe_shedding(n, op.cost.shed_ns(n, n / 10));
    }
    detector.fit();

    let mut builder = ModelBuilder::with_auto_engine(ModelConfig::default());
    let tables = builder.build(&op)?;
    let model_build_secs = builder.last_build_secs;
    let engine = builder.engine_name();

    // ---- phase 3: measurement (sharded or single-threaded) ---------
    let m = if cfg.shards > 1 {
        measure_sharded(
            cfg,
            &queries,
            &trace,
            warmup,
            capacity_ns,
            &detector,
            &tables,
            key_slot,
            &mut qor,
        )?
    } else {
        measure_single(
            cfg,
            &trace,
            capacity_ns,
            op,
            builder,
            detector,
            tables,
            key_slot,
            &mut qor,
        )?
    };

    Ok(ExperimentResult {
        query: cfg.query.clone(),
        shedder: m.shedder,
        shards: m.shards,
        fn_percent: qor.fn_percent(),
        false_positives: qor.false_positives(),
        truth_total: qor.truth_total(),
        match_probability,
        capacity_ns,
        latency: m.latency,
        shed_overhead: m.shed_overhead,
        dropped_pms: m.dropped_pms,
        dropped_events: m.dropped_events,
        model_build_secs,
        engine,
        peak_pms: m.peak_pms,
        retrains: m.retrains,
        wall_events_per_sec: m.wall_events_per_sec,
    })
}

/// Phase 3 on the classic single-threaded operator (carried over from
/// phase 2 with its calibrated state).
#[allow(clippy::too_many_arguments)]
fn measure_single(
    cfg: &ExperimentConfig,
    trace: &[Event],
    capacity_ns: f64,
    mut op: Operator,
    mut builder: ModelBuilder,
    detector: OverloadDetector,
    tables: Vec<UtilityTable>,
    key_slot: usize,
    qor: &mut QorAccounting,
) -> crate::Result<Measurement> {
    let lb_ns = cfg.lb_ms * 1e6;
    let warmup = cfg.warmup as usize;

    // keep capturing observations only if drift-triggered retraining is
    // on (§III-D); otherwise stop paying for capture
    let retraining = cfg.retrain_every > 0;
    op.obs.enabled = retraining;
    let mut drift = retraining
        .then(|| crate::model::DriftDetector::snapshot(&op.obs, cfg.drift_threshold));

    let mut shedder: Box<dyn Shedder> = match cfg.shedder {
        ShedderKind::None => Box::new(NoShedder),
        ShedderKind::PSpice => Box::new(PSpiceShedder::new(detector.clone(), tables)),
        ShedderKind::PSpiceMinus => {
            let mut b = ModelBuilder::with_auto_engine(ModelConfig {
                use_tau: false,
                ..ModelConfig::default()
            });
            // rebuild tables without the processing-time term
            op.obs.enabled = true;
            let t = b.build(&op)?;
            op.obs.enabled = false;
            Box::new(PSpiceShedder::new(detector.clone(), t))
        }
        ShedderKind::PmBaseline => {
            Box::new(PmBaselineShedder::new(detector.clone(), cfg.seed ^ 0xBE11))
        }
        ShedderKind::EventBaseline => Box::new(EventBaselineShedder::new(
            detector.clone(),
            key_slot,
            &op.queries,
            cfg.seed ^ 0xEB1,
        )),
    };

    // ---- phase 3: overloaded measurement ---------------------------
    let mut clock = SimClock::new();
    let source = RateSource::from_capacity(capacity_ns, cfg.rate, 0.0);
    let mut latency = LatencyTracker::new(lb_ns, (cfg.events / 2_000).max(1));
    let mut shed_ns = 0.0;
    let mut busy_ns = 0.0;
    let mut dropped_pms = 0u64;
    let mut dropped_events = 0u64;
    let mut peak_pms = 0usize;
    let mut retrains = 0u32;
    let wall_start = std::time::Instant::now();
    let measured = trace.len() - warmup.min(trace.len());

    for (i, e) in trace[warmup.min(trace.len())..].iter().enumerate() {
        let arrival = source.arrival_ns(i as u64);
        let l_q = clock.begin_service(arrival);
        let rep = shedder.on_event(e, l_q, &mut op);
        clock.advance(rep.cost_ns);
        shed_ns += rep.cost_ns;
        busy_ns += rep.cost_ns;
        dropped_pms += rep.dropped_pms as u64;
        let out = if rep.dropped_event {
            dropped_events += 1;
            op.process_bookkeeping(e)
        } else {
            op.process_event(e)
        };
        clock.advance(out.cost_ns);
        busy_ns += out.cost_ns;
        for ce in &out.completions {
            qor.add_detected(ce);
        }
        latency.record(clock.now_ns(), clock.now_ns() - arrival);
        peak_pms = peak_pms.max(op.pm_count());
        // §III-D: periodic drift check -> rebuild the model.  Building
        // the candidate matrix is cheap (counts -> probabilities); the
        // full table rebuild runs only on actual drift.
        if retraining && (i as u64 + 1) % cfg.retrain_every == 0 {
            if let Some(d) = &drift {
                let (_mse, drifted) = d.check(&op.obs);
                if drifted {
                    let fresh = builder.build(&op)?;
                    shedder.update_tables(fresh);
                    drift = Some(crate::model::DriftDetector::snapshot(
                        &op.obs,
                        cfg.drift_threshold,
                    ));
                    retrains += 1;
                }
            }
        }
    }
    let mut wall = Throughput::new();
    wall.record(measured as u64, wall_start.elapsed().as_secs_f64());

    Ok(Measurement {
        latency,
        shed_overhead: if busy_ns > 0.0 { shed_ns / busy_ns } else { 0.0 },
        dropped_pms,
        dropped_events,
        peak_pms,
        retrains,
        shedder: shedder.name(),
        shards: 1,
        wall_events_per_sec: wall.events_per_sec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            query: "q4".into(),
            window: 2_000,
            pattern_n: 4,
            slide: 250,
            dataset: DatasetKind::Bus,
            seed: 3,
            events: 20_000,
            warmup: 20_000,
            rate: 1.4,
            lb_ms: 0.05,
            shedder: ShedderKind::PSpice,
            weights: Vec::new(),
            cost_factors: Vec::new(),
            retrain_every: 0,
            drift_threshold: 0.01,
            shards: 1,
            batch: 256,
        }
    }

    #[test]
    fn pspice_run_end_to_end() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(res.truth_total > 0, "ground truth has complex events");
        assert!((0.0..=100.0).contains(&res.fn_percent));
        assert_eq!(res.false_positives, 0, "white-box shedding never lies");
        assert!(res.capacity_ns > 0.0);
        assert!(res.match_probability > 0.0 && res.match_probability < 1.0);
    }

    #[test]
    fn no_shedding_misses_nothing_without_overload() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 0.5; // under capacity
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.fn_percent, 0.0);
        assert_eq!(res.dropped_pms, 0);
    }

    #[test]
    fn overload_without_shedding_violates_bound() {
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 1.5;
        let res = run_experiment(&cfg).unwrap();
        // queue grows unboundedly: the bound must blow through
        assert!(res.latency.violation_rate() > 0.3, "rate={}", res.latency.violation_rate());
    }

    #[test]
    fn pspice_holds_the_bound_under_overload() {
        let res = run_experiment(&tiny_cfg()).unwrap();
        assert!(
            res.latency.violation_rate() < 0.05,
            "violations={} max={}ns",
            res.latency.violation_rate(),
            res.latency.stats.max()
        );
        assert!(res.dropped_pms > 0, "overload forces drops");
    }

    #[test]
    fn pm_baseline_drops_more_quality() {
        let pspice = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::PmBaseline;
        let pmbl = run_experiment(&cfg).unwrap();
        assert_eq!(pmbl.false_positives, 0);
        // the headline claim, on a small workload: informed ≤ random
        assert!(
            pspice.fn_percent <= pmbl.fn_percent + 5.0,
            "pspice={:.1}% pm-bl={:.1}%",
            pspice.fn_percent,
            pmbl.fn_percent
        );
    }

    #[test]
    fn sharded_runs_match_truth_without_overload() {
        // with 2 shards at an under-capacity rate and no shedding, the
        // sharded runtime must miss nothing and invent nothing
        let mut cfg = tiny_cfg();
        cfg.shedder = ShedderKind::None;
        cfg.rate = 0.5;
        cfg.shards = 2; // q4 is one query, but the runtime caps shards
        cfg.batch = 64;
        cfg.lb_ms = 2.0;
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.fn_percent, 0.0, "sharded run missed truth events");
        assert_eq!(res.false_positives, 0);
        // q4 is one query: the runtime caps the worker count and the
        // result reports what actually ran, not what was requested
        assert_eq!(res.shards, 1);
    }

    #[test]
    fn sharding_absorbs_an_overload_one_worker_cannot() {
        // rate 1.5× one core's capacity: unsharded+no-shedding violates
        // the bound massively (see overload_without_shedding test); four
        // shards on the two-query q1 workload keep the queue bounded
        let mut cfg = tiny_cfg();
        cfg.query = "q1".into();
        cfg.dataset = DatasetKind::Stock;
        cfg.window = 2_000;
        cfg.shedder = ShedderKind::None;
        cfg.rate = 1.5;
        cfg.batch = 32;
        cfg.lb_ms = 2.0;
        cfg.shards = 2;
        let sharded = run_experiment(&cfg).unwrap();
        cfg.shards = 1;
        let single = run_experiment(&cfg).unwrap();
        assert!(
            sharded.latency.violation_rate() < single.latency.violation_rate(),
            "sharded={} single={}",
            sharded.latency.violation_rate(),
            single.latency.violation_rate()
        );
    }

    #[test]
    fn sharded_pspice_sheds_and_stays_sound() {
        let mut cfg = tiny_cfg();
        cfg.shards = 2;
        cfg.batch = 32;
        cfg.lb_ms = 0.5;
        cfg.rate = 3.0; // overload even a 2-way split of one query
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.false_positives, 0, "PM shedding must not invent CEs");
        assert!((0.0..=100.0).contains(&res.fn_percent));
    }
}
