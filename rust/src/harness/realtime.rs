//! The real-time experiment driver: calibrate on the warm-up prefix
//! exactly like the batch runner, then drive the pipeline's ingest
//! plane ([`Pipeline::run_realtime`]) instead of the virtual-time
//! feed loop.
//!
//! Ground truth is deliberately skipped: a real-time run races a
//! clock, so QoR is not comparable across machines — the quantities
//! that ARE portable (p95 vs the bound, queue drops, shed volume) are
//! what [`RealtimeResult`] reports, and what the CI smoke gate checks.
//!
//! Sources come from the configuration: `trace` replays the dataset on
//! the deterministic schedule; `burst`/`flashcrowd`/`oscillate` are
//! the synthetic adversarial generators, parameterized from the
//! *measured* capacity so "120% load" means the same thing on every
//! machine; `tail`/`socket` need an external attachment (a path or an
//! address) and are passed in prebuilt by the CLI.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::events::Event;
use crate::ingest::{
    Burst, FlashCrowd, OscillatingRate, Source, SourceKind, SyntheticSource, TraceSource,
};
use crate::metrics::LatencyTracker;
use crate::model::plane::train_from_operator;
use crate::operator::Operator;
use crate::pipeline::Pipeline;
use crate::runtime::FaultPlan;
use crate::sim::RateSource;

use super::experiment::{apply_cost_factors, build_queries, build_trace, calibrate};

/// Summary of one real-time run — the portable quantities only (see
/// the [module docs](self) for why there is no QoR here).
#[derive(Debug, Clone)]
pub struct RealtimeResult {
    /// configuration echo
    pub query: String,
    /// strategy that ran
    pub shedder: &'static str,
    /// source that fed the run
    pub source: &'static str,
    /// overload plane ("predicted" or "measured")
    pub overload: &'static str,
    /// true = wall clock, false = virtual clock
    pub wall: bool,
    /// measured capacity (mean ns per event on the warm-up prefix)
    pub capacity_ns: f64,
    /// the latency bound LB (ms)
    pub lb_ms: f64,
    /// latency accounting for every processed event
    pub latency: LatencyTracker,
    /// events lost at the full ingest queue (drop-oldest only)
    pub queue_dropped: u64,
    /// PMs dropped by the shedder
    pub dropped_pms: u64,
    /// PMs lost to crashed shard workers (involuntary shed; see
    /// [`crate::shedding::ShedReport::dropped_pms_failure`])
    pub dropped_pms_failure: u64,
    /// shard workers respawned after a failure during the run
    pub recoveries: u64,
    /// PMs restored by checkpointed (snapshot + journal replay)
    /// recovery instead of being lost to `dropped_pms_failure`
    pub recovered_pms: u64,
    /// journaled events replayed into respawned workers
    pub replayed_events: u64,
    /// worker hangs detected by the dispatch deadline
    pub hangs_detected: u64,
    /// a stop signal (SIGINT) ended the run before deadline/source end;
    /// the in-flight batch completed and every total above is valid
    pub interrupted: bool,
    /// events dropped by the shedder (E-BL)
    pub dropped_events: u64,
    /// shed time / operator busy time
    pub shed_overhead: f64,
    /// peak live PM count
    pub peak_pms: usize,
    /// complex events detected during the run
    pub completions: usize,
    /// drift-triggered model rebuilds during the run (the measured
    /// overload plane feeds the same retraining loop as the simulated
    /// one — see [`Pipeline::run_realtime`])
    pub retrains: u32,
    /// model-table epoch at the end of the run (`retrains` + initial
    /// installs; 0 when the strategy carries no tables)
    pub table_epoch: u64,
    /// wall-clock events/s of the run loop
    pub wall_events_per_sec: f64,
    /// real elapsed seconds (host time, even for virtual runs)
    pub real_elapsed_secs: f64,
}

impl RealtimeResult {
    /// Events that went through latency accounting.
    pub fn events_processed(&self) -> u64 {
        self.latency.stats.count()
    }

    /// Hand-rolled JSON (the vendored crate set has no serde): flat
    /// object, milliseconds for every latency field.
    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.6}")
            } else {
                "0".into()
            }
        }
        let l = &self.latency;
        format!(
            concat!(
                "{{\n",
                "  \"query\": \"{query}\",\n",
                "  \"shedder\": \"{shedder}\",\n",
                "  \"source\": \"{source}\",\n",
                "  \"overload\": \"{overload}\",\n",
                "  \"wall\": {wall},\n",
                "  \"capacity_ns\": {capacity_ns},\n",
                "  \"lb_ms\": {lb_ms},\n",
                "  \"events\": {events},\n",
                "  \"completions\": {completions},\n",
                "  \"mean_ms\": {mean_ms},\n",
                "  \"p50_ms\": {p50_ms},\n",
                "  \"p95_ms\": {p95_ms},\n",
                "  \"max_ms\": {max_ms},\n",
                "  \"violations\": {violations},\n",
                "  \"violation_rate\": {violation_rate},\n",
                "  \"queue_dropped\": {queue_dropped},\n",
                "  \"dropped_pms\": {dropped_pms},\n",
                "  \"dropped_pms_failure\": {dropped_pms_failure},\n",
                "  \"recoveries\": {recoveries},\n",
                "  \"recovered_pms\": {recovered_pms},\n",
                "  \"replayed_events\": {replayed_events},\n",
                "  \"hangs_detected\": {hangs_detected},\n",
                "  \"interrupted\": {interrupted},\n",
                "  \"dropped_events\": {dropped_events},\n",
                "  \"shed_overhead\": {shed_overhead},\n",
                "  \"peak_pms\": {peak_pms},\n",
                "  \"retrains\": {retrains},\n",
                "  \"table_epoch\": {table_epoch},\n",
                "  \"wall_events_per_sec\": {weps},\n",
                "  \"real_elapsed_secs\": {elapsed}\n",
                "}}\n"
            ),
            query = self.query,
            shedder = self.shedder,
            source = self.source,
            overload = self.overload,
            wall = self.wall,
            capacity_ns = num(self.capacity_ns),
            lb_ms = num(self.lb_ms),
            events = self.events_processed(),
            completions = self.completions,
            mean_ms = num(l.stats.mean() / 1e6),
            p50_ms = num(l.quantile(0.5) / 1e6),
            p95_ms = num(l.p95_ns() / 1e6),
            max_ms = num(l.stats.max() / 1e6),
            violations = l.violations,
            violation_rate = num(l.violation_rate()),
            queue_dropped = self.queue_dropped,
            dropped_pms = self.dropped_pms,
            dropped_pms_failure = self.dropped_pms_failure,
            recoveries = self.recoveries,
            recovered_pms = self.recovered_pms,
            replayed_events = self.replayed_events,
            hangs_detected = self.hangs_detected,
            interrupted = self.interrupted,
            dropped_events = self.dropped_events,
            shed_overhead = num(self.shed_overhead),
            peak_pms = self.peak_pms,
            retrains = self.retrains,
            table_epoch = self.table_epoch,
            weps = num(self.wall_events_per_sec),
            elapsed = num(self.real_elapsed_secs),
        )
    }

    /// Write [`RealtimeResult::to_json`] to a file.
    pub fn write_json(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// Mean per-event cost (ns) over the warm-up prefix — the portable
/// capacity yardstick the synthetic generators and the trace schedule
/// are calibrated against.  Same settle-skip as the batch runner's
/// ground-truth pass, but over the prefix only: real-time runs never
/// see the measurement events ahead of time.
fn measure_capacity(cfg: &ExperimentConfig, queries: &[crate::query::Query], warmup: &[Event]) -> f64 {
    let mut op = Operator::new(queries.to_vec());
    apply_cost_factors(&mut op, cfg);
    op.obs.enabled = false;
    let skip = warmup.len() / 10;
    let mut sum = 0.0;
    let mut n = 0u64;
    for (i, e) in warmup.iter().enumerate() {
        let out = op.process_event(e);
        if i >= skip {
            sum += out.cost_ns;
            n += 1;
        }
    }
    sum / n.max(1) as f64
}

/// Build the configured ingest source.  Synthetic generators replay
/// the measurement slice of the trace with profile parameters derived
/// from `capacity_ns`, so the same config overloads every machine by
/// the same factor; sequence numbers and timestamps continue from the
/// warm-up prefix so windows see one monotonic stream.
pub fn build_realtime_source(
    cfg: &ExperimentConfig,
    capacity_ns: f64,
    trace: &[Event],
    warmup: usize,
) -> crate::Result<Box<dyn Source>> {
    let pool = trace[warmup..].to_vec();
    anyhow::ensure!(!pool.is_empty(), "no measurement events after warm-up");
    let seq0 = pool[0].seq;
    let ts0_ns = if warmup > 0 {
        trace[warmup - 1].ts_ms as f64 * 1e6
    } else {
        0.0
    };
    // one profile "cycle" spans ~2000 events of drain time: long enough
    // for queueing to build, short enough that a smoke run sees many
    let period_ns = 2_000.0 * capacity_ns;
    let source: Box<dyn Source> = match cfg.source {
        SourceKind::Trace => Box::new(TraceSource::new(
            pool,
            RateSource::from_capacity(capacity_ns, cfg.rate, 0.0),
        )),
        SourceKind::Burst => Box::new(
            SyntheticSource::new(
                pool,
                Box::new(Burst::from_capacity(
                    capacity_ns,
                    0.5,
                    2.0 * cfg.rate,
                    period_ns,
                    0.25 * period_ns,
                )),
                seq0,
                ts0_ns,
            )
            .with_limit(cfg.events),
        ),
        SourceKind::FlashCrowd => Box::new(
            SyntheticSource::new(
                pool,
                Box::new(FlashCrowd::from_capacity(
                    capacity_ns,
                    0.6,
                    2.0 * cfg.rate,
                    0.25 * period_ns,
                    0.5 * period_ns,
                    period_ns,
                    0.5 * period_ns,
                )),
                seq0,
                ts0_ns,
            )
            .with_limit(cfg.events),
        ),
        SourceKind::Oscillate => Box::new(
            SyntheticSource::new(
                pool,
                Box::new(OscillatingRate::from_capacity(
                    capacity_ns,
                    cfg.rate,
                    0.8,
                    period_ns,
                )),
                seq0,
                ts0_ns,
            )
            .with_limit(cfg.events),
        ),
        SourceKind::Tail | SourceKind::Socket => anyhow::bail!(
            "source {:?} needs an external attachment (--path / --addr)",
            cfg.source.name()
        ),
    };
    Ok(source)
}

/// Run one real-time experiment: calibrate + train on the warm-up
/// prefix (identical to the batch runner's phase 2), then drive the
/// ingest plane until the source ends or `cfg.duration_ms` of clock
/// time passes.  `external` overrides the configured source (the CLI
/// builds tail/socket sources there); `wall` swaps the virtual clock
/// for the monotonic one.
pub fn run_realtime_experiment(
    cfg: &ExperimentConfig,
    external: Option<Box<dyn Source>>,
    wall: bool,
) -> crate::Result<RealtimeResult> {
    run_realtime_experiment_with_stop(cfg, external, wall, None)
}

/// [`run_realtime_experiment`] with a cooperative stop flag: when the
/// flag goes `true` (a SIGINT handler, a watchdog) the pipeline
/// finishes its in-flight batch and returns the run's measurements
/// with [`RealtimeResult::interrupted`] set, instead of losing them.
pub fn run_realtime_experiment_with_stop(
    cfg: &ExperimentConfig,
    external: Option<Box<dyn Source>>,
    wall: bool,
    stop: Option<Arc<AtomicBool>>,
) -> crate::Result<RealtimeResult> {
    let queries = build_queries(cfg)?;
    let trace = build_trace(cfg);
    let warmup = (cfg.warmup as usize).min(trace.len());
    let capacity_ns = measure_capacity(cfg, &queries, &trace[..warmup]);
    anyhow::ensure!(capacity_ns > 0.0, "warm-up prefix too short to measure capacity");
    let (op, detector) = calibrate(cfg, &queries, &trace)?;
    let tables = if cfg.shedder.needs_tables() {
        let mut model = cfg.model.build(cfg.shedder.model_config());
        train_from_operator(model.as_mut(), &op)?
    } else {
        Vec::new()
    };
    drop(op);
    let source = match external {
        Some(s) => s,
        None => build_realtime_source(cfg, capacity_ns, &trace, warmup)?,
    };
    let source_name = source.name();
    let mut builder = Pipeline::builder()
        .queries(queries)
        .shedder(cfg.shedder)
        .fault_plan(FaultPlan::parse(&cfg.faults)?)
        .checkpoint_every(cfg.checkpoint_every)
        .journal_cap(cfg.journal_cap)
        .worker_deadline_ms(cfg.worker_deadline_ms)
        .detector(detector)
        .tables(tables)
        .latency_bound_ms(cfg.lb_ms)
        .latency_stride((cfg.events / 2_000).max(1))
        .shards(cfg.shards)
        .batch(cfg.batch)
        .seed(cfg.seed)
        .key_slot(cfg.dataset.key_slot())
        .cost_factors(cfg.cost_factors.clone())
        .model(cfg.model)
        .retrain(cfg.retrain_every, cfg.drift_threshold)
        .overload(cfg.overload)
        .ingest_capacity(cfg.ingest_capacity)
        .ingest_policy(cfg.ingest_policy)
        .ingest_source(source);
    if let Some(flag) = stop {
        builder = builder.stop_flag(flag);
    }
    if wall {
        builder = builder.wall_clock();
    }
    let mut pipe = builder.build()?;
    pipe.prime(&trace[..warmup]);
    let deadline_ns = if cfg.duration_ms > 0.0 {
        pipe.now_ns() + cfg.duration_ms * 1e6
    } else {
        f64::INFINITY
    };
    // audit:allow(wall-clock): reports real_elapsed_secs for the smoke log —
    // instrumentation only, the run is timed by the pipeline's Clock
    let started = Instant::now();
    let run = pipe.run_realtime(deadline_ns)?;
    let real_elapsed_secs = started.elapsed().as_secs_f64();
    Ok(RealtimeResult {
        query: cfg.query.clone(),
        shedder: run.shedder,
        source: source_name,
        overload: cfg.overload.name(),
        wall,
        capacity_ns,
        lb_ms: cfg.lb_ms,
        latency: run.latency,
        queue_dropped: run.queue_dropped,
        dropped_pms: run.totals.dropped_pms,
        dropped_pms_failure: run.totals.dropped_pms_failure,
        recoveries: run.recoveries,
        recovered_pms: run.totals.recovered_pms,
        replayed_events: run.totals.replayed_events,
        hangs_detected: run.totals.hangs_detected,
        interrupted: run.interrupted,
        dropped_events: run.totals.dropped_events,
        shed_overhead: run.shed_overhead,
        peak_pms: run.peak_pms,
        completions: run.completions.len(),
        retrains: run.retrains,
        table_epoch: run.table_epoch,
        wall_events_per_sec: run.wall_events_per_sec,
        real_elapsed_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::DatasetKind;
    use crate::shedding::{OverloadKind, ShedderKind};

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            query: "q4".into(),
            window: 2_000,
            pattern_n: 4,
            slide: 250,
            dataset: DatasetKind::Bus,
            seed: 3,
            events: 10_000,
            warmup: 12_000,
            rate: 1.4,
            lb_ms: 0.05,
            shedder: ShedderKind::PSpice,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn trace_source_run_completes_virtually() {
        let res = run_realtime_experiment(&tiny_cfg(), None, false).unwrap();
        assert_eq!(res.source, "trace");
        assert_eq!(res.overload, "predicted");
        assert!(!res.wall);
        assert_eq!(res.events_processed(), 10_000);
        assert!(res.capacity_ns > 0.0);
        // pSPICE holds the bound on the replayed overload
        assert!(
            res.latency.violation_rate() < 0.05,
            "violations={}",
            res.latency.violation_rate()
        );
    }

    #[test]
    fn synthetic_burst_overloads_and_sheds() {
        let mut cfg = tiny_cfg();
        cfg.source = crate::ingest::SourceKind::Burst;
        let res = run_realtime_experiment(&cfg, None, false).unwrap();
        assert_eq!(res.source, "burst");
        assert_eq!(res.events_processed(), 10_000);
        assert!(
            res.dropped_pms > 0,
            "2.8x-capacity bursts must force shedding"
        );
    }

    #[test]
    fn measured_overload_plane_runs() {
        let mut cfg = tiny_cfg();
        cfg.source = crate::ingest::SourceKind::Oscillate;
        cfg.overload = OverloadKind::Measured;
        let res = run_realtime_experiment(&cfg, None, false).unwrap();
        assert_eq!(res.overload, "measured");
        assert_eq!(res.events_processed(), 10_000);
        assert!(res.dropped_pms > 0, "measured plane must also shed");
    }

    #[test]
    fn json_has_the_gate_fields() {
        let res = run_realtime_experiment(&tiny_cfg(), None, false).unwrap();
        let json = res.to_json();
        for key in [
            "\"p95_ms\"",
            "\"lb_ms\"",
            "\"violation_rate\"",
            "\"queue_dropped\"",
            "\"shedder\"",
            "\"wall\": false",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // parses as JSON (python gate in CI does the same)
        assert!(json.trim_end().ends_with('}'));
        assert!(json.starts_with('{'));
    }

    #[test]
    fn wall_clock_run_retrains_on_drift() {
        // the measured ingest plane must feed the drift loop exactly
        // like the virtual one: a ~0 threshold makes every due check a
        // retrain once the model has observations
        let mut cfg = tiny_cfg();
        cfg.overload = OverloadKind::Measured;
        cfg.retrain_every = 1_500;
        cfg.drift_threshold = 1e-12;
        let res = run_realtime_experiment(&cfg, None, true).unwrap();
        assert!(res.wall);
        assert_eq!(res.events_processed(), 10_000);
        assert!(
            res.retrains >= 1,
            "tight threshold must retrain on the wall clock"
        );
        assert_eq!(res.table_epoch, res.retrains as u64);
        let json = res.to_json();
        assert!(json.contains("\"retrains\""), "json must carry retrains");
        assert!(json.contains("\"table_epoch\""));
    }

    #[test]
    fn stop_flag_interrupts_and_keeps_the_measurements() {
        let mut cfg = tiny_cfg();
        cfg.source = crate::ingest::SourceKind::Oscillate;
        // flag already set: the loop must exit before processing
        // anything, still returning a well-formed (interrupted) result
        let stop = Arc::new(AtomicBool::new(true));
        let res =
            run_realtime_experiment_with_stop(&cfg, None, false, Some(stop)).unwrap();
        assert!(res.interrupted);
        assert_eq!(res.events_processed(), 0);
        let json = res.to_json();
        assert!(json.contains("\"interrupted\": true"), "{json}");
        // a run nobody interrupts reports false
        let res = run_realtime_experiment(&tiny_cfg(), None, false).unwrap();
        assert!(!res.interrupted);
        assert!(res.to_json().contains("\"interrupted\": false"));
    }

    #[test]
    fn injected_kill_flows_into_the_realtime_result() {
        let mut cfg = tiny_cfg();
        cfg.query = "q1".into();
        cfg.dataset = DatasetKind::Stock;
        cfg.window = 1_500;
        cfg.shards = 2;
        cfg.batch = 64;
        cfg.source = crate::ingest::SourceKind::Oscillate;
        cfg.faults = "kill:0@10".into();
        let res = run_realtime_experiment(&cfg, None, false).unwrap();
        assert_eq!(res.recoveries, 1, "one kill, one respawn");
        assert!(res.dropped_pms_failure > 0, "the dead shard held PMs");
        let json = res.to_json();
        assert!(json.contains("\"dropped_pms_failure\""), "{json}");
        assert!(json.contains("\"recoveries\": 1"), "{json}");
        // same seed, same plan: failure accounting is deterministic
        let again = run_realtime_experiment(&cfg, None, false).unwrap();
        assert_eq!(again.dropped_pms_failure, res.dropped_pms_failure);
        assert_eq!(again.completions, res.completions);
    }

    #[test]
    fn duration_deadline_stops_the_run() {
        let mut cfg = tiny_cfg();
        cfg.source = crate::ingest::SourceKind::Oscillate;
        cfg.duration_ms = 1.0; // 1 virtual ms — far less than the trace
        let res = run_realtime_experiment(&cfg, None, false).unwrap();
        assert!(
            res.events_processed() < 10_000,
            "deadline must cut the run short (processed {})",
            res.events_processed()
        );
    }
}
