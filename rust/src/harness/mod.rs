//! Experiment harness: the paper's evaluation pipeline.
//!
//! * [`experiment`] — the three-phase runner (ground truth → calibrate
//!   + train → overloaded measurement on a [`crate::pipeline::Pipeline`])
//!   producing FN%/FP/latency/overhead numbers for one configuration,
//! * [`figures`] — drivers that regenerate every figure of the paper's
//!   evaluation section (Figs. 5–9) as printed tables + CSV files,
//! * [`realtime`] — the real-time driver: same calibration, then the
//!   ingest plane ([`crate::pipeline::Pipeline::run_realtime`]) under
//!   replay, synthetic-overload, tail or socket sources.

pub mod experiment;
pub mod figures;
pub mod realtime;

pub use experiment::{run_experiment, ExperimentResult};
pub use realtime::{
    run_realtime_experiment, run_realtime_experiment_with_stop, RealtimeResult,
};
