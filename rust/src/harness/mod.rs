//! Experiment harness: the paper's evaluation pipeline.
//!
//! * [`experiment`] — the three-phase runner (ground truth → calibrate
//!   + train → overloaded measurement on a [`crate::pipeline::Pipeline`])
//!   producing FN%/FP/latency/overhead numbers for one configuration,
//! * [`figures`] — drivers that regenerate every figure of the paper's
//!   evaluation section (Figs. 5–9) as printed tables + CSV files.

pub mod experiment;
pub mod figures;

pub use experiment::{run_experiment, ExperimentResult};
