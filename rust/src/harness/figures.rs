//! Figure drivers: regenerate every figure of the paper's evaluation
//! (§IV-B) as printed tables + CSV files under `results/`.
//!
//! Absolute numbers differ from the paper (synthetic data, virtual-time
//! substrate) but the *shapes* it claims are what these drivers check:
//! who wins, roughly by how much, and where the crossovers are (see
//! EXPERIMENTS.md for recorded runs).

use std::io::Write;
use std::path::Path;

use crate::config::ExperimentConfig;
use crate::datasets::DatasetKind;
use crate::shedding::ShedderKind;

use super::experiment::{run_experiment, ExperimentResult};

/// Scale factor applied to all event counts (CLI `--scale`); lets tests
/// and quick runs use the same drivers at reduced volume.
#[derive(Debug, Clone)]
pub struct FigureOpts {
    /// multiply warm-up/measure event counts (1.0 = paper-scale defaults)
    pub scale: f64,
    /// where CSVs go
    pub out_dir: std::path::PathBuf,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            scale: 1.0,
            out_dir: std::path::PathBuf::from("results"),
        }
    }
}

impl FigureOpts {
    fn events(&self, base: u64) -> u64 {
        ((base as f64 * self.scale) as u64).max(5_000)
    }
}

fn write_csv(path: &Path, header: &str, rows: &[String]) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

const SHEDDERS: [ShedderKind; 3] = [
    ShedderKind::PSpice,
    ShedderKind::PmBaseline,
    ShedderKind::EventBaseline,
];

fn base_cfg(query: &str, opts: &FigureOpts) -> ExperimentConfig {
    let (dataset, window, pattern_n) = match query {
        "q1" => (DatasetKind::Stock, 5_000, 0),
        "q2" => (DatasetKind::Stock, 7_500, 0),
        "q3" => (DatasetKind::Soccer, 1_500, 4),
        "q4" => (DatasetKind::Bus, 2_000, 4),
        "q1+q2" => (DatasetKind::Stock, 10_000, 0),
        other => panic!("unknown query {other}"),
    };
    ExperimentConfig {
        query: query.into(),
        window,
        pattern_n,
        slide: 500,
        dataset,
        seed: 42,
        events: opts.events(60_000),
        warmup: opts.events(60_000),
        rate: 1.2,
        lb_ms: 0.5,
        shedder: ShedderKind::PSpice,
        model: crate::model::ModelKind::Markov,
        weights: Vec::new(),
        cost_factors: Vec::new(),
        retrain_every: 0,
        drift_threshold: 0.01,
        shards: 1,
        batch: 256,
        ..ExperimentConfig::default()
    }
}

fn print_result(sweep: &str, x: f64, r: &ExperimentResult) {
    println!(
        "{:>10} {:>9.3} | {:<8} | mp={:>5.1}% fn={:>5.1}% fp={} gt={} \
         drops(pm={}, ev={}) lat(max={:.2}ms viol={:.2}%) ovh={:.3}% [{}]",
        sweep,
        x,
        r.shedder,
        r.match_probability * 100.0,
        r.fn_percent,
        r.false_positives,
        r.truth_total,
        r.dropped_pms,
        r.dropped_events,
        r.latency.stats.max() / 1e6,
        r.latency.violation_rate() * 100.0,
        r.shed_overhead * 100.0,
        r.engine,
    );
}

/// Fig. 5 — FN% vs match probability (window-size sweep for Q1/Q2,
/// pattern-size sweep for Q3/Q4), at rate 120%, all three shedders.
pub fn fig5(query: &str, opts: &FigureOpts) -> crate::Result<()> {
    println!("== Figure 5 ({query}): impact of match probability ==");
    let sweep: Vec<u64> = match query {
        "q1" => vec![3_500, 4_500, 5_000, 5_500, 6_000, 10_000],
        "q2" => vec![6_000, 7_000, 7_500, 8_000, 12_000, 14_000],
        // pattern sizes, paper order (decreasing n = increasing mp)
        "q3" | "q4" => vec![7, 6, 5, 4, 3, 2],
        other => anyhow::bail!("fig5 unsupported for {other}"),
    };
    let mut rows = Vec::new();
    for &v in &sweep {
        for shedder in SHEDDERS {
            let mut cfg = base_cfg(query, opts);
            cfg.shedder = shedder;
            match query {
                "q1" | "q2" => cfg.window = v,
                _ => cfg.pattern_n = v as usize,
            }
            let r = run_experiment(&cfg)?;
            print_result("sweep", v as f64, &r);
            rows.push(format!(
                "{v},{},{:.4},{:.2},{},{:.4}",
                r.shedder,
                r.match_probability,
                r.fn_percent,
                r.false_positives,
                r.shed_overhead
            ));
        }
    }
    write_csv(
        &opts.out_dir.join(format!("fig5_{query}.csv")),
        "sweep,shedder,match_probability,fn_percent,false_positives,shed_overhead",
        &rows,
    )
}

/// Fig. 6 — FN% vs input rate (120%..200%) at a fixed match
/// probability (Q1 and Q3 in the paper).
pub fn fig6(query: &str, opts: &FigureOpts) -> crate::Result<()> {
    println!("== Figure 6 ({query}): impact of event rate ==");
    let mut rows = Vec::new();
    for rate in [1.2, 1.4, 1.6, 1.8, 2.0] {
        for shedder in SHEDDERS {
            let mut cfg = base_cfg(query, opts);
            cfg.shedder = shedder;
            cfg.rate = rate;
            let r = run_experiment(&cfg)?;
            print_result("rate", rate, &r);
            rows.push(format!(
                "{rate},{},{:.4},{:.2},{}",
                r.shedder, r.match_probability, r.fn_percent, r.false_positives
            ));
        }
    }
    write_csv(
        &opts.out_dir.join(format!("fig6_{query}.csv")),
        "rate,shedder,match_probability,fn_percent,false_positives",
        &rows,
    )
}

/// Fig. 7 — event latency over time for Q2 at rates 120% and 140%:
/// pSPICE must hold LB = 1 (virtual) second.
pub fn fig7(opts: &FigureOpts) -> crate::Result<()> {
    println!("== Figure 7 (q2): latency bound maintenance ==");
    let mut rows = Vec::new();
    for rate in [1.2, 1.4] {
        let mut cfg = base_cfg("q2", opts);
        cfg.rate = rate;
        cfg.lb_ms = 1.0;
        let r = run_experiment(&cfg)?;
        print_result("rate", rate, &r);
        println!(
            "   latency: mean={:.3}ms p_max={:.3}ms violations={:.3}% (LB=1ms)",
            r.latency.stats.mean() / 1e6,
            r.latency.stats.max() / 1e6,
            r.latency.violation_rate() * 100.0
        );
        for (t, l) in &r.latency.trace {
            rows.push(format!("{rate},{:.0},{:.0}", t, l));
        }
    }
    write_csv(
        &opts.out_dir.join("fig7_latency.csv"),
        "rate,t_ns,latency_ns",
        &rows,
    )
}

/// Fig. 8 — pSPICE vs pSPICE-- as the per-query processing-time ratio
/// τ_Q1/τ_Q2 grows (multi-query Q1+Q2, ws=10K, rate 120%).
pub fn fig8(opts: &FigureOpts) -> crate::Result<()> {
    println!("== Figure 8 (q1+q2): processing time in the utility ==");
    let mut rows = Vec::new();
    for factor in [1.0, 2.0, 4.0, 8.0, 12.0, 16.0] {
        for (shedder, label) in [
            (ShedderKind::PSpice, "pspice"),
            (ShedderKind::PSpiceMinus, "pspice--"),
        ] {
            let mut cfg = base_cfg("q1+q2", opts);
            cfg.shedder = shedder;
            // wider LB so drops are rate-driven, not bound-driven —
            // otherwise the tau effect saturates at 100% FN
            cfg.lb_ms = 3.0;
            cfg.window = 6_000;
            // queries: [q1_rise, q1_fall, q2_rise, q2_fall]
            cfg.cost_factors = vec![factor, factor, 1.0, 1.0];
            let r = run_experiment(&cfg)?;
            println!(
                "  tau_q1/tau_q2={factor:>4} {label:<9} fn={:>5.1}% (fp={})",
                r.fn_percent, r.false_positives
            );
            rows.push(format!("{factor},{label},{:.2}", r.fn_percent));
        }
    }
    write_csv(
        &opts.out_dir.join("fig8_tau.csv"),
        "tau_ratio,shedder,fn_percent",
        &rows,
    )
}

/// Fig. 9a — shedding overhead (% of operator busy time) vs window
/// size, Q1, all three shedders.
pub fn fig9a(opts: &FigureOpts) -> crate::Result<()> {
    println!("== Figure 9a (q1): load shedding overhead ==");
    let mut rows = Vec::new();
    for ws in [3_500u64, 4_500, 5_000, 5_500, 6_000, 10_000] {
        for shedder in SHEDDERS {
            let mut cfg = base_cfg("q1", opts);
            cfg.window = ws;
            cfg.shedder = shedder;
            let r = run_experiment(&cfg)?;
            println!(
                "  ws={ws:>6} {:<8} overhead={:.3}% (drops pm={} ev={})",
                r.shedder,
                r.shed_overhead * 100.0,
                r.dropped_pms,
                r.dropped_events
            );
            rows.push(format!("{ws},{},{:.5}", r.shedder, r.shed_overhead));
        }
    }
    write_csv(
        &opts.out_dir.join("fig9a_overhead.csv"),
        "ws,shedder,shed_overhead_frac",
        &rows,
    )
}

/// Fig. 9b — model build time vs window size (Q1, larger windows).
/// Runs the warm-up + build only (no measurement phase needed).
pub fn fig9b(opts: &FigureOpts) -> crate::Result<()> {
    use crate::model::{ModelBuilder, ModelConfig};
    use crate::operator::Operator;

    println!("== Figure 9b (q1): model building overhead ==");
    let mut rows = Vec::new();
    for ws in [6_000u64, 10_000, 16_000, 18_000, 24_000, 32_000] {
        let mut cfg = base_cfg("q1", opts);
        cfg.window = ws;
        let queries = super::experiment::build_queries(&cfg)?;
        let trace = super::experiment::build_trace(&cfg);
        let mut op = Operator::new(queries);
        for e in &trace[..cfg.warmup as usize] {
            op.process_event(e);
        }
        // bin size follows the paper: more bins for larger windows ⇒
        // more value-iteration work; max_bins capped by artifact size
        let mut mb = ModelBuilder::with_auto_engine(ModelConfig {
            max_bins: 512,
            ..ModelConfig::default()
        });
        let t0 = crate::sim::WallTimer::start();
        let tables = mb.build(&op)?;
        let secs = t0.elapsed_secs();
        println!(
            "  ws={ws:>6} build={:.4}s bins={} engine={}",
            secs,
            tables[0].rows.len(),
            mb.engine_name()
        );
        rows.push(format!("{ws},{secs:.6},{}", mb.engine_name()));
    }
    write_csv(
        &opts.out_dir.join("fig9b_model_build.csv"),
        "ws,build_secs,engine",
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_drivers_run_at_tiny_scale() {
        let opts = FigureOpts {
            scale: 0.02, // 5k events floor kicks in
            out_dir: std::env::temp_dir().join("pspice_fig_test"),
        };
        // one cheap cell per driver family: fig9b covers warm-up + build
        fig9b(&FigureOpts {
            scale: 0.02,
            out_dir: opts.out_dir.clone(),
        })
        .unwrap();
        assert!(opts.out_dir.join("fig9b_model_build.csv").exists());
    }
}
