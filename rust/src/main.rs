//! `pspice` binary: CLI entrypoint (see [`pspice::cli`]).
fn main() {
    pspice::util::logger::init();
    if let Err(e) = pspice::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
