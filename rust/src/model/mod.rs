//! The pSPICE model: from aggregated observations to utility tables.
//!
//! * [`utility`] — the `UT_q` tables (paper §III-C-3): per-state,
//!   per-remaining-events-bin utilities with O(1) interpolated lookup,
//! * [`builder`] — the Markov model builder (paper Fig. 2): learns
//!   `T_q` and `R_q` from observations, composes per-bin chains, runs
//!   the model engine (AOT/PJRT or rust fallback) and assembles the
//!   tables,
//! * [`retrain`] — drift detection on the transition matrix (§III-D),
//! * [`plane`] — the versioned model plane: the [`UtilityModel`]
//!   trainer trait (Markov + frequency-only backends), the immutable
//!   epoch-numbered [`TableSet`] snapshot every operator state reads
//!   through, and the [`ModelController`] train→snapshot→publish loop
//!   driving drift retraining on any backend, sharded included.

pub mod builder;
pub mod plane;
pub mod retrain;
pub mod utility;

pub use builder::{ModelBuilder, ModelConfig};
pub use plane::{
    FrequencyModel, KeyUtilityTable, ModelController, ModelHarvest, ModelKind, TableSet,
    TrainingView, UtilityModel,
};
pub use retrain::DriftDetector;
pub use utility::UtilityTable;
