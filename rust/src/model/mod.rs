//! The pSPICE model: from aggregated observations to utility tables.
//!
//! * [`utility`] — the `UT_q` tables (paper §III-C-3): per-state,
//!   per-remaining-events-bin utilities with O(1) interpolated lookup,
//! * [`builder`] — the model builder (paper Fig. 2): learns `T_q` and
//!   `R_q` from observations, composes per-bin chains, runs the model
//!   engine (AOT/PJRT or rust fallback) and assembles the tables,
//! * [`retrain`] — drift detection on the transition matrix (§III-D).

pub mod builder;
pub mod retrain;
pub mod utility;

pub use builder::{ModelBuilder, ModelConfig};
pub use retrain::DriftDetector;
pub use utility::UtilityTable;
