//! Drift detection (paper §III-D): periodically rebuild a candidate
//! transition matrix from fresh statistics and compare it against the
//! matrix the live model was built from; retrain when the mean squared
//! error exceeds a threshold.

use crate::linalg::Mat;
use crate::operator::ObservationHub;

/// Per-query transition-matrix drift detector.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    /// MSE threshold above which the model is considered stale.
    pub threshold: f64,
    /// Matrices the current model was built from.
    baseline: Vec<Mat>,
}

impl DriftDetector {
    /// Snapshot the matrices a model was just built from.
    pub fn snapshot(hub: &ObservationHub, threshold: f64) -> Self {
        DriftDetector {
            threshold,
            baseline: hub
                .queries
                .iter()
                .map(|q| q.transition_matrix())
                .collect(),
        }
    }

    /// Check current statistics against the baseline.  Returns the
    /// maximum per-query MSE and whether it crossed the threshold.
    ///
    /// A hub whose shape no longer matches the baseline — a different
    /// query count, or a query whose transition matrix changed
    /// dimension after a retrain/model swap — is treated as maximal
    /// drift (`(f64::INFINITY, true)`) instead of feeding mismatched
    /// shapes into [`Mat::mse`] (which asserts) or silently truncating
    /// the `zip`: the retrain this forces re-snapshots the baseline at
    /// the new shape, so the detector self-heals.
    pub fn check(&self, hub: &ObservationHub) -> (f64, bool) {
        if hub.queries.len() != self.baseline.len() {
            return (f64::INFINITY, true);
        }
        let mut max_mse = 0.0f64;
        for (q, base) in hub.queries.iter().zip(&self.baseline) {
            let t = q.transition_matrix();
            if t.rows() != base.rows() || t.cols() != base.cols() {
                return (f64::INFINITY, true);
            }
            max_mse = max_mse.max(t.mse(base));
        }
        (max_mse, max_mse > self.threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operator::QueryStats;

    fn hub_with(counts: &[(u32, u32, u64)]) -> ObservationHub {
        let mut hub = ObservationHub::new(&[3]);
        for &(s, s2, n) in counts {
            for _ in 0..n {
                hub.queries[0].record(s, s2, 1.0);
            }
        }
        hub
    }

    #[test]
    fn no_drift_on_same_distribution() {
        let hub = hub_with(&[(0, 0, 90), (0, 1, 10), (1, 2, 5), (1, 1, 5)]);
        let det = DriftDetector::snapshot(&hub, 0.01);
        let mut hub2 = hub.clone();
        // double the counts: same distribution
        for q in &mut hub2.queries {
            for row in &mut q.counts {
                for c in row.iter_mut() {
                    *c *= 2;
                }
            }
        }
        let (mse, drift) = det.check(&hub2);
        assert!(mse < 1e-12);
        assert!(!drift);
    }

    #[test]
    fn shape_mismatch_is_maximal_drift_not_a_panic() {
        // a query whose transition matrix changed dimension after
        // retraining (or a hub with a different query count) must read
        // as drifted, never panic inside Mat::mse
        let hub3 = hub_with(&[(0, 0, 5), (0, 1, 5)]);
        let det = DriftDetector::snapshot(&hub3, 0.5);
        let mut hub4 = ObservationHub::new(&[4]);
        hub4.queries[0].record(0, 1, 1.0);
        let (mse, drifted) = det.check(&hub4);
        assert!(drifted, "dimension change must force a retrain");
        assert!(mse.is_infinite());
        // different query count: same verdict
        let hub2q = ObservationHub::new(&[3, 3]);
        let (mse2, drifted2) = det.check(&hub2q);
        assert!(drifted2);
        assert!(mse2.is_infinite());
    }

    #[test]
    fn drift_on_changed_distribution() {
        let hub = hub_with(&[(0, 0, 90), (0, 1, 10)]);
        let det = DriftDetector::snapshot(&hub, 0.01);
        // distribution flips: advances become dominant
        let hub2 = hub_with(&[(0, 0, 10), (0, 1, 90)]);
        let (mse, drift) = det.check(&hub2);
        assert!(mse > 0.01, "mse={mse}");
        assert!(drift);
        let _ = QueryStats::new(2); // keep import used
    }
}
