//! Utility tables (paper §III-C-3).
//!
//! `UT_q` stores the utility of a PM of query `q` for every state and
//! every remaining-events *bin*; the shedder reads it with an O(1)
//! interpolated lookup (paper: "linear interpolation" between bin
//! boundaries).
//!
//! Scaling: completion probability and remaining processing time have
//! different units, so both are min–max normalized over the table
//! before applying Eq. 1, exactly as §III-C-3 prescribes ("we bring the
//! completion probabilities and processing times to the same scale").
//! `U = w_q · P̂ / (τ̂ + ε)` with a small ε so zero-time states don't
//! produce infinities.

use crate::linalg::markov::MarkovTables;

/// Normalization floor for the scaled processing time.
const EPS: f64 = 1e-3;

/// One query's utility table.
#[derive(Debug, Clone)]
pub struct UtilityTable {
    /// states (incl. initial)
    pub m: usize,
    /// bin size in events
    pub bs: u64,
    /// `rows[j][s]` — utility at state `s` with `(j+1)·bs` events left
    pub rows: Vec<Vec<f64>>,
}

impl UtilityTable {
    /// Assemble a table from raw Markov tables.
    pub fn from_tables(tables: &MarkovTables, weight: f64, bs: u64, use_tau: bool) -> Self {
        let nbins = tables.completion.len();
        let m = tables.completion.first().map_or(0, |r| r.len());
        // min-max over the whole table (not per row: cross-bin ordering
        // matters — a PM with more remaining events IS worth more)
        let (mut cmin, mut cmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut tmin, mut tmax) = (f64::INFINITY, f64::NEG_INFINITY);
        for j in 0..nbins {
            for s in 0..m {
                let c = tables.completion[j][s];
                let t = tables.remaining_time[j][s];
                cmin = cmin.min(c);
                cmax = cmax.max(c);
                tmin = tmin.min(t);
                tmax = tmax.max(t);
            }
        }
        let cspan = (cmax - cmin).max(1e-12);
        let tspan = (tmax - tmin).max(1e-12);
        let rows = (0..nbins)
            .map(|j| {
                (0..m)
                    .map(|s| {
                        let p = (tables.completion[j][s] - cmin) / cspan;
                        let tau = (tables.remaining_time[j][s] - tmin) / tspan;
                        if use_tau {
                            weight * p / (tau + EPS)
                        } else {
                            // pSPICE-- ablation: completion probability only
                            weight * p
                        }
                    })
                    .collect()
            })
            .collect();
        UtilityTable { m, bs, rows }
    }

    /// O(1) utility lookup for a PM at `state` with `remaining` events
    /// left in its window, linearly interpolating between bins.
    #[inline]
    pub fn lookup(&self, state: u32, remaining: u64) -> f64 {
        if remaining == 0 || self.rows.is_empty() {
            // no events left: the PM cannot complete any more
            return 0.0;
        }
        let s = state as usize;
        debug_assert!(s < self.m);
        // row j corresponds to (j+1)*bs remaining events
        let x = remaining as f64 / self.bs as f64 - 1.0;
        let last = self.rows.len() - 1;
        if x <= 0.0 {
            // below the first bin: interpolate toward utility 0 at R=0
            let frac = remaining as f64 / self.bs as f64;
            return self.rows[0][s] * frac;
        }
        let lo = (x.floor() as usize).min(last);
        let hi = (lo + 1).min(last);
        let frac = (x - lo as f64).clamp(0.0, 1.0);
        self.rows[lo][s] * (1.0 - frac) + self.rows[hi][s] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::markov::build_tables;
    use crate::linalg::Mat;

    fn tables() -> MarkovTables {
        let t = Mat::from_rows(3, 3, &[0.7, 0.3, 0.0, 0.0, 0.5, 0.5, 0.0, 0.0, 1.0]);
        build_tables(&t, &[1.0, 2.0, 0.0], 16)
    }

    #[test]
    fn later_states_more_valuable() {
        let ut = UtilityTable::from_tables(&tables(), 1.0, 10, true);
        // with equal remaining events, a PM closer to completion has
        // higher completion probability -> higher utility
        for j in 0..16 {
            assert!(ut.rows[j][1] >= ut.rows[j][0], "bin {j}");
        }
    }

    #[test]
    fn more_remaining_events_more_utility() {
        let ut = UtilityTable::from_tables(&tables(), 1.0, 10, false);
        for s in 0..2 {
            for j in 1..16 {
                assert!(
                    ut.rows[j][s] + 1e-12 >= ut.rows[j - 1][s],
                    "s={s} j={j}"
                );
            }
        }
    }

    #[test]
    fn lookup_interpolates_between_bins() {
        let ut = UtilityTable::from_tables(&tables(), 1.0, 10, true);
        let at_bin0 = ut.lookup(0, 10); // exactly bin 0
        let at_bin1 = ut.lookup(0, 20); // exactly bin 1
        let mid = ut.lookup(0, 15);
        assert!((mid - 0.5 * (at_bin0 + at_bin1)).abs() < 1e-9);
        assert!((ut.lookup(0, 10) - ut.rows[0][0]).abs() < 1e-12);
    }

    #[test]
    fn lookup_zero_remaining_is_zero() {
        let ut = UtilityTable::from_tables(&tables(), 1.0, 10, true);
        assert_eq!(ut.lookup(0, 0), 0.0);
        assert_eq!(ut.lookup(1, 0), 0.0);
        // below first bin shrinks toward zero
        assert!(ut.lookup(1, 5) < ut.lookup(1, 10));
    }

    #[test]
    fn lookup_clamps_above_table() {
        let ut = UtilityTable::from_tables(&tables(), 1.0, 10, true);
        let last = ut.rows.len() - 1;
        assert!((ut.lookup(1, 10_000) - ut.rows[last][1]).abs() < 1e-12);
    }

    #[test]
    fn weight_scales_utility() {
        let t = tables();
        let u1 = UtilityTable::from_tables(&t, 1.0, 10, true);
        let u3 = UtilityTable::from_tables(&t, 3.0, 10, true);
        assert!((u3.lookup(1, 50) - 3.0 * u1.lookup(1, 50)).abs() < 1e-9);
    }

    #[test]
    fn pspice_minus_minus_ignores_tau() {
        // make a chain where state 0 has huge remaining time
        let t = Mat::from_rows(3, 3, &[0.9, 0.1, 0.0, 0.0, 0.1, 0.9, 0.0, 0.0, 1.0]);
        let tabs = build_tables(&t, &[100.0, 1.0, 0.0], 16);
        let with_tau = UtilityTable::from_tables(&tabs, 1.0, 10, true);
        let without = UtilityTable::from_tables(&tabs, 1.0, 10, false);
        // pSPICE (with tau) must punish the expensive state 0 more than
        // pSPICE-- does, relative to state 1
        let ratio_with = with_tau.rows[10][0] / with_tau.rows[10][1].max(1e-12);
        let ratio_without = without.rows[10][0] / without.rows[10][1].max(1e-12);
        assert!(ratio_with < ratio_without);
    }
}
