//! The model builder (paper Fig. 2 + §III-C): turns aggregated
//! observations into per-query [`UtilityTable`]s.
//!
//! Pipeline per build:
//!
//! 1. per query: learn `T_q` (normalized transition counts, absorbing
//!    final state) and the expected one-event reward `r_q`,
//! 2. pick the bin size `bs = ceil(ws / max_bins)` and compose the
//!    one-event chain into the per-bin chain (exact doubling —
//!    [`crate::linalg::markov::compose_bin`]),
//! 3. run the model engine (AOT artifact via PJRT, or rust fallback) to
//!    get completion/remaining-time tables for all queries in ONE
//!    batched call,
//! 4. scale and combine into `UT_q` (Eq. 1).

use crate::linalg::markov::compose_bin;
use crate::operator::{ObservationHub, Operator};
use crate::runtime::ModelEngine;

use super::plane::{TrainingView, UtilityModel};
use super::utility::UtilityTable;

/// Model-builder configuration.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Observations required before the first build (paper's η).
    pub eta: u64,
    /// Maximum number of bins per table (bounds memory; paper's
    /// `ws/bs`).  The artifact variants cap this at 512.
    pub max_bins: usize,
    /// Include remaining processing time in the utility (false =
    /// the paper's pSPICE-- ablation).
    pub use_tau: bool,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            eta: 50_000,
            max_bins: 256,
            use_tau: true,
        }
    }
}

/// The model builder.
pub struct ModelBuilder {
    /// configuration
    pub cfg: ModelConfig,
    engine: Box<dyn ModelEngine>,
    /// wall-clock time of the last build (for Fig. 9b)
    pub last_build_secs: f64,
}

impl ModelBuilder {
    /// Builder using the given engine.
    pub fn new(cfg: ModelConfig, engine: Box<dyn ModelEngine>) -> Self {
        ModelBuilder {
            cfg,
            engine,
            last_build_secs: 0.0,
        }
    }

    /// Builder with the best available engine (PJRT if artifacts exist).
    pub fn with_auto_engine(cfg: ModelConfig) -> Self {
        Self::new(cfg, crate::runtime::auto_engine())
    }

    /// Engine name (for logs / EXPERIMENTS.md).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Enough observations to build? (η per §III-C)
    pub fn ready(&self, hub: &ObservationHub) -> bool {
        hub.total() >= self.cfg.eta
    }

    /// Expected window size in events for each query of an operator
    /// (count windows exact; time windows via the operator's rate
    /// estimate).  Delegates to [`Operator::expected_ws`].
    pub fn expected_ws(op: &Operator) -> Vec<u64> {
        op.expected_ws()
    }

    /// Build utility tables for every query of `op` from its current
    /// observation counts (the single-operator convenience around
    /// [`ModelBuilder::build_view`]).
    pub fn build(&mut self, op: &Operator) -> crate::Result<Vec<UtilityTable>> {
        let ws = op.expected_ws();
        let weights: Vec<f64> = op.queries.iter().map(|cq| cq.query.weight).collect();
        self.build_view(&TrainingView {
            hub: &op.obs,
            ws: &ws,
            weights: &weights,
        })
    }

    /// Build utility tables from harvested training inputs — the
    /// [`UtilityModel`] training entry point, independent of where the
    /// observations came from (a local operator or a merged sharded
    /// harvest).
    pub fn build_view(&mut self, view: &TrainingView<'_>) -> crate::Result<Vec<UtilityTable>> {
        anyhow::ensure!(
            view.hub.queries.len() == view.ws.len()
                && view.ws.len() == view.weights.len(),
            "training view shape mismatch"
        );
        let timer = crate::sim::WallTimer::start();
        // one shared bin count so all queries batch into one engine call
        let max_ws = *view.ws.iter().max().expect("at least one query");
        let bs = (max_ws as f64 / self.cfg.max_bins as f64).ceil().max(1.0) as u64;
        let nbins = (max_ws as f64 / bs as f64).ceil() as usize;

        let chains: Vec<_> = view
            .hub
            .queries
            .iter()
            .map(|qs| {
                let t = qs.transition_matrix();
                let r = qs.expected_reward();
                compose_bin(&t, &r, bs)
            })
            .collect();
        let tables = self.engine.build_tables(&chains, nbins)?;
        let out = tables
            .iter()
            .zip(view.weights)
            .map(|(tab, &w)| UtilityTable::from_tables(tab, w, bs, self.cfg.use_tau))
            .collect();
        self.last_build_secs = timer.elapsed_secs();
        log::debug!(
            "model build: {} queries, bs={bs}, nbins={nbins}, {:.3}s via {}",
            view.weights.len(),
            self.last_build_secs,
            self.engine.name()
        );
        Ok(out)
    }
}

/// The canonical [`UtilityModel`]: the paper's Markov-reward trainer.
impl UtilityModel for ModelBuilder {
    fn name(&self) -> &'static str {
        "markov"
    }

    fn engine(&self) -> &'static str {
        self.engine_name()
    }

    fn ready(&self, hub: &ObservationHub) -> bool {
        ModelBuilder::ready(self, hub)
    }

    fn train(&mut self, view: &TrainingView<'_>) -> crate::Result<Vec<UtilityTable>> {
        self.build_view(view)
    }

    fn last_train_secs(&self) -> f64 {
        self.last_build_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::query::builtin::q4;
    use crate::runtime::FallbackEngine;

    fn trained_operator() -> Operator {
        let mut op = Operator::new(q4(4, 2000, 400).queries);
        let mut g = BusGen::with_seed(1);
        for _ in 0..30_000 {
            op.process_event(&g.next_event().unwrap());
        }
        op
    }

    #[test]
    fn builds_tables_with_fallback() {
        let op = trained_operator();
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 1000,
                max_bins: 64,
                use_tau: true,
            },
            Box::new(FallbackEngine),
        );
        assert!(mb.ready(&op.obs));
        let tables = mb.build(&op).unwrap();
        assert_eq!(tables.len(), 1);
        let ut = &tables[0];
        assert_eq!(ut.m, 5);
        assert!(!ut.rows.is_empty());
        // utilities are finite and non-negative
        for row in &ut.rows {
            for &u in row {
                assert!(u.is_finite() && u >= 0.0);
            }
        }
        assert!(mb.last_build_secs >= 0.0);
    }

    #[test]
    fn expected_ws_count_windows() {
        let op = trained_operator();
        assert_eq!(ModelBuilder::expected_ws(&op), vec![2000]);
    }

    #[test]
    fn not_ready_without_observations() {
        let op = Operator::new(q4(4, 2000, 400).queries);
        let mb = ModelBuilder::new(ModelConfig::default(), Box::new(FallbackEngine));
        assert!(!mb.ready(&op.obs));
    }
}
