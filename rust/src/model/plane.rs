//! The versioned model plane: the API seam between *learning* a
//! utility model and *reading* it from the shedding hot path.
//!
//! Three pieces (paper §III-C/§III-D, generalized the way hSPICE and
//! gSPICE vary it):
//!
//! * [`UtilityModel`] — the trainer abstraction: consume aggregated
//!   [`ObservationHub`] statistics (via a [`TrainingView`]) and produce
//!   per-query [`UtilityTable`]s, the O(1) interpolated-lookup artifact
//!   the shedder reads.  Backends: the canonical Markov-chain builder
//!   ([`crate::model::ModelBuilder`], `ModelKind::Markov`) and the
//!   cheap frequency-only [`FrequencyModel`] (`ModelKind::Freq`).
//! * [`TableSet`] — an immutable, epoch-numbered model snapshot
//!   (utility tables + per-query check-cost factors + expected window
//!   sizes + E-BL's [`KeyUtilityTable`]), `Arc`-shared between the
//!   coordinator, every worker shard, and the strategies.  Operator
//!   states install whole snapshots
//!   ([`OperatorState::install_table_set`]) and report the epoch they
//!   are reading ([`OperatorState::table_epoch`]); the sharded runtime
//!   broadcasts the `Arc` to its workers, so a retrain is one
//!   atomic hot swap, never a field-by-field mutation.
//! * [`ModelController`] — the train→snapshot→publish loop: harvest
//!   observations from any backend
//!   ([`OperatorState::harvest_observations`] — the sharded runtime
//!   merges per-worker statistics), drift-check them against the
//!   matrices the live model was built from, and on drift train a
//!   fresh epoch and publish it to the state.
//!
//! # Quickstart
//!
//! Mirrors `examples/quickstart`: calibrate an operator, train a model
//! through the plane, snapshot and install it.
//!
//! ```no_run
//! use std::sync::Arc;
//! use pspice::datasets::BusGen;
//! use pspice::events::EventStream;
//! use pspice::model::plane::train_from_operator;
//! use pspice::model::{ModelConfig, ModelKind, TableSet};
//! use pspice::operator::{Operator, OperatorState};
//! use pspice::query::builtin::q4;
//!
//! // 1. calibrate: stream warm-up events through a plain operator so
//! //    its ObservationHub learns the transition statistics
//! let mut op = Operator::new(q4(4, 2_000, 250).queries);
//! for e in BusGen::with_seed(7).take_events(40_000) {
//!     op.process_event(&e);
//! }
//!
//! // 2. train any UtilityModel backend (swap Markov for Freq freely)
//! let mut model = ModelKind::Markov.build(ModelConfig::default());
//! let tables = train_from_operator(model.as_mut(), &op).unwrap();
//!
//! // 3. snapshot as an immutable epoch-0 TableSet and hot-swap it in
//! let set = Arc::new(TableSet::initial(tables, vec![1.0], None));
//! op.install_table_set(Arc::clone(&set));
//! assert_eq!(op.table_epoch(), 0);
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::events::Event;
use crate::nfa::CompiledQuery;
use crate::operator::{ObservationHub, OperatorState};
use crate::query::{Predicate, Query};

use super::builder::{ModelBuilder, ModelConfig};
use super::retrain::DriftDetector;
use super::utility::UtilityTable;

/// Borrowed training inputs for one [`UtilityModel::train`] call: the
/// aggregated observation statistics plus the per-query expected window
/// sizes and importance weights (all in global query order, one entry
/// per query).
#[derive(Debug, Clone, Copy)]
pub struct TrainingView<'a> {
    /// aggregated `<q, s, s', t>` statistics
    pub hub: &'a ObservationHub,
    /// expected window size in events per query (count windows exact,
    /// time windows via the operator's rate estimate)
    pub ws: &'a [u64],
    /// per-query importance weights `w_q`
    pub weights: &'a [f64],
}

/// A trainable utility model: the *training* half of the model plane.
///
/// Training consumes [`ObservationHub`] statistics through a
/// [`TrainingView`] and produces per-query [`UtilityTable`]s — the
/// *inference* half is the tables' own O(1) interpolated
/// [`UtilityTable::lookup`], which the shedder reads through an
/// installed [`TableSet`].  Implementations: the canonical Markov-chain
/// [`crate::model::ModelBuilder`] and the frequency-only
/// [`FrequencyModel`]; future predictors (state-aware, learned,
/// per-type) plug in here.
pub trait UtilityModel {
    /// Short backend name (`"markov"`, `"freq"`; the CLI's `--model`
    /// values).
    fn name(&self) -> &'static str;

    /// Execution-engine label for reports (for the Markov backend the
    /// model-engine name, e.g. `"rust-fallback"` or `"pjrt-aot"`).
    fn engine(&self) -> &'static str {
        self.name()
    }

    /// Enough observations to train? (the paper's η)
    fn ready(&self, hub: &ObservationHub) -> bool;

    /// Train utility tables from aggregated observations (one table per
    /// query, global order).
    fn train(&mut self, view: &TrainingView<'_>) -> crate::Result<Vec<UtilityTable>>;

    /// Wall-clock seconds of the last [`UtilityModel::train`] call
    /// (Fig. 9b's model-build overhead).
    fn last_train_secs(&self) -> f64;
}

/// Train a model straight from a calibrated single-threaded operator
/// (the phase-2 convenience wrapper around [`UtilityModel::train`]).
pub fn train_from_operator(
    model: &mut dyn UtilityModel,
    op: &crate::operator::Operator,
) -> crate::Result<Vec<UtilityTable>> {
    let ws = op.expected_ws();
    let weights: Vec<f64> = op.queries.iter().map(|cq| cq.query.weight).collect();
    model.train(&TrainingView {
        hub: &op.obs,
        ws: &ws,
        weights: &weights,
    })
}

/// Which [`UtilityModel`] backend to instantiate (the CLI's `--model`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// the paper's Markov-reward model (completion probability +
    /// remaining processing time through the model engine)
    Markov,
    /// frequency-only advance probabilities ([`FrequencyModel`])
    Freq,
}

impl ModelKind {
    /// Canonical backend name.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Markov => "markov",
            ModelKind::Freq => "freq",
        }
    }

    /// Instantiate the backend.  The `use_tau` and `max_bins` fields of
    /// [`ModelConfig`] only affect the Markov backend; η (`eta`) gates
    /// both.
    pub fn build(self, cfg: ModelConfig) -> Box<dyn UtilityModel> {
        match self {
            ModelKind::Markov => Box::new(ModelBuilder::with_auto_engine(cfg)),
            ModelKind::Freq => Box::new(FrequencyModel::new(cfg.eta)),
        }
    }
}

impl std::str::FromStr for ModelKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "markov" => Ok(ModelKind::Markov),
            "freq" | "frequency" => Ok(ModelKind::Freq),
            other => anyhow::bail!("unknown model {other:?} (expected markov|freq)"),
        }
    }
}

/// The frequency-only utility model: a trait-proving second backend
/// that skips the Markov-reward machinery entirely.
///
/// A PM at state `s` scores `w_q · Π_{k≥s} p_adv(k)`, where `p_adv(k)`
/// is the observed frequency of *forward* transitions out of state `k`
/// — a crude completion-likelihood estimate with no remaining-time term
/// and no remaining-events binning (one bin spanning the whole window,
/// so [`UtilityTable::lookup`] still decays the utility toward zero as
/// the window runs out).  Roughly the spirit of gSPICE's cheapest
/// learned predictors: strictly less informed than the Markov model,
/// far cheaper to train.
#[derive(Debug, Clone)]
pub struct FrequencyModel {
    /// observations required before the first train (the paper's η)
    pub eta: u64,
    last_train_secs: f64,
}

impl FrequencyModel {
    /// Model requiring `eta` observations before it trains.
    pub fn new(eta: u64) -> Self {
        FrequencyModel {
            eta,
            last_train_secs: 0.0,
        }
    }
}

impl UtilityModel for FrequencyModel {
    fn name(&self) -> &'static str {
        "freq"
    }

    fn ready(&self, hub: &ObservationHub) -> bool {
        hub.total() >= self.eta
    }

    fn train(&mut self, view: &TrainingView<'_>) -> crate::Result<Vec<UtilityTable>> {
        anyhow::ensure!(
            view.hub.queries.len() == view.ws.len()
                && view.ws.len() == view.weights.len(),
            "training view shape mismatch"
        );
        let timer = crate::sim::WallTimer::start();
        let mut out = Vec::with_capacity(view.hub.queries.len());
        for (qs, (&ws, &w)) in view
            .hub
            .queries
            .iter()
            .zip(view.ws.iter().zip(view.weights))
        {
            let m = qs.m;
            // forward-transition frequency per non-final state
            let mut p_adv = vec![0.0f64; m];
            for s in 0..m.saturating_sub(1) {
                let row = &qs.counts[s];
                let n: u64 = row.iter().sum();
                if n > 0 {
                    let fwd: u64 = row[s + 1..].iter().sum();
                    p_adv[s] = fwd as f64 / n as f64;
                }
            }
            // utility[s] = w · Π_{k=s}^{m-2} p_adv(k), built back to
            // front so each state costs one multiply
            let mut row = vec![0.0f64; m];
            let mut prod = 1.0f64;
            for s in (0..m).rev() {
                if s < m - 1 {
                    prod *= p_adv[s];
                }
                row[s] = w * prod;
            }
            out.push(UtilityTable {
                m,
                bs: ws.max(1),
                rows: vec![row],
            });
        }
        self.last_train_secs = timer.elapsed_secs();
        Ok(out)
    }

    fn last_train_secs(&self) -> f64 {
        self.last_train_secs
    }
}

/// E-BL's key-slot utility table: per key *value* (stock symbol /
/// player id / bus id), how often the operator's patterns reference it.
/// Built once from the query set and shared (`Arc`) between the
/// [`crate::shedding::EventBaselineShedder`] and the [`TableSet`]
/// snapshot — one allocation, two readers.  It is static per query set
/// (patterns don't drift), so retrains carry the same `Arc` forward;
/// the snapshot holds it as part of the complete model state, while the
/// strategy reads its own clone of the `Arc`.
#[derive(Debug, Clone, Default)]
pub struct KeyUtilityTable {
    slot: usize,
    // ordered map: lookups are point reads, but the determinism audit
    // bans hash containers from result-affecting modules outright —
    // the table is tiny (pattern-referenced key values), so the
    // O(log n) read costs nothing measurable
    utilities: BTreeMap<i64, f64>,
}

impl KeyUtilityTable {
    /// Build from compiled queries: each reference to a concrete key
    /// value in a pattern raises that value's utility (paper §IV-A: "an
    /// event type receives a higher utility proportional to its
    /// repetition in patterns and in windows").
    pub fn from_compiled(key_slot: usize, queries: &[CompiledQuery]) -> Self {
        let mut utilities: BTreeMap<i64, f64> = BTreeMap::new();
        let mut bump = |preds: &[Predicate]| {
            for p in preds {
                match p {
                    Predicate::AttrCmp { slot, value, .. } if *slot == key_slot => {
                        *utilities.entry(*value as i64).or_insert(0.0) += 1.0;
                    }
                    Predicate::AttrIn { slot, values } if *slot == key_slot => {
                        for v in values {
                            *utilities.entry(*v as i64).or_insert(0.0) += 1.0;
                        }
                    }
                    _ => {}
                }
            }
        };
        for cq in queries {
            for s in &cq.head {
                bump(&s.preds);
            }
            if let Some(g) = &cq.any {
                bump(&g.spec.preds);
            }
        }
        KeyUtilityTable {
            slot: key_slot,
            utilities,
        }
    }

    /// Compile `queries` and build the table
    /// (see [`KeyUtilityTable::from_compiled`]).
    pub fn from_queries(queries: &[Query], key_slot: usize) -> Self {
        let compiled: Vec<CompiledQuery> = queries
            .iter()
            .cloned()
            .map(CompiledQuery::compile)
            .collect();
        Self::from_compiled(key_slot, &compiled)
    }

    /// The attribute slot holding the correlation key.
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// Utility of an event's key value (0 for values no pattern uses).
    #[inline]
    pub fn utility(&self, e: &Event) -> f64 {
        let key = e.attrs[self.slot] as i64;
        self.utilities.get(&key).copied().unwrap_or(0.0)
    }

    /// Distinct key values with non-zero utility.
    pub fn len(&self) -> usize {
        self.utilities.len()
    }

    /// No key value has utility?
    pub fn is_empty(&self) -> bool {
        self.utilities.is_empty()
    }
}

/// An immutable, epoch-numbered model snapshot: everything the shedding
/// hot path reads, swapped atomically as one `Arc`.
///
/// Epoch 0 is the calibration-time install; every drift retrain bumps
/// the epoch by one ([`TableSet::next_epoch`]).  `Operator` and
/// `ShardedOperator` report the epoch they are currently reading via
/// [`OperatorState::table_epoch`]; the sharded runtime broadcasts the
/// `Arc` to every worker, so all shards observe the same epoch between
/// dispatches.
#[derive(Debug, Clone)]
pub struct TableSet {
    /// snapshot version: 0 = initial install, +1 per retrain
    pub epoch: u64,
    /// per-query utility tables (global order; empty = strategies that
    /// never rank by utility, every PM scores 0)
    pub tables: Vec<UtilityTable>,
    /// per-query check-cost factors (global order; empty = leave the
    /// state's current factors untouched)
    pub check_factors: Vec<f64>,
    /// expected window sizes the tables were trained at — snapshot
    /// *metadata* for audits and tests, not consumed by the operator
    /// (empty for externally built tables)
    pub ws: Vec<u64>,
    /// E-BL's key-slot utilities: the same `Arc` the
    /// [`crate::shedding::EventBaselineShedder`] was built with, carried
    /// so the snapshot is the complete model state.  Pattern utilities
    /// are static per query set, so successor epochs carry it unchanged
    /// — swapping in a *different* table here does NOT rewire an
    /// already-built E-BL (it keeps its own `Arc` clone).
    pub key: Option<Arc<KeyUtilityTable>>,
}

impl TableSet {
    /// The epoch-0 snapshot installed at pipeline build time.
    pub fn initial(
        tables: Vec<UtilityTable>,
        check_factors: Vec<f64>,
        key: Option<Arc<KeyUtilityTable>>,
    ) -> Self {
        TableSet {
            epoch: 0,
            tables,
            check_factors,
            ws: Vec::new(),
            key,
        }
    }

    /// The successor snapshot after a retrain: fresh tables, epoch + 1,
    /// cost factors and key table carried over unchanged.
    pub fn next_epoch(&self, tables: Vec<UtilityTable>, ws: Vec<u64>) -> Self {
        TableSet {
            epoch: self.epoch + 1,
            tables,
            check_factors: self.check_factors.clone(),
            ws,
            key: self.key.clone(),
        }
    }

    /// Table of query `q`, if the snapshot carries tables.
    pub fn table(&self, q: usize) -> Option<&UtilityTable> {
        self.tables.get(q)
    }
}

/// Reusable buffers for [`OperatorState::harvest_observations`]: the
/// merged observation statistics plus the per-query expected window
/// sizes (global query order — the sharded runtime collects each
/// worker's local statistics into the global slots; queries are
/// partitioned, so merging is placement, never summation).
#[derive(Debug, Clone)]
pub struct ModelHarvest {
    /// merged per-query statistics
    pub hub: ObservationHub,
    /// expected window size in events per query
    pub ws: Vec<u64>,
}

impl Default for ModelHarvest {
    fn default() -> Self {
        ModelHarvest {
            hub: ObservationHub::new(&[]),
            ws: Vec::new(),
        }
    }
}

/// The train→snapshot→publish loop (paper §III-D, backend-agnostic).
///
/// Owns the [`UtilityModel`], the [`DriftDetector`] baseline and the
/// current [`TableSet`]; [`ModelController::check_and_retrain`]
/// harvests observations from the state (single-threaded or sharded),
/// drift-checks them, and on drift trains a fresh epoch and publishes
/// it through [`OperatorState::install_table_set`] — on the sharded
/// runtime that is the `UpdateTables` broadcast to every worker.
pub struct ModelController {
    model: Box<dyn UtilityModel>,
    threshold: f64,
    weights: Vec<f64>,
    current: Arc<TableSet>,
    drift: Option<DriftDetector>,
    harvest: ModelHarvest,
    retrains: u32,
}

impl ModelController {
    /// Controller over `model` with the given drift `threshold`,
    /// per-query `weights`, and the already-installed `initial`
    /// snapshot (the drift baseline is taken later, at
    /// [`ModelController::begin`]).
    pub fn new(
        model: Box<dyn UtilityModel>,
        threshold: f64,
        weights: Vec<f64>,
        initial: Arc<TableSet>,
    ) -> Self {
        ModelController {
            model,
            threshold,
            weights,
            current: initial,
            drift: None,
            harvest: ModelHarvest::default(),
            retrains: 0,
        }
    }

    /// Install the controller's current snapshot on a state (used when
    /// the controller, not the pipeline, owns the install).
    pub fn install_initial(&mut self, state: &mut dyn OperatorState) {
        state.install_table_set(Arc::clone(&self.current));
    }

    /// Snapshot the drift baseline from the state's current statistics
    /// (call once, at the calibration→measurement boundary).
    pub fn begin(&mut self, state: &dyn OperatorState) {
        state.harvest_observations(&mut self.harvest);
        self.drift = Some(DriftDetector::snapshot(&self.harvest.hub, self.threshold));
    }

    /// Harvest → drift-check → (on drift) train a fresh epoch and
    /// publish it to the state.  Returns whether a retrain happened.
    /// A no-op until [`ModelController::begin`] has set the baseline.
    pub fn check_and_retrain(
        &mut self,
        state: &mut dyn OperatorState,
    ) -> crate::Result<bool> {
        let Some(d) = &self.drift else {
            return Ok(false);
        };
        state.harvest_observations(&mut self.harvest);
        let (_mse, drifted) = d.check(&self.harvest.hub);
        if !drifted {
            return Ok(false);
        }
        // honor the model's η gate: a drift verdict on too few
        // observations (e.g. the forced-drift shape-change path) must
        // not replace working tables with ones trained on noise — the
        // next checkpoint retries once enough statistics accumulate
        if !self.model.ready(&self.harvest.hub) {
            return Ok(false);
        }
        let view = TrainingView {
            hub: &self.harvest.hub,
            ws: &self.harvest.ws,
            weights: &self.weights,
        };
        let tables = self.model.train(&view)?;
        let next = Arc::new(self.current.next_epoch(tables, self.harvest.ws.clone()));
        self.current = Arc::clone(&next);
        state.install_table_set(next);
        self.drift = Some(DriftDetector::snapshot(&self.harvest.hub, self.threshold));
        self.retrains += 1;
        Ok(true)
    }

    /// The snapshot the controller last published (or was given).
    pub fn table_set(&self) -> &Arc<TableSet> {
        &self.current
    }

    /// Epoch of the current snapshot.
    pub fn epoch(&self) -> u64 {
        self.current.epoch
    }

    /// Retrains performed so far.
    pub fn retrains(&self) -> u32 {
        self.retrains
    }

    /// The model backend's name (`"markov"` / `"freq"`).
    pub fn model_name(&self) -> &'static str {
        self.model.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::operator::Operator;
    use crate::query::builtin::q4;

    fn trained_operator() -> Operator {
        let mut op = Operator::new(q4(4, 2_000, 400).queries);
        let mut g = BusGen::with_seed(1);
        for _ in 0..30_000 {
            op.process_event(&g.next_event().unwrap());
        }
        op
    }

    #[test]
    fn model_kind_round_trips_and_builds() {
        for kind in [ModelKind::Markov, ModelKind::Freq] {
            assert_eq!(kind.name().parse::<ModelKind>().unwrap(), kind);
            let model = kind.build(ModelConfig::default());
            assert_eq!(model.name(), kind.name());
        }
        assert!("magic".parse::<ModelKind>().is_err());
        assert_eq!("frequency".parse::<ModelKind>().unwrap(), ModelKind::Freq);
    }

    #[test]
    fn frequency_model_trains_monotone_tables() {
        let op = trained_operator();
        let mut model = FrequencyModel::new(100);
        assert!(model.ready(&op.obs));
        let tables = train_from_operator(&mut model, &op).unwrap();
        assert_eq!(tables.len(), 1);
        let ut = &tables[0];
        assert_eq!(ut.m, 5);
        assert_eq!(ut.rows.len(), 1, "one bin spanning the window");
        // utilities are finite, non-negative, and monotone in state:
        // a PM closer to completion is never worth less
        for s in 0..ut.m {
            let u = ut.rows[0][s];
            assert!(u.is_finite() && u >= 0.0, "s={s} u={u}");
            if s > 0 {
                assert!(ut.rows[0][s] + 1e-12 >= ut.rows[0][s - 1], "s={s}");
            }
        }
        // lookup decays toward zero as the window runs out
        assert!(model.last_train_secs() >= 0.0);
        assert!(ut.lookup(1, 100) <= ut.lookup(1, 2_000) + 1e-12);
        assert_eq!(ut.lookup(1, 0), 0.0);
    }

    #[test]
    fn frequency_model_scales_with_weights() {
        let op = trained_operator();
        let hub = &op.obs;
        let ws = op.expected_ws();
        let mut model = FrequencyModel::new(0);
        let w1 = model
            .train(&TrainingView {
                hub,
                ws: &ws,
                weights: &[1.0],
            })
            .unwrap();
        let w3 = model
            .train(&TrainingView {
                hub,
                ws: &ws,
                weights: &[3.0],
            })
            .unwrap();
        for s in 0..w1[0].m {
            assert!((w3[0].rows[0][s] - 3.0 * w1[0].rows[0][s]).abs() < 1e-12);
        }
    }

    #[test]
    fn table_set_epochs_advance_and_carry_config() {
        let key = Arc::new(KeyUtilityTable::default());
        let set = TableSet::initial(Vec::new(), vec![1.0, 2.0], Some(key));
        assert_eq!(set.epoch, 0);
        assert!(set.table(0).is_none());
        let next = set.next_epoch(Vec::new(), vec![10, 20]);
        assert_eq!(next.epoch, 1);
        assert_eq!(next.check_factors, vec![1.0, 2.0]);
        assert_eq!(next.ws, vec![10, 20]);
        assert!(next.key.is_some());
        assert_eq!(next.next_epoch(Vec::new(), Vec::new()).epoch, 2);
    }

    #[test]
    fn key_utility_table_counts_pattern_references() {
        let queries = crate::query::builtin::q1(1_000).queries;
        let table = KeyUtilityTable::from_queries(&queries, crate::datasets::stock::A_SYMBOL);
        assert!(!table.is_empty());
        assert_eq!(table.slot(), crate::datasets::stock::A_SYMBOL);
        for sym in crate::query::builtin::PATTERN_RANKS {
            let e = Event::new(0, 0, 0, &[sym as f64, 1.0, 1.0]);
            assert!(table.utility(&e) >= 2.0, "sym={sym}");
        }
        let e = Event::new(0, 0, 0, &[400.0, 1.0, 1.0]);
        assert_eq!(table.utility(&e), 0.0);
    }

    #[test]
    fn controller_retrains_on_drift_and_bumps_epoch() {
        let mut op = trained_operator();
        let initial = Arc::new(TableSet::initial(Vec::new(), vec![1.0], None));
        let mut ctl = ModelController::new(
            ModelKind::Freq.build(ModelConfig {
                eta: 100,
                ..ModelConfig::default()
            }),
            1e-12,
            vec![1.0],
            Arc::clone(&initial),
        );
        ctl.install_initial(&mut op);
        assert_eq!(op.table_epoch(), 0);
        // before begin(): no baseline, never retrains
        assert!(!ctl.check_and_retrain(&mut op).unwrap());
        ctl.begin(&op);
        // unchanged statistics: no drift at any threshold
        assert!(!ctl.check_and_retrain(&mut op).unwrap());
        // more observations shift the learned matrix past the tiny
        // threshold: the controller trains and publishes epoch 1
        let mut g = BusGen::with_seed(2);
        for _ in 0..10_000 {
            op.process_event(&g.next_event().unwrap());
        }
        assert!(ctl.check_and_retrain(&mut op).unwrap());
        assert_eq!(ctl.epoch(), 1);
        assert_eq!(ctl.retrains(), 1);
        assert_eq!(op.table_epoch(), 1);
        assert_eq!(ctl.table_set().tables.len(), 1);
        assert_eq!(ctl.model_name(), "freq");
    }
}
