//! Minimal `log` facade backend writing to stderr with a level filter.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{tag}] {}: {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the stderr logger (idempotent). Level comes from
/// `PSPICE_LOG` (`error|warn|info|debug|trace`), default `info`.
pub fn init() {
    let level = match std::env::var("PSPICE_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger fails if called twice; that's fine.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
