//! String interner: maps names (symbols, stop ids, player names…) to dense
//! `u32` ids so events carry integers, not heap strings, on the hot path.

use std::collections::HashMap;

/// Dense string ↔ id bidirectional map.
#[derive(Debug, Default, Clone)]
pub struct Interner {
    by_name: HashMap<String, u32>,
    names: Vec<String>,
}

impl Interner {
    /// Empty interner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `name`, returning its stable dense id.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        id
    }

    /// Id for `name` if already interned.
    pub fn get(&self, name: &str) -> Option<u32> {
        self.by_name.get(name).copied()
    }

    /// Name for `id` (panics on unknown id — ids come from `intern`).
    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    /// Number of interned strings.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if nothing interned yet.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let mut i = Interner::new();
        let a = i.intern("AAPL");
        let b = i.intern("MSFT");
        assert_ne!(a, b);
        assert_eq!(i.intern("AAPL"), a);
        assert_eq!(i.name(a), "AAPL");
        assert_eq!(i.get("MSFT"), Some(b));
        assert_eq!(i.get("GOOG"), None);
        assert_eq!(i.len(), 2);
    }

    #[test]
    fn ids_are_dense() {
        let mut i = Interner::new();
        for k in 0..100 {
            assert_eq!(i.intern(&format!("s{k}")), k);
        }
    }
}
