//! Running statistics (Welford) and small summary helpers used by the
//! metrics module, the latency regressions and the benches.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Default, Clone)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Fresh accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (0 if < 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation (+inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (-inf if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Snapshot into a [`Summary`].
    pub fn summary(&self) -> Summary {
        Summary {
            count: self.n,
            mean: self.mean(),
            stddev: self.stddev(),
            min: self.min,
            max: self.max,
        }
    }
}

/// Immutable snapshot of an [`OnlineStats`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Observation count.
    pub count: u64,
    /// Mean value.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
}

/// Percentile of a (will be sorted) sample, `q` in `[0, 1]`.
pub fn percentile(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    xs.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let frac = pos - lo as f64;
        xs[lo] * (1.0 - frac) + xs[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // direct sample variance
        let var = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / 7.0;
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 1.0), 5.0);
        assert_eq!(percentile(&mut xs, 0.5), 3.0);
        assert!((percentile(&mut xs, 0.25) - 2.0).abs() < 1e-12);
    }
}
