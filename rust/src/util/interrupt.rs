//! Cooperative SIGINT handling for long-running CLI commands (the
//! vendored crate set has no `ctrlc`/`signal-hook`; this is a minimal
//! libc-`signal(2)` shim).
//!
//! [`install`] registers a handler and returns the shared stop flag the
//! handler sets.  Loops that take the flag (e.g.
//! [`crate::pipeline::PipelineBuilder::stop_flag`]) finish their
//! in-flight batch and return their measurements instead of dying
//! mid-run.  A **second** SIGINT restores the default disposition and
//! re-raises, so a hung run can still be killed the ordinary way.
//!
//! On non-unix targets [`install`] returns a flag nothing ever sets.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

/// The handler only ever *reads* this cell (an atomic store through a
/// pre-created `Arc` — no allocation, async-signal-safe); `install`
/// populates it before the handler can fire.
static FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();

/// Register the SIGINT handler (idempotent) and return the stop flag
/// it sets.  The first Ctrl-C flips the flag; the second falls back to
/// the default disposition (process death).
pub fn install() -> Arc<AtomicBool> {
    let flag = FLAG.get_or_init(|| Arc::new(AtomicBool::new(false)));
    imp::register();
    Arc::clone(flag)
}

/// Has the flag been set (by a signal or by hand)?  Mostly for tests;
/// run loops poll the `Arc` they were given directly.
pub fn fired() -> bool {
    FLAG.get().map(|f| f.load(Ordering::SeqCst)).unwrap_or(false)
}

#[cfg(unix)]
mod imp {
    use super::{Ordering, FLAG};

    pub const SIGINT: i32 = 2;
    const SIG_DFL: usize = 0;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn raise(signum: i32) -> i32;
    }

    extern "C" fn on_sigint(sig: i32) {
        if let Some(f) = FLAG.get() {
            if !f.swap(true, Ordering::SeqCst) {
                // first Ctrl-C: cooperative shutdown, run loops notice
                // at their next batch boundary
                return;
            }
        }
        // second Ctrl-C (or a handler without a flag, which cannot
        // happen through `install`): die the ordinary way
        unsafe {
            signal(sig, SIG_DFL);
            raise(sig);
        }
    }

    pub fn register() {
        unsafe {
            signal(SIGINT, on_sigint as usize);
        }
    }

    /// Deliver a real SIGINT to this process (test hook).
    #[cfg(test)]
    pub fn self_interrupt() {
        unsafe {
            raise(SIGINT);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn register() {}
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn a_real_sigint_sets_the_flag_once() {
        let flag = install();
        assert!(!flag.load(Ordering::SeqCst));
        assert!(!fired());
        // `raise` delivers synchronously on the calling thread, so the
        // handler has run by the time it returns
        imp::self_interrupt();
        assert!(flag.load(Ordering::SeqCst), "handler must set the flag");
        assert!(fired());
        // install() hands every caller the same flag
        assert!(install().load(Ordering::SeqCst));
    }
}
