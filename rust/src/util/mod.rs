//! Small self-contained utilities: deterministic RNG, string interning,
//! running statistics, a tiny stderr logger and a SIGINT stop-flag shim
//! for graceful CLI shutdown.
//!
//! The offline crate cache ships no `rand`/`tracing`; these stand-ins are
//! deliberately minimal and fully deterministic (seeded) so every
//! experiment in the harness is reproducible bit-for-bit.

pub mod interner;
pub mod interrupt;
pub mod logger;
pub mod rng;
pub mod stats;

pub use interner::Interner;
pub use rng::Rng;
pub use stats::{OnlineStats, Summary};
