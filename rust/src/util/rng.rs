//! Deterministic PRNG: xoshiro256** seeded through SplitMix64.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2018).  Not cryptographic — used only for synthetic data
//! generation, Bernoulli shedding and property-test case generation.

/// xoshiro256** generator with convenience samplers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n must be > 0). Lemire-style rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply trick, with rejection for exact uniformity.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let low = m as u64;
            if low >= n || low >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/stddev.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponentially distributed value with the given rate (λ).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted_index needs positive total");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for sub-streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chance_estimates_p() {
        let mut r = Rng::seeded(11);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.3).abs() < 0.01, "p={p}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Rng::seeded(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
