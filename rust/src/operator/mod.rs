//! The CEP operator: multi-query pattern matching over windows, with
//! observation capture for the model builder and a virtual cost model
//! for deterministic overload experiments.

pub mod cost;
pub mod observe;
#[allow(clippy::module_inception)]
pub mod operator;
pub mod state;

pub use cost::{CostModel, EST_PMS_PER_CELL};
pub use observe::{DeltaRow, ObservationHub, QueryStats, StatsDelta};
pub use operator::{
    cell_cmp, CellTake, ComplexEvent, Operator, PmRef, ProcessOutcome, RateDigest, ShardSnapshot,
    ShedCell,
};
pub use state::{BatchResult, FailureDrain, OperatorState, PerShard, ShedOutcome, MAX_SHARDS};
