//! Virtual cost model: how much operator time (in virtual nanoseconds)
//! each primitive operation consumes.
//!
//! The paper runs a Java prototype on a fixed machine and overloads it
//! with real wall-clock rates; we replace the wall clock with a
//! deterministic cost model so experiments are reproducible and fast
//! (DESIGN.md §3).  The *relationships* the paper relies on are
//! preserved: event processing latency grows linearly with the number of
//! live PMs (their §III-E regression target), window management adds
//! per-open-window cost, and different queries can have different
//! per-check costs (their Fig. 8 τ_Q1/τ_Q2 factor is `check_factor`).

/// Average live PMs per `(query, window, state)` shed cell on the
/// built-in workloads — the bridge between the paper's per-PM cost
/// framing (`l_s = g(n_pm)`) and the engine's O(cells) shed decision.
/// `shed_scan_ns` is per *cell* and equals the pre-recalibration per-PM
/// scan unit (14 ns) times this factor, so a shed pass over a typical
/// population costs exactly what it did when the model charged per PM;
/// callers that only know a PM count estimate the cell count as
/// `n_pm / EST_PMS_PER_CELL`.
pub const EST_PMS_PER_CELL: f64 = 3.2;

/// Cost model parameters (virtual nanoseconds).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fixed per-event overhead (dequeue, bookkeeping).
    pub base_event_ns: f64,
    /// Per open window per event (window management).
    pub per_window_ns: f64,
    /// Per (PM, event) check, before the per-query factor.
    pub per_check_ns: f64,
    /// Per-query multiplier on `per_check_ns` (Fig. 8's τ factor).
    pub check_factor: Vec<f64>,
    /// Per window-open test per event.
    pub open_check_ns: f64,
    /// Shedder cost per *cell* scanned (utility lookup + selection).
    /// The shed decision ranks `(query, window, state)` cells, not
    /// individual PMs, so its cost is O(cells); the default is the old
    /// per-PM unit (14 ns) × [`EST_PMS_PER_CELL`] for continuity with
    /// the paper's per-PM `g(n_pm)` framing.
    pub shed_scan_ns: f64,
    /// Shedder cost per PM actually dropped.
    pub shed_drop_ns: f64,
    /// E-BL's per-open-window drop-decision cost per event (black-box
    /// shedding works at event granularity inside every window, which
    /// is what makes its overhead grow with window overlap — Fig. 9a).
    pub ebl_per_window_ns: f64,
}

impl CostModel {
    /// Defaults roughly calibrated to a few hundred ns per PM check —
    /// the scale is irrelevant (rates are relative to measured capacity),
    /// only the ratios matter.
    pub fn with_queries(n_queries: usize) -> Self {
        CostModel {
            base_event_ns: 150.0,
            per_window_ns: 12.0,
            per_check_ns: 120.0,
            check_factor: vec![1.0; n_queries],
            open_check_ns: 25.0,
            shed_scan_ns: 14.0 * EST_PMS_PER_CELL,
            shed_drop_ns: 30.0,
            ebl_per_window_ns: 3.0,
        }
    }

    /// Cost of one (PM, event) check for query `q`.
    #[inline]
    pub fn check_ns(&self, q: usize) -> f64 {
        self.per_check_ns * self.check_factor[q]
    }

    /// Cost of a shed pass that scanned `scanned` *cells* and dropped
    /// `dropped` PMs — the O(cells) decision plus the O(dropped)
    /// removal, the engine's realization of the paper's `l_s = g(n_pm)`
    /// (which assumed a per-PM scan).
    #[inline]
    pub fn shed_ns(&self, scanned: usize, dropped: usize) -> f64 {
        self.shed_scan_ns * scanned as f64 + self.shed_drop_ns * dropped as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_scale_checks() {
        let mut c = CostModel::with_queries(2);
        c.check_factor[1] = 4.0;
        assert!((c.check_ns(1) - 4.0 * c.check_ns(0)).abs() < 1e-9);
    }

    #[test]
    fn shed_cost_linear() {
        let c = CostModel::with_queries(1);
        let a = c.shed_ns(100, 10);
        let b = c.shed_ns(200, 20);
        assert!((b - 2.0 * a).abs() < 1e-9);
    }
}
