//! The [`OperatorState`] abstraction: one surface for everything a
//! shedding strategy needs from the engine, implemented by both the
//! single-threaded [`Operator`](super::Operator) and the sharded
//! [`ShardedOperator`](crate::runtime::ShardedOperator).
//!
//! Before this trait existed, strategies were written twice: once
//! against `Operator` (per-event) and once against `ShardedOperator`
//! (per-batch, via ad-hoc inherent methods).  Now a strategy is written
//! once against `&mut dyn OperatorState` and runs unchanged on 1..N
//! worker shards; `parallelism()` is the only knob that differs (the
//! overload detector scales its latency predictions by it).

use std::sync::Arc;

use crate::events::{DropMask, Event};
use crate::model::plane::{ModelHarvest, TableSet};
use crate::util::Rng;

use super::cost::CostModel;
use super::operator::{ComplexEvent, PmRef};

/// Hard cap on worker shards.  Shard counts are small and fixed at
/// pipeline build time, which lets per-shard bookkeeping
/// ([`PerShard`], the dispatch scratch) live in inline fixed-size
/// arrays instead of per-pass heap `Vec`s.
pub const MAX_SHARDS: usize = 32;

/// Merged outcome of processing one event batch on an operator state
/// (any shard count).  For the single-threaded operator the makespan
/// equals the total; for N shards the makespan is the slowest shard's
/// cost (the batch runs in parallel).
#[derive(Debug, Default, Clone)]
pub struct BatchResult {
    /// completions in the canonical deterministic order
    pub completions: Vec<ComplexEvent>,
    /// virtual batch makespan (ns): what the clock advances by
    pub cost_ns_max: f64,
    /// summed virtual cost over all shards (total work, ns)
    pub cost_ns_total: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
}

impl BatchResult {
    /// Zero every counter and clear the completions, keeping their
    /// buffer — readies a recycled result for the next
    /// [`OperatorState::process_batch_into`] call.
    pub fn reset(&mut self) {
        self.completions.clear();
        self.cost_ns_max = 0.0;
        self.cost_ns_total = 0.0;
        self.checks = 0;
        self.opened = 0;
        self.closed = 0;
    }
}

/// Per-shard `(scanned, dropped)` counters of one shed pass, stored
/// inline (no heap — shard counts are bounded by [`MAX_SHARDS`] and
/// known at build time, so a `Vec` per pass was pure allocator churn).
#[derive(Debug, Clone, Copy, Default)]
pub struct PerShard {
    counts: [(usize, usize); MAX_SHARDS],
    len: usize,
}

impl PerShard {
    /// Counters for a single-shard (single-threaded) pass.
    pub fn single(scanned: usize, dropped: usize) -> Self {
        let mut p = PerShard::default();
        p.push(scanned, dropped);
        p
    }

    /// Append one shard's counters.
    pub fn push(&mut self, scanned: usize, dropped: usize) {
        assert!(self.len < MAX_SHARDS, "more shards than MAX_SHARDS");
        self.counts[self.len] = (scanned, dropped);
        self.len += 1;
    }

    /// Number of shards recorded.
    pub fn len(&self) -> usize {
        self.len
    }

    /// No shards recorded?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The recorded `(scanned, dropped)` pairs.
    #[inline]
    pub fn as_slice(&self) -> &[(usize, usize)] {
        &self.counts[..self.len]
    }

    /// Iterate the recorded pairs.
    pub fn iter(&self) -> std::slice::Iter<'_, (usize, usize)> {
        self.as_slice().iter()
    }
}

impl std::ops::Index<usize> for PerShard {
    type Output = (usize, usize);
    fn index(&self, i: usize) -> &(usize, usize) {
        &self.as_slice()[i]
    }
}

impl std::ops::IndexMut<usize> for PerShard {
    fn index_mut(&mut self, i: usize) -> &mut (usize, usize) {
        assert!(i < self.len, "shard index {i} out of range {}", self.len);
        &mut self.counts[i]
    }
}

impl PartialEq for PerShard {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for PerShard {}

/// Failure accounting drained from an operator state: PMs lost to
/// worker deaths (semantically an involuntary 100%-shed round — they
/// flow into `ShedReport::dropped_pms_failure`, charging failures to
/// QoR instead of availability), the worker respawns performed, and —
/// when the checkpoint plane is armed — the state the respawns brought
/// back instead of losing.  The single-threaded operator has no
/// workers to lose, so its drain is always the default zero value.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct FailureDrain {
    /// PMs that died with their worker since the last drain
    pub dropped_pms: u64,
    /// worker respawns since the last drain
    pub recoveries: u64,
    /// PMs restored by snapshot + journal replay instead of being lost
    pub recovered_pms: u64,
    /// journaled events replayed into respawned workers
    pub replayed_events: u64,
    /// PMs dropped by replayed shed directives (already decided before
    /// the crash, booked exactly once — as voluntary shedding)
    pub replayed_drop_pms: u64,
    /// worker hangs detected by the dispatch deadline
    pub hangs_detected: u64,
    /// virtual cost of the replays (charged to the clock by the caller
    /// so recovery cannot hide work from the latency accounting)
    pub replay_cost_ns: f64,
}

/// Outcome of one utility-ordered shed pass (paper Alg. 2).
#[derive(Debug, Default, Clone)]
pub struct ShedOutcome {
    /// PMs scanned globally (the live population before the drop)
    pub scanned: usize,
    /// PMs dropped globally
    pub dropped: usize,
    /// per shard: (cells scanned, PMs dropped) — used to cost the pass
    /// as the slowest shard's O(cells) decision + O(dropped) removal
    /// (shards shed in parallel)
    pub per_shard: PerShard,
}

/// Everything a load-shedding strategy may ask of the engine,
/// independent of how many worker shards back it.
///
/// Implementations: [`Operator`](super::Operator) (`parallelism() ==
/// 1`) and [`ShardedOperator`](crate::runtime::ShardedOperator)
/// (`parallelism() == n_shards()`).
pub trait OperatorState {
    /// Worker parallelism: 1 for the single-threaded operator, the
    /// shard count for the sharded runtime.  Latency predictions scale
    /// by `1/parallelism` (work divides across workers).
    fn parallelism(&self) -> usize;

    /// Global live PM count (the paper's `n_pm`).
    fn pm_count(&self) -> usize;

    /// Open windows across the whole state (E-BL's per-window cost).
    fn open_windows(&self) -> usize;

    /// Completed-over-created PM ratio (the paper's match probability).
    fn match_probability(&self) -> f64;

    /// The virtual cost model used for shed-cost accounting.
    fn cost(&self) -> &CostModel;

    /// Enumerate every live PM with its shedding coordinates into
    /// `buf` (cleared first).  Note that `pm_id` is only unique within
    /// one backend shard; `(query, open_seq, key_bits, state)` is the
    /// sharding-invariant identity.
    fn pm_refs(&self, buf: &mut Vec<PmRef>);

    /// Install an immutable, epoch-numbered model snapshot
    /// ([`TableSet`]): the utility tables [`Self::shed_lowest`] ranks
    /// by plus the per-query check-cost factors, swapped atomically.
    /// The sharded runtime broadcasts the `Arc` to every worker
    /// (`UpdateTables`); drift retraining publishes successor epochs
    /// through this same entry point.
    fn install_table_set(&mut self, set: Arc<TableSet>);

    /// Epoch of the model snapshot the state is currently reading
    /// (0 = the initial install; bumped by every retrain).
    fn table_epoch(&self) -> u64;

    /// Snapshot the state's observation statistics and expected window
    /// sizes into `into` (global query order) — the training inputs for
    /// [`crate::model::ModelController`].  The sharded runtime asks
    /// every worker for its local statistics (`Request::Observations`)
    /// and merges them into the global slots; queries are partitioned
    /// across shards, so the merge is placement, never summation.
    fn harvest_observations(&self, into: &mut ModelHarvest);

    /// Toggle observation capture.
    fn set_obs_enabled(&mut self, enabled: bool);

    /// Process a batch of events, *overwriting* `out` (reset first, so
    /// its completions buffer is recycled — the allocation-free form at
    /// the coordinator API boundary).  Events whose [`DropMask`] bit is
    /// set get window bookkeeping only (black-box event-shedding
    /// semantics: shed events still exist in the stream).
    fn process_batch_into(
        &mut self,
        events: &[Event],
        shed_mask: Option<&DropMask>,
        out: &mut BatchResult,
    );

    /// Allocating convenience around [`Self::process_batch_into`].
    fn process_batch(&mut self, events: &[Event], shed_mask: Option<&DropMask>) -> BatchResult {
        let mut out = BatchResult::default();
        self.process_batch_into(events, shed_mask, &mut out);
        out
    }

    /// Drop the `rho` globally lowest-utility PMs (paper Alg. 2) using
    /// the installed tables; missing tables score a PM at utility 0.
    fn shed_lowest(&mut self, rho: usize) -> ShedOutcome;

    /// Drop `rho` PMs uniformly at random (the PM-BL baseline).
    /// Returns how many were actually dropped.
    fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize;

    /// Remove every PM and window (between experiment phases).
    fn reset_state(&mut self);

    /// Take the failure accounting accumulated since the last drain —
    /// see [`FailureDrain`].  Backends without supervised workers (the
    /// single-threaded operator) keep the default: nothing ever fails
    /// out from under them, so the drain is always zero.
    fn drain_failures(&mut self) -> FailureDrain {
        FailureDrain::default()
    }
}
