//! The [`OperatorState`] abstraction: one surface for everything a
//! shedding strategy needs from the engine, implemented by both the
//! single-threaded [`Operator`](super::Operator) and the sharded
//! [`ShardedOperator`](crate::runtime::ShardedOperator).
//!
//! Before this trait existed, strategies were written twice: once
//! against `Operator` (per-event) and once against `ShardedOperator`
//! (per-batch, via ad-hoc inherent methods).  Now a strategy is written
//! once against `&mut dyn OperatorState` and runs unchanged on 1..N
//! worker shards; `parallelism()` is the only knob that differs (the
//! overload detector scales its latency predictions by it).

use crate::events::Event;
use crate::model::UtilityTable;
use crate::util::Rng;

use super::cost::CostModel;
use super::operator::{ComplexEvent, PmRef};

/// Merged outcome of processing one event batch on an operator state
/// (any shard count).  For the single-threaded operator the makespan
/// equals the total; for N shards the makespan is the slowest shard's
/// cost (the batch runs in parallel).
#[derive(Debug, Default, Clone)]
pub struct BatchResult {
    /// completions in the canonical deterministic order
    pub completions: Vec<ComplexEvent>,
    /// virtual batch makespan (ns): what the clock advances by
    pub cost_ns_max: f64,
    /// summed virtual cost over all shards (total work, ns)
    pub cost_ns_total: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
}

/// Outcome of one utility-ordered shed pass (paper Alg. 2).
#[derive(Debug, Default, Clone)]
pub struct ShedOutcome {
    /// PMs scanned globally (the live population before the drop)
    pub scanned: usize,
    /// PMs dropped globally
    pub dropped: usize,
    /// per shard: (scanned, dropped) — used to cost the pass as the
    /// slowest shard's scan + drop (shards shed in parallel)
    pub per_shard: Vec<(usize, usize)>,
}

/// Everything a load-shedding strategy may ask of the engine,
/// independent of how many worker shards back it.
///
/// Implementations: [`Operator`](super::Operator) (`parallelism() ==
/// 1`) and [`ShardedOperator`](crate::runtime::ShardedOperator)
/// (`parallelism() == n_shards()`).
pub trait OperatorState {
    /// Worker parallelism: 1 for the single-threaded operator, the
    /// shard count for the sharded runtime.  Latency predictions scale
    /// by `1/parallelism` (work divides across workers).
    fn parallelism(&self) -> usize;

    /// Global live PM count (the paper's `n_pm`).
    fn pm_count(&self) -> usize;

    /// Open windows across the whole state (E-BL's per-window cost).
    fn open_windows(&self) -> usize;

    /// Completed-over-created PM ratio (the paper's match probability).
    fn match_probability(&self) -> f64;

    /// The virtual cost model used for shed-cost accounting.
    fn cost(&self) -> &CostModel;

    /// Enumerate every live PM with its shedding coordinates into
    /// `buf` (cleared first).  Note that `pm_id` is only unique within
    /// one backend shard; `(query, open_seq, key_bits, state)` is the
    /// sharding-invariant identity.
    fn pm_refs(&self, buf: &mut Vec<PmRef>);

    /// Install per-query utility tables (global query order), used by
    /// [`Self::shed_lowest`] and refreshed on model retraining.
    fn install_tables(&mut self, tables: &[UtilityTable]);

    /// Apply per-query check-cost factors (global query order).
    fn set_cost_factors(&mut self, factors: &[f64]);

    /// Toggle observation capture.
    fn set_obs_enabled(&mut self, enabled: bool);

    /// Process a batch of events.  Events whose `shed_mask` bit is set
    /// get window bookkeeping only (black-box event-shedding semantics:
    /// shed events still exist in the stream).
    fn process_batch(&mut self, events: &[Event], shed_mask: Option<&[bool]>) -> BatchResult;

    /// Drop the `rho` globally lowest-utility PMs (paper Alg. 2) using
    /// the installed tables; missing tables score a PM at utility 0.
    fn shed_lowest(&mut self, rho: usize) -> ShedOutcome;

    /// Drop `rho` PMs uniformly at random (the PM-BL baseline).
    /// Returns how many were actually dropped.
    fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize;

    /// Remove every PM and window (between experiment phases).
    fn reset_state(&mut self);
}
