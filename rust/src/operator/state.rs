//! The [`OperatorState`] abstraction: one surface for everything a
//! shedding strategy needs from the engine, implemented by both the
//! single-threaded [`Operator`](super::Operator) and the sharded
//! [`ShardedOperator`](crate::runtime::ShardedOperator).
//!
//! Before this trait existed, strategies were written twice: once
//! against `Operator` (per-event) and once against `ShardedOperator`
//! (per-batch, via ad-hoc inherent methods).  Now a strategy is written
//! once against `&mut dyn OperatorState` and runs unchanged on 1..N
//! worker shards; `parallelism()` is the only knob that differs (the
//! overload detector scales its latency predictions by it).

use crate::events::{DropMask, Event};
use crate::model::UtilityTable;
use crate::util::Rng;

use super::cost::CostModel;
use super::operator::{ComplexEvent, PmRef};

/// Hard cap on worker shards.  Shard counts are small and fixed at
/// pipeline build time, which lets per-shard bookkeeping
/// ([`PerShard`], the dispatch scratch) live in inline fixed-size
/// arrays instead of per-pass heap `Vec`s.
pub const MAX_SHARDS: usize = 32;

/// Merged outcome of processing one event batch on an operator state
/// (any shard count).  For the single-threaded operator the makespan
/// equals the total; for N shards the makespan is the slowest shard's
/// cost (the batch runs in parallel).
#[derive(Debug, Default, Clone)]
pub struct BatchResult {
    /// completions in the canonical deterministic order
    pub completions: Vec<ComplexEvent>,
    /// virtual batch makespan (ns): what the clock advances by
    pub cost_ns_max: f64,
    /// summed virtual cost over all shards (total work, ns)
    pub cost_ns_total: f64,
    /// (PM, event) checks performed
    pub checks: u64,
    /// windows opened
    pub opened: usize,
    /// windows closed
    pub closed: usize,
}

/// Per-shard `(scanned, dropped)` counters of one shed pass, stored
/// inline (no heap — shard counts are bounded by [`MAX_SHARDS`] and
/// known at build time, so a `Vec` per pass was pure allocator churn).
#[derive(Debug, Clone, Copy, Default)]
pub struct PerShard {
    counts: [(usize, usize); MAX_SHARDS],
    len: usize,
}

impl PerShard {
    /// Counters for a single-shard (single-threaded) pass.
    pub fn single(scanned: usize, dropped: usize) -> Self {
        let mut p = PerShard::default();
        p.push(scanned, dropped);
        p
    }

    /// Append one shard's counters.
    pub fn push(&mut self, scanned: usize, dropped: usize) {
        assert!(self.len < MAX_SHARDS, "more shards than MAX_SHARDS");
        self.counts[self.len] = (scanned, dropped);
        self.len += 1;
    }

    /// Number of shards recorded.
    pub fn len(&self) -> usize {
        self.len
    }

    /// No shards recorded?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The recorded `(scanned, dropped)` pairs.
    #[inline]
    pub fn as_slice(&self) -> &[(usize, usize)] {
        &self.counts[..self.len]
    }

    /// Iterate the recorded pairs.
    pub fn iter(&self) -> std::slice::Iter<'_, (usize, usize)> {
        self.as_slice().iter()
    }
}

impl std::ops::Index<usize> for PerShard {
    type Output = (usize, usize);
    fn index(&self, i: usize) -> &(usize, usize) {
        &self.as_slice()[i]
    }
}

impl std::ops::IndexMut<usize> for PerShard {
    fn index_mut(&mut self, i: usize) -> &mut (usize, usize) {
        assert!(i < self.len, "shard index {i} out of range {}", self.len);
        &mut self.counts[i]
    }
}

impl PartialEq for PerShard {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for PerShard {}

/// Outcome of one utility-ordered shed pass (paper Alg. 2).
#[derive(Debug, Default, Clone)]
pub struct ShedOutcome {
    /// PMs scanned globally (the live population before the drop)
    pub scanned: usize,
    /// PMs dropped globally
    pub dropped: usize,
    /// per shard: (scanned, dropped) — used to cost the pass as the
    /// slowest shard's scan + drop (shards shed in parallel)
    pub per_shard: PerShard,
}

/// Everything a load-shedding strategy may ask of the engine,
/// independent of how many worker shards back it.
///
/// Implementations: [`Operator`](super::Operator) (`parallelism() ==
/// 1`) and [`ShardedOperator`](crate::runtime::ShardedOperator)
/// (`parallelism() == n_shards()`).
pub trait OperatorState {
    /// Worker parallelism: 1 for the single-threaded operator, the
    /// shard count for the sharded runtime.  Latency predictions scale
    /// by `1/parallelism` (work divides across workers).
    fn parallelism(&self) -> usize;

    /// Global live PM count (the paper's `n_pm`).
    fn pm_count(&self) -> usize;

    /// Open windows across the whole state (E-BL's per-window cost).
    fn open_windows(&self) -> usize;

    /// Completed-over-created PM ratio (the paper's match probability).
    fn match_probability(&self) -> f64;

    /// The virtual cost model used for shed-cost accounting.
    fn cost(&self) -> &CostModel;

    /// Enumerate every live PM with its shedding coordinates into
    /// `buf` (cleared first).  Note that `pm_id` is only unique within
    /// one backend shard; `(query, open_seq, key_bits, state)` is the
    /// sharding-invariant identity.
    fn pm_refs(&self, buf: &mut Vec<PmRef>);

    /// Install per-query utility tables (global query order), used by
    /// [`Self::shed_lowest`] and refreshed on model retraining.
    fn install_tables(&mut self, tables: &[UtilityTable]);

    /// Apply per-query check-cost factors (global query order).
    fn set_cost_factors(&mut self, factors: &[f64]);

    /// Toggle observation capture.
    fn set_obs_enabled(&mut self, enabled: bool);

    /// Process a batch of events.  Events whose [`DropMask`] bit is set
    /// get window bookkeeping only (black-box event-shedding semantics:
    /// shed events still exist in the stream).
    fn process_batch(&mut self, events: &[Event], shed_mask: Option<&DropMask>) -> BatchResult;

    /// Drop the `rho` globally lowest-utility PMs (paper Alg. 2) using
    /// the installed tables; missing tables score a PM at utility 0.
    fn shed_lowest(&mut self, rho: usize) -> ShedOutcome;

    /// Drop `rho` PMs uniformly at random (the PM-BL baseline).
    /// Returns how many were actually dropped.
    fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize;

    /// Remove every PM and window (between experiment phases).
    fn reset_state(&mut self);
}
