//! Observation capture (paper §III-C): per query, aggregated counts of
//! state transitions `<q, s, s'>` and summed processing-time rewards
//! `<q, s, s', t>` from which the model builder learns the transition
//! matrix `T_q` and the reward function `R_q`.
//!
//! Counts are aggregated in place (O(m²) memory per query, no raw log),
//! so observation capture adds O(1) work per (PM, event) check.

use crate::linalg::Mat;

/// Aggregated transition statistics for one query.
#[derive(Debug, Clone)]
pub struct QueryStats {
    /// Markov state count (incl. initial).
    pub m: usize,
    /// `counts[s][s']` — observed one-event transitions.
    pub counts: Vec<Vec<u64>>,
    /// `reward_ns[s][s']` — summed processing time of those transitions.
    pub reward_ns: Vec<Vec<f64>>,
    /// Total observations.
    pub total: u64,
    /// `dirty[s]` — row `s` changed since the last
    /// [`QueryStats::take_delta`].  Drives the sharded runtime's delta
    /// harvests; a fresh or reset instance is all-dirty so the first
    /// harvest ships every row.
    dirty: Vec<bool>,
}

impl QueryStats {
    /// Empty stats for an `m`-state query.
    pub fn new(m: usize) -> Self {
        QueryStats {
            m,
            counts: vec![vec![0; m]; m],
            reward_ns: vec![vec![0.0; m]; m],
            total: 0,
            dirty: vec![true; m],
        }
    }

    /// Record one observation `<s, s', t_ns>`.
    #[inline]
    pub fn record(&mut self, s: u32, s2: u32, t_ns: f64) {
        self.counts[s as usize][s2 as usize] += 1;
        self.reward_ns[s as usize][s2 as usize] += t_ns;
        self.total += 1;
        self.dirty[s as usize] = true;
    }

    /// Record `n` identical observations `<s, s', t_ns>` at once — the
    /// operator's skim path reports a whole cell of self-loop checks
    /// with one call instead of one per PM.  Counts are exact; the
    /// summed reward uses one multiply, which can differ from `n`
    /// sequential [`QueryStats::record`] calls in the last FP ulp
    /// (documented on the skim path, which is where it matters).
    #[inline]
    pub fn record_many(&mut self, s: u32, s2: u32, t_ns: f64, n: u64) {
        self.counts[s as usize][s2 as usize] += n;
        self.reward_ns[s as usize][s2 as usize] += t_ns * n as f64;
        self.total += n;
        self.dirty[s as usize] = true;
    }

    /// Learned transition matrix (rows normalized; final state forced
    /// absorbing; unobserved rows stay put).
    pub fn transition_matrix(&self) -> Mat {
        let mut t = Mat::zeros(self.m, self.m);
        for s in 0..self.m {
            for s2 in 0..self.m {
                t[(s, s2)] = self.counts[s][s2] as f64;
            }
        }
        crate::linalg::markov::absorbing_normalize(&mut t);
        t
    }

    /// Learned expected one-event reward per state:
    /// `r(s) = Σ_{s'} P(s,s') · avg t(s,s')`, which reduces to
    /// (total reward out of s) / (total transitions out of s).
    pub fn expected_reward(&self) -> Vec<f64> {
        (0..self.m)
            .map(|s| {
                let n: u64 = self.counts[s].iter().sum();
                if n == 0 || s == self.m - 1 {
                    0.0
                } else {
                    let tot: f64 = self.reward_ns[s].iter().sum();
                    tot / n as f64
                }
            })
            .collect()
    }

    /// Reset all counters (used at retraining boundaries).  Marks every
    /// row dirty: the zeroed rows must reach the next delta harvest.
    pub fn reset(&mut self) {
        for row in &mut self.counts {
            row.fill(0);
        }
        for row in &mut self.reward_ns {
            row.fill(0.0);
        }
        self.total = 0;
        self.dirty.fill(true);
    }

    /// Overwrite this instance from `src`, reusing its allocations —
    /// the harvest path copies whole hubs at drift-check cadence, and
    /// `Vec::clone_from` recycles the row buffers where a plain
    /// `clone()` would reallocate them every checkpoint.
    pub fn assign_from(&mut self, src: &QueryStats) {
        self.m = src.m;
        self.counts.clone_from(&src.counts);
        self.reward_ns.clone_from(&src.reward_ns);
        self.total = src.total;
        self.dirty.clone_from(&src.dirty);
    }

    /// Snapshot the rows dirtied since the last call — **verbatim
    /// cumulative values**, not arithmetic differences, so applying the
    /// delta to a mirror is bit-identical to a full clone (f64 rewards
    /// never go through extra additions) — and clear the dirty flags.
    ///
    /// The sharded runtime's observation harvest ships these instead of
    /// cloning whole `m × m` count matrices every drift check.
    pub fn take_delta(&mut self) -> StatsDelta {
        let mut rows = Vec::new();
        for s in 0..self.m {
            if self.dirty[s] {
                rows.push(DeltaRow {
                    s: s as u32,
                    counts: self.counts[s].clone(),
                    reward_ns: self.reward_ns[s].clone(),
                });
                self.dirty[s] = false;
            }
        }
        StatsDelta {
            m: self.m,
            total: self.total,
            rows,
        }
    }

    /// Overwrite this instance's dirtied rows from a
    /// [`QueryStats::take_delta`] snapshot.  Resizes (zeroed) on a
    /// state-count change — the sender marks everything dirty whenever
    /// that can happen, so no stale row survives a resize.
    pub fn apply_delta(&mut self, d: &StatsDelta) {
        if self.m != d.m {
            self.m = d.m;
            self.counts.clear();
            self.counts.resize_with(d.m, || vec![0; d.m]);
            self.reward_ns.clear();
            self.reward_ns.resize_with(d.m, || vec![0.0; d.m]);
            self.dirty = vec![true; d.m];
        }
        for row in &d.rows {
            self.counts[row.s as usize].clone_from(&row.counts);
            self.reward_ns[row.s as usize].clone_from(&row.reward_ns);
        }
        self.total = d.total;
    }
}

/// One dirtied row of a [`QueryStats`] matrix pair, by source state.
#[derive(Debug, Clone)]
pub struct DeltaRow {
    /// source state `s`
    pub s: u32,
    /// cumulative `counts[s][..]`, verbatim
    pub counts: Vec<u64>,
    /// cumulative `reward_ns[s][..]`, verbatim
    pub reward_ns: Vec<f64>,
}

/// The rows of one query's statistics dirtied since the last harvest
/// (see [`QueryStats::take_delta`]): what the sharded runtime sends
/// over the worker channel instead of a full matrix clone.
#[derive(Debug, Clone)]
pub struct StatsDelta {
    /// Markov state count of the sender
    pub m: usize,
    /// cumulative total observations
    pub total: u64,
    /// dirtied rows, ascending by state
    pub rows: Vec<DeltaRow>,
}

/// Statistics for all queries of an operator.
#[derive(Debug, Clone)]
pub struct ObservationHub {
    /// per-query stats
    pub queries: Vec<QueryStats>,
    /// capture on/off (off on the ground-truth and measurement-free runs)
    pub enabled: bool,
}

impl Default for ObservationHub {
    /// An empty, enabled hub — the starting point a snapshot's
    /// [`ObservationHub::assign_from`] grows into.
    fn default() -> Self {
        ObservationHub::new(&[])
    }
}

impl ObservationHub {
    /// Hub for queries with the given state counts.
    pub fn new(ms: &[usize]) -> Self {
        ObservationHub {
            queries: ms.iter().map(|&m| QueryStats::new(m)).collect(),
            enabled: true,
        }
    }

    /// Total observations across queries.
    pub fn total(&self) -> u64 {
        self.queries.iter().map(|q| q.total).sum()
    }

    /// Mark every row of every query dirty, forcing the next delta
    /// harvest to ship the full matrices.  The checkpoint plane calls
    /// this after a snapshot import: the restored rows must reach the
    /// coordinator's mirror verbatim, whatever its pre-crash state.
    pub fn mark_all_dirty(&mut self) {
        for q in &mut self.queries {
            q.dirty.fill(true);
        }
    }

    /// Overwrite this hub from `src`, reusing allocations (see
    /// [`QueryStats::assign_from`]).
    pub fn assign_from(&mut self, src: &ObservationHub) {
        self.enabled = src.enabled;
        self.queries.truncate(src.queries.len());
        for (dst, s) in self.queries.iter_mut().zip(&src.queries) {
            dst.assign_from(s);
        }
        for s in &src.queries[self.queries.len()..] {
            self.queries.push(s.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transition_matrix_normalizes() {
        let mut qs = QueryStats::new(3);
        // from state 0: 3 stays, 1 advance
        for _ in 0..3 {
            qs.record(0, 0, 10.0);
        }
        qs.record(0, 1, 30.0);
        let t = qs.transition_matrix();
        assert!((t[(0, 0)] - 0.75).abs() < 1e-12);
        assert!((t[(0, 1)] - 0.25).abs() < 1e-12);
        assert!(t.is_row_stochastic(1e-12));
        // final row absorbing
        assert_eq!(t[(2, 2)], 1.0);
    }

    #[test]
    fn expected_reward_averages() {
        let mut qs = QueryStats::new(3);
        qs.record(0, 0, 10.0);
        qs.record(0, 1, 30.0);
        let r = qs.expected_reward();
        assert!((r[0] - 20.0).abs() < 1e-12);
        assert_eq!(r[1], 0.0); // unobserved
        assert_eq!(r[2], 0.0); // final state
    }

    #[test]
    fn reset_clears() {
        let mut qs = QueryStats::new(2);
        qs.record(0, 1, 5.0);
        qs.reset();
        assert_eq!(qs.total, 0);
        assert_eq!(qs.counts[0][1], 0);
    }

    #[test]
    fn delta_round_trip_is_bit_identical() {
        let mut src = QueryStats::new(3);
        let mut mirror = QueryStats::new(0);
        // first harvest: everything is dirty (fresh instance)
        src.record(0, 1, 10.5);
        src.record(1, 2, 0.1 + 0.2); // a value with FP residue
        let d = src.take_delta();
        assert_eq!(d.rows.len(), 3, "fresh stats ship every row");
        mirror.apply_delta(&d);
        assert_eq!(mirror.counts, src.counts);
        assert_eq!(mirror.total, src.total);
        for (a, b) in mirror.reward_ns.iter().zip(&src.reward_ns) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // quiet harvest: nothing dirty, nothing shipped
        let d = src.take_delta();
        assert!(d.rows.is_empty());
        assert_eq!(d.total, src.total);
        // touch one row: only that row crosses
        src.record_many(2, 2, 7.25, 4);
        let d = src.take_delta();
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.rows[0].s, 2);
        mirror.apply_delta(&d);
        assert_eq!(mirror.counts, src.counts);
        assert_eq!(mirror.total, src.total);
        // reset marks everything dirty so the zeroes propagate
        src.reset();
        let d = src.take_delta();
        assert_eq!(d.rows.len(), 3);
        mirror.apply_delta(&d);
        assert_eq!(mirror.counts, QueryStats::new(3).counts);
        assert_eq!(mirror.total, 0);
    }
}
