//! Observation capture (paper §III-C): per query, aggregated counts of
//! state transitions `<q, s, s'>` and summed processing-time rewards
//! `<q, s, s', t>` from which the model builder learns the transition
//! matrix `T_q` and the reward function `R_q`.
//!
//! Counts are aggregated in place (O(m²) memory per query, no raw log),
//! so observation capture adds O(1) work per (PM, event) check.

use crate::linalg::Mat;

/// Aggregated transition statistics for one query.
#[derive(Debug, Clone)]
pub struct QueryStats {
    /// Markov state count (incl. initial).
    pub m: usize,
    /// `counts[s][s']` — observed one-event transitions.
    pub counts: Vec<Vec<u64>>,
    /// `reward_ns[s][s']` — summed processing time of those transitions.
    pub reward_ns: Vec<Vec<f64>>,
    /// Total observations.
    pub total: u64,
}

impl QueryStats {
    /// Empty stats for an `m`-state query.
    pub fn new(m: usize) -> Self {
        QueryStats {
            m,
            counts: vec![vec![0; m]; m],
            reward_ns: vec![vec![0.0; m]; m],
            total: 0,
        }
    }

    /// Record one observation `<s, s', t_ns>`.
    #[inline]
    pub fn record(&mut self, s: u32, s2: u32, t_ns: f64) {
        self.counts[s as usize][s2 as usize] += 1;
        self.reward_ns[s as usize][s2 as usize] += t_ns;
        self.total += 1;
    }

    /// Record `n` identical observations `<s, s', t_ns>` at once — the
    /// operator's skim path reports a whole cell of self-loop checks
    /// with one call instead of one per PM.  Counts are exact; the
    /// summed reward uses one multiply, which can differ from `n`
    /// sequential [`QueryStats::record`] calls in the last FP ulp
    /// (documented on the skim path, which is where it matters).
    #[inline]
    pub fn record_many(&mut self, s: u32, s2: u32, t_ns: f64, n: u64) {
        self.counts[s as usize][s2 as usize] += n;
        self.reward_ns[s as usize][s2 as usize] += t_ns * n as f64;
        self.total += n;
    }

    /// Learned transition matrix (rows normalized; final state forced
    /// absorbing; unobserved rows stay put).
    pub fn transition_matrix(&self) -> Mat {
        let mut t = Mat::zeros(self.m, self.m);
        for s in 0..self.m {
            for s2 in 0..self.m {
                t[(s, s2)] = self.counts[s][s2] as f64;
            }
        }
        crate::linalg::markov::absorbing_normalize(&mut t);
        t
    }

    /// Learned expected one-event reward per state:
    /// `r(s) = Σ_{s'} P(s,s') · avg t(s,s')`, which reduces to
    /// (total reward out of s) / (total transitions out of s).
    pub fn expected_reward(&self) -> Vec<f64> {
        (0..self.m)
            .map(|s| {
                let n: u64 = self.counts[s].iter().sum();
                if n == 0 || s == self.m - 1 {
                    0.0
                } else {
                    let tot: f64 = self.reward_ns[s].iter().sum();
                    tot / n as f64
                }
            })
            .collect()
    }

    /// Reset all counters (used at retraining boundaries).
    pub fn reset(&mut self) {
        for row in &mut self.counts {
            row.fill(0);
        }
        for row in &mut self.reward_ns {
            row.fill(0.0);
        }
        self.total = 0;
    }

    /// Overwrite this instance from `src`, reusing its allocations —
    /// the harvest path copies whole hubs at drift-check cadence, and
    /// `Vec::clone_from` recycles the row buffers where a plain
    /// `clone()` would reallocate them every checkpoint.
    pub fn assign_from(&mut self, src: &QueryStats) {
        self.m = src.m;
        self.counts.clone_from(&src.counts);
        self.reward_ns.clone_from(&src.reward_ns);
        self.total = src.total;
    }
}

/// Statistics for all queries of an operator.
#[derive(Debug, Clone)]
pub struct ObservationHub {
    /// per-query stats
    pub queries: Vec<QueryStats>,
    /// capture on/off (off on the ground-truth and measurement-free runs)
    pub enabled: bool,
}

impl ObservationHub {
    /// Hub for queries with the given state counts.
    pub fn new(ms: &[usize]) -> Self {
        ObservationHub {
            queries: ms.iter().map(|&m| QueryStats::new(m)).collect(),
            enabled: true,
        }
    }

    /// Total observations across queries.
    pub fn total(&self) -> u64 {
        self.queries.iter().map(|q| q.total).sum()
    }

    /// Overwrite this hub from `src`, reusing allocations (see
    /// [`QueryStats::assign_from`]).
    pub fn assign_from(&mut self, src: &ObservationHub) {
        self.enabled = src.enabled;
        self.queries.truncate(src.queries.len());
        for (dst, s) in self.queries.iter_mut().zip(&src.queries) {
            dst.assign_from(s);
        }
        for s in &src.queries[self.queries.len()..] {
            self.queries.push(s.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transition_matrix_normalizes() {
        let mut qs = QueryStats::new(3);
        // from state 0: 3 stays, 1 advance
        for _ in 0..3 {
            qs.record(0, 0, 10.0);
        }
        qs.record(0, 1, 30.0);
        let t = qs.transition_matrix();
        assert!((t[(0, 0)] - 0.75).abs() < 1e-12);
        assert!((t[(0, 1)] - 0.25).abs() < 1e-12);
        assert!(t.is_row_stochastic(1e-12));
        // final row absorbing
        assert_eq!(t[(2, 2)], 1.0);
    }

    #[test]
    fn expected_reward_averages() {
        let mut qs = QueryStats::new(3);
        qs.record(0, 0, 10.0);
        qs.record(0, 1, 30.0);
        let r = qs.expected_reward();
        assert!((r[0] - 20.0).abs() < 1e-12);
        assert_eq!(r[1], 0.0); // unobserved
        assert_eq!(r[2], 0.0); // final state
    }

    #[test]
    fn reset_clears() {
        let mut qs = QueryStats::new(2);
        qs.record(0, 1, 5.0);
        qs.reset();
        assert_eq!(qs.total, 0);
        assert_eq!(qs.counts[0][1], 0);
    }
}
