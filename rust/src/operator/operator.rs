//! The multi-query CEP operator (paper §II-A): processes the totally
//! ordered input stream event by event, matching every live PM in every
//! open window, emitting complex events on completion, capturing
//! observations for the model builder, and accounting virtual cost.
//!
//! The operator also exposes the shedding primitives the paper's load
//! shedder needs (Alg. 2).  Since a PM's utility is
//! `table[state][bin(R_w)]` and `R_w` is a per-window quantity, every PM
//! of one `(query, window, state)` **cell** scores the same utility; the
//! operator therefore ranks and drops *cells* (tracked incrementally by
//! each window's [`crate::windows::StateCounts`] index) instead of
//! materializing one entry per PM.  Per-PM enumeration
//! ([`Operator::pm_refs`]) is retained
//! for tests and QoR accounting so the equivalence stays checkable.

use std::cmp::Ordering;
use std::sync::Arc;

use crate::events::{DropMask, Event};
use crate::model::plane::{ModelHarvest, TableSet};
use crate::model::UtilityTable;
use crate::nfa::{CompiledQuery, PartialMatch, StepResult};
use crate::query::{OpenPolicy, Query, WindowSpec};
use crate::util::Rng;
use crate::windows::{QueryWindows, Window};

use super::cost::CostModel;
use super::observe::ObservationHub;
use super::state::{BatchResult, OperatorState, PerShard, ShedOutcome};

/// A detected complex event.  Identity `(query, window_open_seq,
/// key_bits)` is stable across shedding decisions, which is what makes
/// false-negative accounting well-defined.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ComplexEvent {
    /// Query index within the operator.
    pub query: usize,
    /// Opening sequence number of the window it completed in.
    pub window_open_seq: u64,
    /// Bound correlation keys of the completing PM.
    pub key_bits: u64,
    /// Sequence number of the completing event.
    pub completed_seq: u64,
}

/// Result of processing one event.
#[derive(Debug, Default, Clone)]
pub struct ProcessOutcome {
    /// Complex events detected while processing this event.
    pub completions: Vec<ComplexEvent>,
    /// Virtual processing cost of this event (ns).
    pub cost_ns: f64,
    /// Number of (PM, event) checks performed.
    pub checks: u64,
    /// Windows opened / closed by this event.
    pub opened: usize,
    /// Windows closed by this event.
    pub closed: usize,
}

impl ProcessOutcome {
    /// Zero every counter and clear the completions, keeping their
    /// buffer — readies a reused outcome for the next
    /// [`Operator::process_event_into`] call.
    pub fn reset(&mut self) {
        self.completions.clear();
        self.cost_ns = 0.0;
        self.checks = 0;
        self.opened = 0;
        self.closed = 0;
    }
}

/// Coordinates of one PM for the shedder.
#[derive(Debug, Clone, Copy)]
pub struct PmRef {
    /// query index
    pub query: usize,
    /// current state
    pub state: u32,
    /// expected remaining events in its window
    pub remaining: u64,
    /// unique PM id (used by [`Operator::drop_pms`])
    pub pm_id: u64,
    /// opening sequence number of the PM's window (sharding-invariant
    /// identity, used for deterministic victim tie-breaking)
    pub open_seq: u64,
    /// bound correlation keys of the PM (identity component)
    pub key_bits: u64,
}

/// One non-empty `(query, window, state)` shedding cell: `count` live
/// PMs sharing one utility.  The unit the shedder ranks — there are
/// typically orders of magnitude fewer cells than PMs.
#[derive(Debug, Clone, Copy)]
pub struct ShedCell {
    /// looked-up utility (shared by every PM in the cell)
    pub utility: f64,
    /// query index (global in cross-shard exchanges)
    pub query: usize,
    /// opening sequence number of the cell's window
    pub open_seq: u64,
    /// NFA state of the cell's PMs
    pub state: u32,
    /// live PMs in the cell
    pub count: u32,
}

/// A drop instruction against one cell: remove the first `take` PMs of
/// the cell in window position order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellTake {
    /// query index (local to the executing operator)
    pub query: usize,
    /// opening sequence number of the cell's window
    pub open_seq: u64,
    /// NFA state of the cell
    pub state: u32,
    /// PMs to drop from the cell (≤ its live count)
    pub take: u32,
}

/// Total order over shedding cells: utility first (NaN-safe — a
/// poisoned NaN utility sorts above every number, so such cells are
/// treated as high-utility and survive), then the sharding-invariant
/// cell identity `(query, open_seq, state)`.  Together with the
/// first-`take`-in-position-order rule of [`CellTake`], this defines
/// the engine's deterministic victim selection: the per-PM order
/// `(utility, query, open_seq, state, window position)`, identical on
/// one shard and on N.
pub fn cell_cmp(a: &ShedCell, b: &ShedCell) -> Ordering {
    a.utility
        .total_cmp(&b.utility)
        .then_with(|| a.query.cmp(&b.query))
        .then_with(|| a.open_seq.cmp(&b.open_seq))
        .then_with(|| a.state.cmp(&b.state))
}

/// Frozen scalar digest of the operator's stream-rate state: the last
/// processed position and the events-per-ms EWMA that time-window
/// `R_w` estimates read.  Every operator folds every event into its
/// digest — which makes the digest identical across shards and
/// reproducible coordinator-side, so a worker whose irrelevant batches
/// were skipped can be brought bit-exactly current with one
/// [`Operator::set_rate_digest`] instead of replaying the events.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateDigest {
    /// EWMA of events per millisecond of source time
    pub events_per_ms: f64,
    /// timestamp of the previous fold (EWMA denominator anchor)
    pub prev_ts: u64,
    /// sequence number of the last folded event
    pub last_seq: u64,
    /// timestamp of the last folded event
    pub last_ts: u64,
}

impl Default for RateDigest {
    fn default() -> Self {
        RateDigest {
            events_per_ms: 1.0,
            prev_ts: 0,
            last_seq: 0,
            last_ts: 0,
        }
    }
}

impl RateDigest {
    /// Fold one event into the digest — the single definition of the
    /// rate update, shared by event processing, shed-event bookkeeping
    /// and the sharded coordinator's mirror, so all three produce the
    /// same floating-point sequence.
    #[inline]
    pub fn fold(&mut self, e: &Event) {
        if e.ts_ms > self.prev_ts {
            let inst = 1.0 / (e.ts_ms - self.prev_ts) as f64;
            self.events_per_ms = 0.999 * self.events_per_ms + 0.001 * inst;
        }
        self.prev_ts = e.ts_ms;
        self.last_seq = e.seq;
        self.last_ts = e.ts_ms;
    }
}

/// A compact, self-contained copy of one operator's matching state:
/// live PMs and window positions (with their [`crate::windows::StateCounts`]
/// cell indexes), the PM-id/created/completed counters, the stream-rate
/// digest and the observation-stat rows.  What the checkpoint plane
/// (`runtime/sharded/checkpoint.rs`) ships per shard every
/// `checkpoint_every` dispatches, and what `respawn` restores before
/// replaying the journal.
///
/// Model tables, check-cost factors, the obs-enabled flag and routing
/// are deliberately absent: the coordinator holds the authoritative
/// copies and reinstalls them on every respawn, so snapshotting them
/// would only create a second source of truth.
#[derive(Debug, Clone, Default)]
pub struct ShardSnapshot {
    /// per-query open windows (PMs, claims, cell counts)
    pub wins: Vec<QueryWindows>,
    /// next fresh PM id
    pub next_pm_id: u64,
    /// cached live PM count
    pub n_pms: usize,
    /// total PMs ever created
    pub pms_created: u64,
    /// total complex events ever emitted
    pub completions_total: u64,
    /// stream-rate digest at capture time
    pub rate: RateDigest,
    /// observation statistics (verbatim cumulative rows)
    pub obs: ObservationHub,
}

/// The CEP operator.
#[derive(Clone)]
pub struct Operator {
    /// Compiled queries.
    pub queries: Vec<CompiledQuery>,
    /// Per-query open windows.
    pub wins: Vec<QueryWindows>,
    /// Cost model.
    pub cost: CostModel,
    /// Observation capture.
    pub obs: ObservationHub,
    next_pm_id: u64,
    /// cached total PM count (kept incrementally)
    n_pms: usize,
    /// total PMs ever created (match-probability denominator)
    pub pms_created: u64,
    /// total complex events ever emitted (match-probability numerator)
    pub completions_total: u64,
    /// stream-rate digest: last processed position and the
    /// events-per-ms EWMA (for `R_w` of time windows)
    rate: RateDigest,
    /// per-query utility tables for [`Operator::shed_lowest`]
    /// (installed via [`OperatorState::install_table_set`] or the
    /// inherent [`Operator::install_tables`]; may be empty, in which
    /// case every PM scores utility 0)
    tables: Vec<UtilityTable>,
    /// epoch of the installed [`TableSet`] (0 until one is installed)
    table_epoch: u64,
    /// scratch buffers reused across shed passes (no hot-path alloc)
    shed_scratch: Vec<PmRef>,
    shed_cells: Vec<ShedCell>,
    shed_takes: Vec<CellTake>,
    shed_group: Vec<(u32, u32)>,
    shed_ids: Vec<u64>,
    /// per-event outcome reused by [`OperatorState::process_batch`]
    batch_scratch: ProcessOutcome,
    /// type-routed skim enabled (default on): events whose type no step
    /// of a query consumes take the bulk-accounted bookkeeping path for
    /// that query instead of the per-PM match loop
    type_routing: bool,
}

impl Operator {
    /// Build an operator for a query set.
    pub fn new(queries: Vec<Query>) -> Self {
        let compiled: Vec<CompiledQuery> =
            queries.into_iter().map(CompiledQuery::compile).collect();
        let ms: Vec<usize> = compiled.iter().map(|c| c.m).collect();
        let n = compiled.len();
        Operator {
            wins: (0..n).map(|_| QueryWindows::default()).collect(),
            obs: ObservationHub::new(&ms),
            cost: CostModel::with_queries(n),
            queries: compiled,
            next_pm_id: 0,
            n_pms: 0,
            pms_created: 0,
            completions_total: 0,
            rate: RateDigest::default(),
            tables: Vec::new(),
            table_epoch: 0,
            shed_scratch: Vec::new(),
            shed_cells: Vec::new(),
            shed_takes: Vec::new(),
            shed_group: Vec::new(),
            shed_ids: Vec::new(),
            batch_scratch: ProcessOutcome::default(),
            type_routing: true,
        }
    }

    /// Enable or disable the type-routed skim path (on by default).
    /// Routing is result-equivalent by construction — a skimmed event's
    /// type matches no step, so no PM could have advanced — and its
    /// virtual-cost accounting equals the modeled per-PM loop exactly
    /// in real arithmetic (per-window multiply instead of per-PM adds,
    /// so the FP rounding of `cost_ns` can differ in the last ulp).
    /// Disabling it restores the PR 3 behavior for comparison runs.
    pub fn set_type_routing(&mut self, enabled: bool) {
        self.type_routing = enabled;
    }

    /// Current number of live partial matches (paper's `n_pm`).
    #[inline]
    pub fn pm_count(&self) -> usize {
        self.n_pms
    }

    /// Current stream position `(seq, ts)`.
    pub fn position(&self) -> (u64, u64) {
        (self.rate.last_seq, self.rate.last_ts)
    }

    /// EWMA estimate of events per millisecond of source time.
    pub fn events_per_ms(&self) -> f64 {
        self.rate.events_per_ms
    }

    /// Snapshot of the stream-rate digest (see [`RateDigest`]).
    pub fn rate_digest(&self) -> RateDigest {
        self.rate
    }

    /// Overwrite the stream-rate digest — the sharded coordinator's
    /// resync path for a worker whose irrelevant batches were skipped
    /// (the coordinator folds the same events into a mirror digest, so
    /// installing it is bit-identical to having processed them).
    pub fn set_rate_digest(&mut self, d: RateDigest) {
        self.rate = d;
    }

    /// Expected window size in events for each query (count windows
    /// exact; time windows via the rate estimate) — the `ws` inputs of
    /// a [`crate::model::TrainingView`].
    pub fn expected_ws(&self) -> Vec<u64> {
        let mut out = Vec::new();
        self.expected_ws_into(&mut out);
        out
    }

    /// [`Operator::expected_ws`] into a recycled buffer (cleared
    /// first) — the harvest path runs at drift-check cadence and must
    /// not reallocate per checkpoint.
    pub fn expected_ws_into(&self, out: &mut Vec<u64>) {
        out.clear();
        out.extend(self.queries.iter().map(|cq| match cq.query.window {
            WindowSpec::Count(ws) => ws,
            WindowSpec::TimeMs(ms) => {
                (ms as f64 * self.rate.events_per_ms).ceil().max(1.0) as u64
            }
        }));
    }

    /// Epoch of the installed model snapshot (0 until a [`TableSet`]
    /// is installed).
    pub fn table_epoch(&self) -> u64 {
        self.table_epoch
    }

    /// Apply a model snapshot with an explicit query mapping:
    /// `local_to_global[l]` is the global index of this operator's
    /// `l`-th query (identity for the single-threaded operator; the
    /// shard assignment for a worker).  Empty `tables` clear the
    /// installed tables; empty `check_factors` leave the cost model
    /// untouched.
    pub fn apply_table_set(&mut self, set: &TableSet, local_to_global: &[usize]) {
        assert_eq!(
            local_to_global.len(),
            self.queries.len(),
            "one mapping entry per local query"
        );
        // loud, uniform validation across backends: a partial snapshot
        // is a caller bug, not something to degrade around
        if let Some(&max_g) = local_to_global.iter().max() {
            assert!(
                set.tables.is_empty() || set.tables.len() > max_g,
                "table set misses query {max_g}: one table per query"
            );
            assert!(
                set.check_factors.is_empty() || set.check_factors.len() > max_g,
                "table set misses a check factor for query {max_g}"
            );
        }
        if set.tables.is_empty() {
            self.tables.clear();
        } else {
            self.tables = local_to_global
                .iter()
                .map(|&g| set.tables[g].clone())
                .collect();
        }
        if !set.check_factors.is_empty() {
            for (l, &g) in local_to_global.iter().enumerate() {
                self.cost.check_factor[l] = set.check_factors[g];
            }
        }
        self.table_epoch = set.epoch;
    }

    /// Does this query's window multi-seed (slide-opened windows track
    /// one PM per correlation key, e.g. Q4's per-stop PMs)?
    #[inline]
    fn multi_seed(cq: &CompiledQuery) -> bool {
        matches!(cq.query.open, OpenPolicy::EveryK(_))
    }

    /// Process one event through every query and window.
    pub fn process_event(&mut self, e: &Event) -> ProcessOutcome {
        let mut out = ProcessOutcome::default();
        self.process_event_into(e, &mut out);
        out
    }

    /// Process one event, *accumulating* into `out`: counters and costs
    /// add, completions append.  The allocation-free form of
    /// [`Operator::process_event`] — callers reuse one
    /// [`ProcessOutcome`] (see [`ProcessOutcome::reset`]) across a
    /// whole batch so the per-event hot path never touches the heap.
    // audit: no-alloc
    pub fn process_event_into(&mut self, e: &Event, out: &mut ProcessOutcome) {
        out.cost_ns += self.cost.base_event_ns;
        // rate estimate for time-window R_w
        self.rate.fold(e);

        // disjoint field borrows for the match loop
        let routing = self.type_routing;
        let Operator {
            queries,
            wins,
            cost,
            obs,
            next_pm_id,
            n_pms,
            pms_created,
            completions_total,
            ..
        } = self;
        for (qi, cq) in queries.iter().enumerate() {
            let spec = cq.query.window;
            let qw = &mut wins[qi];
            // 1. expire windows that ended before this event
            let closed = qw.expire(spec, e.seq, e.ts_ms);
            out.closed += closed.windows;
            *n_pms -= closed.pms;
            // 2. maybe open a new window (the opening event is processed
            //    inside it, like the paper's bus example)
            out.cost_ns += cost.open_check_ns;
            if qw.should_open(cq, e) {
                qw.open(e, next_pm_id);
                *n_pms += 1;
                *pms_created += 1;
                out.opened += 1;
            }
            // 3. match against every PM of every open window
            let check_ns = cost.check_ns(qi);
            let multi_seed = Self::multi_seed(cq);
            out.cost_ns += cost.per_window_ns * qw.windows.len() as f64;
            // type-routed skim: no step (or OnMatch open spec) of this
            // query consumes e's type, so no PM can advance and no
            // observation can leave the diagonal — charge the modeled
            // per-PM check cost in bulk off the cell index and move on.
            // O(windows) instead of O(PMs); the modeled operator still
            // "checks" every PM (checks/cost/self-loop observations are
            // accounted identically, with per-cell multiplies replacing
            // per-PM adds — same value in real arithmetic).
            if routing && !cq.types.contains(e.etype) {
                for w in qw.windows.iter() {
                    let n = w.pms.len() as u64;
                    if n == 0 {
                        continue;
                    }
                    out.checks += n;
                    out.cost_ns += check_ns * n as f64;
                    if obs.enabled {
                        let obs_q = &mut obs.queries[qi];
                        for (s, c) in w.counts.iter_nonzero() {
                            obs_q.record_many(s, s, check_ns, c as u64);
                        }
                    }
                }
                continue;
            }
            // fast path for key-free sequences (Q1/Q2 shape): evaluate
            // the step predicates ONCE per event, then each PM check is
            // a bit test.  Virtual-cost and observation accounting are
            // identical to the generic path (the modeled operator still
            // checks every PM — only our wall-clock shrinks).
            if cq.key_free_seq {
                let mask = cq.step_mask(e);
                let obs_on = obs.enabled;
                let obs_q = &mut obs.queries[qi];
                let final_state = (cq.m - 1) as u32;
                for w in qw.windows.iter_mut() {
                    let open_seq = w.open_seq;
                    let Window { pms, counts, .. } = w;
                    let mut i = 0;
                    while i < pms.len() {
                        let pm = &mut pms[i];
                        let s = pm.state;
                        let advanced = mask & (1u64 << s) != 0;
                        out.checks += 1;
                        out.cost_ns += check_ns;
                        if advanced {
                            pm.state = s + 1;
                        }
                        if obs_on {
                            obs_q.record(s, pm.state, check_ns);
                        }
                        if advanced && pm.state == final_state {
                            *completions_total += 1;
                            out.completions.push(ComplexEvent {
                                query: qi,
                                window_open_seq: open_seq,
                                key_bits: pm.key_bits(),
                                completed_seq: e.seq,
                            });
                            counts.dec(s);
                            pms.swap_remove(i);
                            *n_pms -= 1;
                        } else {
                            if advanced {
                                counts.advance(s, s + 1);
                            }
                            i += 1;
                        }
                    }
                }
                continue;
            }
            for w in qw.windows.iter_mut() {
                let open_seq = w.open_seq;
                let mut new_seeds = 0usize;
                let Window { pms, claimed, counts, .. } = w;
                let mut i = 0;
                while i < pms.len() {
                    let pm = &mut pms[i];
                    let s_before = pm.state;
                    let was_seed = s_before == 0;
                    let r = cq.try_advance(pm, e);
                    out.checks += 1;
                    out.cost_ns += check_ns;
                    // multi-seed key dedup: a seed that just bound an
                    // already-claimed key must not advance (another PM
                    // already tracks that correlation group).  The
                    // membership test is O(log k) in either `ClaimSet`
                    // representation.
                    if multi_seed
                        && was_seed
                        && r != StepResult::NoMatch
                        && claimed.contains(pm.key_bits())
                    {
                        // revert: re-seed in place.  The check still
                        // happened and its cost was charged, so the
                        // observation must be recorded as a self-loop —
                        // skipping it biased the transition matrix.
                        if obs.enabled {
                            obs.queries[qi].record(s_before, s_before, check_ns);
                        }
                        let id = pm.id;
                        let opened = pm.opened_seq;
                        *pm = PartialMatch::seed(id, opened);
                        i += 1;
                        continue;
                    }
                    if obs.enabled {
                        let s_after = pm.state;
                        obs.queries[qi].record(s_before, s_after, check_ns);
                    }
                    match r {
                        StepResult::NoMatch => {
                            i += 1;
                        }
                        StepResult::Advanced => {
                            counts.advance(s_before, pm.state);
                            if multi_seed && was_seed {
                                claimed.insert(pm.key_bits());
                                new_seeds += 1;
                            }
                            i += 1;
                        }
                        StepResult::Completed => {
                            *completions_total += 1;
                            out.completions.push(ComplexEvent {
                                query: qi,
                                window_open_seq: open_seq,
                                key_bits: pm.key_bits(),
                                completed_seq: e.seq,
                            });
                            if multi_seed && was_seed {
                                // single-step any-group completed from seed
                                claimed.insert(pm.key_bits());
                                new_seeds += 1;
                            }
                            counts.dec(s_before);
                            pms.swap_remove(i);
                            *n_pms -= 1;
                        }
                    }
                }
                for _ in 0..new_seeds {
                    pms.push(PartialMatch::seed(*next_pm_id, open_seq));
                    counts.inc(0);
                    *next_pm_id += 1;
                    *n_pms += 1;
                    *pms_created += 1;
                }
            }
        }
    }

    /// Window bookkeeping only (expiry + opening), without PM matching.
    ///
    /// Used for events *dropped by a black-box shedder* (E-BL): per the
    /// eSPICE/E-BL semantics, events are shed from *within* windows, so
    /// window open/close predicates still see every event; only the
    /// matching work is saved.
    pub fn process_bookkeeping(&mut self, e: &Event) -> ProcessOutcome {
        let mut out = ProcessOutcome::default();
        self.process_bookkeeping_into(e, &mut out);
        out
    }

    /// [`Operator::process_bookkeeping`], accumulating into a reused
    /// outcome — the shed-event counterpart of
    /// [`Operator::process_event_into`].
    pub fn process_bookkeeping_into(&mut self, e: &Event, out: &mut ProcessOutcome) {
        out.cost_ns += self.cost.base_event_ns;
        // rate estimate for time-window R_w — identical to
        // `process_event`: dropped events still arrive, so the stream
        // rate the utility lookups depend on must not go stale
        self.rate.fold(e);
        let Operator {
            queries,
            wins,
            cost,
            next_pm_id,
            n_pms,
            pms_created,
            ..
        } = self;
        for (qi, cq) in queries.iter().enumerate() {
            let qw = &mut wins[qi];
            let closed = qw.expire(cq.query.window, e.seq, e.ts_ms);
            out.closed += closed.windows;
            *n_pms -= closed.pms;
            out.cost_ns += cost.open_check_ns;
            if qw.should_open(cq, e) {
                qw.open(e, next_pm_id);
                *n_pms += 1;
                *pms_created += 1;
                out.opened += 1;
            }
        }
    }

    /// Ratio of completed PMs to created PMs so far — the paper's
    /// *match probability* (computed on the ground-truth run).
    pub fn match_probability(&self) -> f64 {
        if self.pms_created == 0 {
            0.0
        } else {
            self.completions_total as f64 / self.pms_created as f64
        }
    }

    /// Enumerate every live PM with its shedding coordinates.
    ///
    /// Retained for tests and QoR accounting; the shed path itself works
    /// on [`Operator::cell_refs`], which is O(cells) instead of O(n_pm).
    pub fn pm_refs(&self, buf: &mut Vec<PmRef>) {
        buf.clear();
        for (qi, qw) in self.wins.iter().enumerate() {
            let spec = self.queries[qi].query.window;
            for w in &qw.windows {
                let remaining = w.remaining_events(
                    spec,
                    self.rate.last_seq,
                    self.rate.last_ts,
                    self.rate.events_per_ms,
                );
                for pm in &w.pms {
                    buf.push(PmRef {
                        query: qi,
                        state: pm.state,
                        remaining,
                        pm_id: pm.id,
                        open_seq: w.open_seq,
                        key_bits: pm.key_bits(),
                    });
                }
            }
        }
    }

    /// Enumerate every non-empty `(query, window, state)` cell with its
    /// table utility into `buf` (cleared first), straight off each
    /// window's incrementally-maintained [`crate::windows::StateCounts`]
    /// index — one utility lookup per *cell*, no per-PM work.
    pub fn cell_refs(&self, buf: &mut Vec<ShedCell>) {
        buf.clear();
        for (qi, qw) in self.wins.iter().enumerate() {
            let spec = self.queries[qi].query.window;
            let table = self.tables.get(qi);
            for w in &qw.windows {
                if w.pms.is_empty() {
                    continue;
                }
                let remaining = w.remaining_events(
                    spec,
                    self.rate.last_seq,
                    self.rate.last_ts,
                    self.rate.events_per_ms,
                );
                for (state, count) in w.counts.iter_nonzero() {
                    let utility = table.map_or(0.0, |t| t.lookup(state, remaining));
                    buf.push(ShedCell {
                        utility,
                        query: qi,
                        open_seq: w.open_seq,
                        state,
                        count,
                    });
                }
            }
        }
    }

    /// Execute cell drop instructions *in place*: for each take, remove
    /// the first `take` PMs of the cell in window position order (the
    /// deterministic tie-break documented on [`cell_cmp`]).  `takes`
    /// must be grouped by window — sorted by `(query, open_seq)` — so
    /// each affected window is rewritten exactly once.  Returns how
    /// many PMs were dropped.
    // audit: no-alloc
    pub fn drop_cells(&mut self, takes: &[CellTake]) -> usize {
        debug_assert!(
            takes
                .windows(2)
                .all(|p| (p[0].query, p[0].open_seq) <= (p[1].query, p[1].open_seq)),
            "cell takes must be grouped by (query, open_seq)"
        );
        let mut group = std::mem::take(&mut self.shed_group);
        let mut dropped = 0usize;
        let mut i = 0;
        while i < takes.len() {
            let (q, open_seq) = (takes[i].query, takes[i].open_seq);
            group.clear();
            while i < takes.len() && takes[i].query == q && takes[i].open_seq == open_seq {
                if takes[i].take > 0 {
                    group.push((takes[i].state, takes[i].take));
                }
                i += 1;
            }
            if group.is_empty() {
                continue;
            }
            let qw = &mut self.wins[q];
            let w_idx = qw
                .windows
                .binary_search_by(|w| w.open_seq.cmp(&open_seq))
                .expect("victim cell's window must still be open");
            let w = &mut qw.windows[w_idx];
            let want: usize = group.iter().map(|&(_, t)| t as usize).sum();
            let got = w.retain_pms(|pm| {
                match group.iter_mut().find(|g| g.0 == pm.state && g.1 > 0) {
                    Some(g) => {
                        g.1 -= 1;
                        false
                    }
                    None => true,
                }
            });
            debug_assert_eq!(got, want, "cell takes must name live PMs");
            dropped += got;
        }
        self.n_pms -= dropped;
        self.shed_group = group;
        dropped
    }

    /// Drop the PMs whose ids are in `ids` (must be sorted ascending —
    /// membership is a binary search, keeping this module free of hash
    /// containers per the determinism audit).  Returns how many were
    /// actually removed.
    pub fn drop_pms(&mut self, ids: &[u64]) -> usize {
        debug_assert!(ids.windows(2).all(|p| p[0] <= p[1]), "drop_pms ids must be sorted");
        let mut dropped = 0;
        for qw in &mut self.wins {
            for w in &mut qw.windows {
                dropped += w.retain_pms(|pm| ids.binary_search(&pm.id).is_err());
            }
        }
        self.n_pms -= dropped;
        dropped
    }

    /// Drop `rho` PMs uniformly at random (the PM-BL baseline), through
    /// the operator-owned shed scratch buffers — no per-call `Vec` or
    /// hash-set allocation.
    pub fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize {
        let mut refs = std::mem::take(&mut self.shed_scratch);
        self.pm_refs(&mut refs);
        if refs.is_empty() || rho == 0 {
            self.shed_scratch = refs;
            return 0;
        }
        let rho = rho.min(refs.len());
        rng.shuffle(&mut refs);
        let mut ids = std::mem::take(&mut self.shed_ids);
        ids.clear();
        ids.extend(refs[..rho].iter().map(|r| r.pm_id));
        ids.sort_unstable();
        let mut dropped = 0;
        for qw in &mut self.wins {
            for w in &mut qw.windows {
                dropped += w.retain_pms(|pm| ids.binary_search(&pm.id).is_err());
            }
        }
        self.n_pms -= dropped;
        self.shed_scratch = refs;
        self.shed_ids = ids;
        dropped
    }

    /// Remove every PM and window (used between experiment phases).
    pub fn reset_state(&mut self) {
        for qw in &mut self.wins {
            qw.windows.clear();
        }
        self.n_pms = 0;
    }

    /// Open windows across all queries.
    pub fn open_windows(&self) -> usize {
        self.wins.iter().map(|q| q.windows.len()).sum()
    }

    /// Install the utility tables [`Operator::shed_lowest`] ranks cells
    /// by (one table per query; model retraining replaces them).
    pub fn install_tables(&mut self, tables: &[UtilityTable]) {
        self.tables = tables.to_vec();
    }

    /// Paper Algorithm 2: drop the `rho` lowest-utility PMs, ranked by
    /// the installed tables (a PM whose query has no table scores 0).
    ///
    /// Works on `(query, window, state)` cells: every PM of a cell
    /// shares one utility, so the pass enumerates and sorts O(cells)
    /// entries instead of O(n_pm), then drops whole cells in place —
    /// a partial final cell is tie-broken deterministically by PM
    /// position in its window.  The resulting victim set is exactly the
    /// first `rho` PMs in the total order
    /// `(utility, query, open_seq, state, window position)`, with a
    /// NaN-safe twist: a poisoned (NaN) utility sorts above every
    /// number, so such PMs are treated as high-utility and survive.
    // audit: no-alloc
    pub fn shed_lowest(&mut self, rho: usize) -> ShedOutcome {
        let n = self.n_pms;
        let mut out = ShedOutcome {
            scanned: n,
            dropped: 0,
            per_shard: PerShard::single(0, 0),
        };
        if n == 0 || rho == 0 {
            return out;
        }
        let mut cells = std::mem::take(&mut self.shed_cells);
        let mut takes = std::mem::take(&mut self.shed_takes);
        self.cell_refs(&mut cells);
        // per-shard scan counter is in *cells*: the decision enumerates
        // and ranks the cell index, never individual PMs
        out.per_shard[0].0 = cells.len();
        cells.sort_unstable_by(cell_cmp);
        takes.clear();
        let mut left = rho.min(n);
        for c in &cells {
            if left == 0 {
                break;
            }
            let take = (c.count as usize).min(left) as u32;
            left -= take as usize;
            takes.push(CellTake {
                query: c.query,
                open_seq: c.open_seq,
                state: c.state,
                take,
            });
        }
        // regroup by window so each one is rewritten exactly once
        takes.sort_unstable_by_key(|t| (t.query, t.open_seq, t.state));
        out.dropped = self.drop_cells(&takes);
        out.per_shard[0].1 = out.dropped;
        self.shed_cells = cells;
        self.shed_takes = takes;
        out
    }

    /// Export the operator's matching state into `snap`, reusing every
    /// buffer the snapshot already owns — a warm snapshot of a warm
    /// operator touches no allocator (the PR 4 discipline).  See
    /// [`ShardSnapshot`] for what is and isn't captured.
    pub fn export_snapshot(&self, snap: &mut ShardSnapshot) {
        snap.wins.resize_with(self.wins.len(), QueryWindows::default);
        for (dst, src) in snap.wins.iter_mut().zip(self.wins.iter()) {
            dst.assign_from(src);
        }
        snap.next_pm_id = self.next_pm_id;
        snap.n_pms = self.n_pms;
        snap.pms_created = self.pms_created;
        snap.completions_total = self.completions_total;
        snap.rate = self.rate;
        snap.obs.assign_from(&self.obs);
    }

    /// Overwrite the operator's matching state from `snap` (the inverse
    /// of [`Operator::export_snapshot`]), recycling the operator's own
    /// buffers.  The obs-enabled flag is preserved — the coordinator
    /// reinstalls it before restoring — and every observation row is
    /// marked dirty so the next delta harvest resyncs the coordinator's
    /// mirror with the restored values verbatim.
    pub fn import_snapshot(&mut self, snap: &ShardSnapshot) {
        assert_eq!(
            snap.wins.len(),
            self.wins.len(),
            "snapshot is for an operator with the same query set"
        );
        for (dst, src) in self.wins.iter_mut().zip(snap.wins.iter()) {
            dst.assign_from(src);
        }
        self.next_pm_id = snap.next_pm_id;
        self.n_pms = snap.n_pms;
        self.pms_created = snap.pms_created;
        self.completions_total = snap.completions_total;
        self.rate = snap.rate;
        let enabled = self.obs.enabled;
        self.obs.assign_from(&snap.obs);
        self.obs.enabled = enabled;
        self.obs.mark_all_dirty();
    }
}

impl OperatorState for Operator {
    fn parallelism(&self) -> usize {
        1
    }

    fn pm_count(&self) -> usize {
        Operator::pm_count(self)
    }

    fn open_windows(&self) -> usize {
        Operator::open_windows(self)
    }

    fn match_probability(&self) -> f64 {
        Operator::match_probability(self)
    }

    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn pm_refs(&self, buf: &mut Vec<PmRef>) {
        Operator::pm_refs(self, buf);
    }

    fn install_table_set(&mut self, set: Arc<TableSet>) {
        let identity: Vec<usize> = (0..self.queries.len()).collect();
        self.apply_table_set(&set, &identity);
    }

    fn table_epoch(&self) -> u64 {
        Operator::table_epoch(self)
    }

    fn harvest_observations(&self, into: &mut ModelHarvest) {
        // overwrite-in-place: the harvest runs every drift checkpoint,
        // so the buffers recycle instead of re-cloning the whole hub
        into.hub.assign_from(&self.obs);
        self.expected_ws_into(&mut into.ws);
    }

    fn set_obs_enabled(&mut self, enabled: bool) {
        self.obs.enabled = enabled;
    }

    fn process_batch_into(
        &mut self,
        events: &[Event],
        shed_mask: Option<&DropMask>,
        out: &mut BatchResult,
    ) {
        if let Some(m) = shed_mask {
            assert_eq!(events.len(), m.len(), "one mask bit per event");
        }
        out.reset();
        // one reused per-event outcome for the whole batch: the hot
        // loop allocates only when completions outgrow their buffers
        let mut o = std::mem::take(&mut self.batch_scratch);
        for (i, e) in events.iter().enumerate() {
            let shed = shed_mask.is_some_and(|m| m.get(i));
            o.reset();
            if shed {
                self.process_bookkeeping_into(e, &mut o);
            } else {
                self.process_event_into(e, &mut o);
            }
            out.cost_ns_max += o.cost_ns;
            out.cost_ns_total += o.cost_ns;
            out.checks += o.checks;
            out.opened += o.opened;
            out.closed += o.closed;
            out.completions.extend_from_slice(&o.completions);
        }
        self.batch_scratch = o;
    }

    fn shed_lowest(&mut self, rho: usize) -> ShedOutcome {
        Operator::shed_lowest(self, rho)
    }

    fn drop_random(&mut self, rho: usize, rng: &mut Rng) -> usize {
        Operator::drop_random(self, rho, rng)
    }

    fn reset_state(&mut self) {
        Operator::reset_state(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{BusGen, StockGen};
    use crate::events::EventStream;
    use crate::query::builtin::{q1, q4};

    fn stock_op(ws: u64) -> Operator {
        Operator::new(q1(ws).queries)
    }

    /// Does every window's cell index agree with a direct recount?
    fn cell_index_consistent(op: &Operator) -> bool {
        op.wins
            .iter()
            .flat_map(|qw| qw.windows.iter())
            .all(|w| w.counts.matches(&w.pms))
    }

    #[test]
    fn windows_open_on_leaders_and_expire() {
        let mut op = stock_op(100);
        let mut g = StockGen::with_seed(1);
        let mut opened = 0;
        for _ in 0..5_000 {
            let e = g.next_event().unwrap();
            let out = op.process_event(&e);
            opened += out.opened;
        }
        assert!(opened > 0, "leader quotes open windows");
        // all windows currently open must be within ws of the tip
        for qw in &op.wins {
            for w in &qw.windows {
                assert!(op.rate.last_seq < w.open_seq + 100);
            }
        }
        // pm count cache consistent
        let direct: usize = op.wins.iter().map(|q| q.pm_count()).sum();
        assert_eq!(direct, op.pm_count());
    }

    #[test]
    fn q4_detects_same_stop_delays() {
        // hand-crafted bus stream: 3 distinct buses delayed at stop 5
        let mut op = Operator::new(q4(3, 1000, 500).queries);
        let mk = |seq, busid: f64, stop: f64, delayed: f64| {
            Event::new(seq, seq, 0, &[busid, stop, delayed, delayed * 5.0])
        };
        let mut completions = Vec::new();
        // seq 0 opens a window (EveryK(500))
        completions.extend(op.process_event(&mk(0, 1.0, 5.0, 1.0)).completions);
        completions.extend(op.process_event(&mk(1, 2.0, 9.0, 1.0)).completions); // other stop
        completions.extend(op.process_event(&mk(2, 2.0, 5.0, 1.0)).completions);
        completions.extend(op.process_event(&mk(3, 2.0, 5.0, 1.0)).completions); // dup bus
        completions.extend(op.process_event(&mk(4, 3.0, 5.0, 0.0)).completions); // on time
        completions.extend(op.process_event(&mk(5, 3.0, 5.0, 1.0)).completions);
        assert_eq!(completions.len(), 1, "exactly one stop-5 complex event");
        assert_eq!(completions[0].query, 0);
        assert_eq!(completions[0].window_open_seq, 0);
        // the stop-9 PM is still live (multi-seed opened one for stop 9)
        assert!(op.pm_count() >= 1);
    }

    #[test]
    fn q4_multi_seed_does_not_duplicate_stop_groups() {
        let mut op = Operator::new(q4(3, 1000, 500).queries);
        let mk = |seq, busid: f64, stop: f64| {
            Event::new(seq, seq, 0, &[busid, stop, 1.0, 5.0])
        };
        // five distinct buses delayed at stop 7: one completion at n=3,
        // and the remaining buses must NOT form a second group counting
        // bus 4,5 plus re-counting (they start a fresh group legally)
        let mut completions = 0;
        for (i, b) in [1.0, 2.0, 3.0, 4.0, 5.0].iter().enumerate() {
            completions += op.process_event(&mk(i as u64, *b, 7.0)).completions.len();
        }
        assert_eq!(completions, 1, "claimed-key dedup prevents double groups");
    }

    #[test]
    fn observations_flow_and_costs_accrue() {
        let mut op = Operator::new(q4(4, 2000, 500).queries);
        let mut g = BusGen::with_seed(2);
        let mut cost = 0.0;
        for _ in 0..10_000 {
            let e = g.next_event().unwrap();
            cost += op.process_event(&e).cost_ns;
        }
        assert!(op.obs.total() > 0, "observations captured");
        assert!(cost > 0.0);
        let t = op.obs.queries[0].transition_matrix();
        assert!(t.is_row_stochastic(1e-9));
    }

    #[test]
    fn drop_random_reduces_pm_count() {
        let mut op = Operator::new(q4(6, 5000, 250).queries);
        let mut g = BusGen::with_seed(3);
        for _ in 0..20_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let before = op.pm_count();
        assert!(before > 10, "need some PMs, got {before}");
        let mut rng = Rng::seeded(1);
        let dropped = op.drop_random(before / 2, &mut rng);
        assert_eq!(dropped, before / 2);
        assert_eq!(op.pm_count(), before - dropped);
        assert!(cell_index_consistent(&op), "cell index drifted");
    }

    #[test]
    fn drop_pms_by_id_is_exact() {
        let mut op = Operator::new(q1(500).queries);
        let mut g = StockGen::with_seed(4);
        for _ in 0..3_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        assert_eq!(refs.len(), op.pm_count());
        let mut victim: Vec<u64> = refs.iter().take(5).map(|r| r.pm_id).collect();
        victim.sort_unstable();
        victim.dedup();
        let dropped = op.drop_pms(&victim);
        assert_eq!(dropped, victim.len().min(refs.len()));
    }

    #[test]
    fn bookkeeping_keeps_rate_estimate_in_step_with_processing() {
        // regression: dropped (bookkept) events must update the
        // events_per_ms EWMA exactly like processed events, or time
        // window R_w estimates go stale under E-BL shedding
        let mut processed = Operator::new(q1(500).queries);
        let mut bookkept = Operator::new(q1(500).queries);
        let mut g = StockGen::with_seed(11);
        for _ in 0..5_000 {
            let e = g.next_event().unwrap();
            processed.process_event(&e);
            bookkept.process_bookkeeping(&e);
        }
        assert!(
            (processed.events_per_ms() - bookkept.events_per_ms()).abs() < 1e-12,
            "rate estimates diverged: {} vs {}",
            processed.events_per_ms(),
            bookkept.events_per_ms()
        );
        // and both moved off the initial estimate
        assert!((processed.events_per_ms() - 1.0).abs() > 1e-6);
    }

    #[test]
    fn rate_digest_mirror_folds_bit_identically() {
        // a detached digest folding the same events is bit-identical to
        // the operator's own (the sharded coordinator's mirror relies
        // on this), and installing it resyncs a stale operator exactly
        let mut op = Operator::new(q1(500).queries);
        let mut stale = Operator::new(q1(500).queries);
        let mut mirror = op.rate_digest();
        assert_eq!(mirror, RateDigest::default());
        let mut g = StockGen::with_seed(11);
        for _ in 0..5_000 {
            let e = g.next_event().unwrap();
            op.process_event(&e);
            mirror.fold(&e);
        }
        assert_eq!(op.rate_digest(), mirror, "mirror diverged");
        assert_ne!(stale.rate_digest(), mirror);
        stale.set_rate_digest(mirror);
        assert_eq!(stale.rate_digest(), op.rate_digest());
        assert_eq!(stale.expected_ws(), op.expected_ws());
    }

    #[test]
    fn reverted_multi_seed_checks_are_observed_as_self_loops() {
        // regression: the claimed-key revert path charged the check cost
        // but skipped obs.record, biasing the transition matrix
        let mut op = Operator::new(q4(3, 1000, 500).queries);
        let mk = |seq, busid: f64, stop: f64| {
            Event::new(seq, seq, 0, &[busid, stop, 1.0, 5.0])
        };
        let mut checks = 0;
        // bus 1 claims stop 5; afterwards the fresh seed of the same
        // window keeps re-binding stop 5 and reverting
        for (i, b) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            checks += op.process_event(&mk(i as u64, *b, 5.0)).checks;
        }
        assert_eq!(
            op.obs.total(),
            checks,
            "every charged check must be observed"
        );
        let t = op.obs.queries[0].transition_matrix();
        assert!(t.is_row_stochastic(1e-9));
        // self-loops at the initial state exist (the reverted checks)
        assert!(op.obs.queries[0].counts[0][0] > 0);
    }

    #[test]
    fn pm_refs_carry_window_identity() {
        let mut op = Operator::new(q4(6, 5000, 250).queries);
        let mut g = BusGen::with_seed(3);
        for _ in 0..10_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        assert!(!refs.is_empty());
        for r in &refs {
            // the window the PM lives in must be open, i.e. opened in
            // the last ws events
            assert!(op.rate.last_seq < r.open_seq + 5000);
        }
    }

    #[test]
    fn cell_index_tracks_the_match_loop() {
        // the incrementally-maintained per-state counts must agree with
        // a direct recount after heavy processing on both the generic
        // (q4) and the key-free fast (q1) paths
        let mut bus = Operator::new(q4(4, 3000, 300).queries);
        let mut g = BusGen::with_seed(6);
        for _ in 0..25_000 {
            bus.process_event(&g.next_event().unwrap());
        }
        assert!(bus.pm_count() > 0);
        assert!(cell_index_consistent(&bus), "q4 cell index drifted");

        let mut stock = stock_op(1_000);
        let mut s = StockGen::with_seed(6);
        for _ in 0..25_000 {
            stock.process_event(&s.next_event().unwrap());
        }
        assert!(stock.pm_count() > 0);
        assert!(cell_index_consistent(&stock), "q1 cell index drifted");
    }

    #[test]
    fn cell_refs_expand_to_the_pm_population() {
        let mut op = tabled_operator();
        let mut cells = Vec::new();
        op.cell_refs(&mut cells);
        let total: usize = cells.iter().map(|c| c.count as usize).sum();
        assert_eq!(total, op.pm_count(), "cells must cover every live PM");
        // expanding each cell's utility `count` times reproduces the
        // per-PM utility multiset exactly (bit-for-bit)
        let mut from_cells: Vec<u64> = cells
            .iter()
            .flat_map(|c| (0..c.count).map(|_| c.utility.to_bits()))
            .collect();
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        let mut from_pms: Vec<u64> = refs
            .iter()
            .map(|r| utility(&op, r).to_bits())
            .collect();
        from_cells.sort_unstable();
        from_pms.sort_unstable();
        assert_eq!(from_cells, from_pms);
    }

    fn tabled_operator() -> Operator {
        use crate::model::{ModelBuilder, ModelConfig};
        let mut op = Operator::new(q4(6, 4000, 200).queries);
        let mut g = BusGen::with_seed(7);
        for _ in 0..40_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 100,
                max_bins: 64,
                use_tau: true,
            },
            Box::new(crate::runtime::FallbackEngine),
        );
        let tables = mb.build(&op).unwrap();
        op.install_tables(&tables);
        op
    }

    fn utility(op: &Operator, r: &PmRef) -> f64 {
        // mirror of shed_lowest's ranking, for assertions
        op.tables[r.query].lookup(r.state, r.remaining)
    }

    #[test]
    fn shed_lowest_drops_exactly_rho() {
        let mut op = tabled_operator();
        let before = op.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let mut cells = Vec::new();
        op.cell_refs(&mut cells);
        let n_cells = cells.len();
        assert!(n_cells < before, "cells must compress the population");
        let out = op.shed_lowest(10);
        assert_eq!(out.scanned, before);
        assert_eq!(out.dropped, 10);
        // the per-shard scan counter is in cells (the O(cells) decision)
        assert_eq!(out.per_shard.as_slice(), &[(n_cells, 10)]);
        assert_eq!(op.pm_count(), before - 10);
        assert!(cell_index_consistent(&op), "cell index drifted");
    }

    #[test]
    fn shed_lowest_drops_the_lowest_utilities() {
        let mut op = tabled_operator();
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        let mut utils: Vec<f64> = refs.iter().map(|r| utility(&op, r)).collect();
        utils.sort_by(|a, b| a.total_cmp(b));
        let rho = 8;
        let threshold = utils[rho - 1];
        op.shed_lowest(rho);
        // every survivor has utility >= the rho-th smallest
        let mut after = Vec::new();
        op.pm_refs(&mut after);
        for r in &after {
            assert!(
                utility(&op, r) >= threshold - 1e-12,
                "survivor below threshold"
            );
        }
    }

    #[test]
    fn shed_lowest_survives_nan_utilities() {
        // regression: partial_cmp().unwrap() panicked when a utility
        // table was poisoned with NaN; total_cmp must select anyway
        let mut op = tabled_operator();
        let mut tables = op.tables.clone();
        for table in &mut tables {
            for row in &mut table.rows {
                for (i, v) in row.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *v = f64::NAN;
                    }
                }
            }
        }
        op.install_tables(&tables);
        let before = op.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let rho = 10;
        let out = op.shed_lowest(rho);
        assert_eq!(out.scanned, before);
        assert_eq!(out.dropped, rho, "exactly rho victims despite NaNs");
        assert_eq!(op.pm_count(), before - rho);
    }

    #[test]
    fn shed_lowest_overdraw_drops_all() {
        let mut op = tabled_operator();
        let before = op.pm_count();
        let out = op.shed_lowest(before + 1000);
        assert_eq!(out.dropped, before);
        assert_eq!(op.pm_count(), 0);
    }

    #[test]
    fn shed_lowest_without_tables_still_drops() {
        // no tables installed: every PM scores utility 0 and exactly
        // rho of them are removed (deterministic tie-break by cell
        // identity, then PM position)
        let mut op = Operator::new(q4(6, 5000, 250).queries);
        let mut g = BusGen::with_seed(3);
        for _ in 0..20_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let before = op.pm_count();
        assert!(before > 10, "need PMs, got {before}");
        let out = op.shed_lowest(before / 2);
        assert_eq!(out.dropped, before / 2);
        assert_eq!(op.pm_count(), before - out.dropped);
        assert!(cell_index_consistent(&op), "cell index drifted");
    }

    #[test]
    fn type_skim_matches_full_loop_on_mixed_types() {
        // the mixed workload interleaves disjoint etype families, so
        // every query skims ~2/3 of the stream: results, checks, PM
        // evolution and observations must be identical to the unrouted
        // per-PM loop, and virtual cost equal up to FP associativity
        use crate::datasets::{mixed_queries, mixed_trace};
        let trace = mixed_trace(12_000, 9);
        let run = |routing: bool| {
            let mut op = Operator::new(mixed_queries(2_000));
            op.set_type_routing(routing);
            let mut ces = Vec::new();
            let (mut checks, mut cost) = (0u64, 0.0f64);
            for e in &trace {
                let o = op.process_event(e);
                ces.extend(o.completions);
                checks += o.checks;
                cost += o.cost_ns;
            }
            let obs_total = op.obs.total();
            (ces, checks, cost, op.pm_count(), obs_total, op)
        };
        let (ces_on, checks_on, cost_on, pms_on, obs_on, op_on) = run(true);
        let (ces_off, checks_off, cost_off, pms_off, obs_off, op_off) = run(false);
        assert_eq!(ces_on, ces_off, "completions diverged");
        assert_eq!(checks_on, checks_off, "modeled check counts diverged");
        assert_eq!(pms_on, pms_off, "PM populations diverged");
        assert_eq!(obs_on, obs_off, "observation totals diverged");
        assert!(checks_on > 0 && obs_on > 0, "scenario must exercise PMs");
        let rel = (cost_on - cost_off).abs() / cost_off.max(1.0);
        assert!(rel < 1e-9, "virtual cost drifted beyond FP noise: {rel}");
        // transition observations agree exactly (counts are integers)
        for (a, b) in op_on.obs.queries.iter().zip(&op_off.obs.queries) {
            assert_eq!(a.counts, b.counts, "transition counts diverged");
        }
        for (a, b) in op_on.wins.iter().zip(&op_off.wins) {
            assert_eq!(a.windows.len(), b.windows.len());
        }
    }

    #[test]
    fn process_event_into_accumulates_like_process_event() {
        let queries = q1(800).queries;
        let mut g = StockGen::with_seed(8);
        let events = g.take_events(3_000);
        let mut a = Operator::new(queries.clone());
        let mut b = Operator::new(queries);
        let mut acc = ProcessOutcome::default();
        let (mut cost, mut checks) = (0.0f64, 0u64);
        let mut ces = Vec::new();
        for e in &events {
            let o = a.process_event(e);
            cost += o.cost_ns;
            checks += o.checks;
            ces.extend(o.completions);
            // reused-outcome form: reset + accumulate
            acc.reset();
            b.process_event_into(e, &mut acc);
        }
        // drive b once more over nothing: acc holds only the last event
        let mut b2 = Operator::new(q1(800).queries);
        let mut acc2 = ProcessOutcome::default();
        let mut ces2 = Vec::new();
        let (mut cost2, mut checks2) = (0.0f64, 0u64);
        let mut g2 = StockGen::with_seed(8);
        for e in &g2.take_events(3_000) {
            acc2.reset();
            b2.process_event_into(e, &mut acc2);
            cost2 += acc2.cost_ns;
            checks2 += acc2.checks;
            ces2.extend_from_slice(&acc2.completions);
        }
        assert_eq!(ces, ces2);
        assert_eq!(checks, checks2);
        assert_eq!(cost.to_bits(), cost2.to_bits(), "identical FP accumulation");
        assert_eq!(a.pm_count(), b2.pm_count());
        assert_eq!(b.pm_count(), a.pm_count());
    }

    #[test]
    fn snapshot_round_trip_is_bit_exact() {
        // export → import must reproduce PM/window/cell-count state
        // bit-for-bit: the restored operator and the original evolve
        // identically (completions, PM ids, FP costs) from there on
        let mut op = tabled_operator();
        assert!(op.pm_count() > 20, "need live PMs to snapshot");
        let mut snap = ShardSnapshot::default();
        op.export_snapshot(&mut snap);

        // the import target is deliberately *dirty* — a different
        // stream history — so the buffer-recycling paths are exercised
        let mut restored = Operator::new(q4(6, 4000, 200).queries);
        let mut other = BusGen::with_seed(99);
        for _ in 0..10_000 {
            restored.process_event(&other.next_event().unwrap());
        }
        restored.import_snapshot(&snap);

        assert_eq!(restored.pm_count(), op.pm_count());
        assert_eq!(restored.open_windows(), op.open_windows());
        assert_eq!(restored.pms_created, op.pms_created);
        assert_eq!(restored.completions_total, op.completions_total);
        assert_eq!(restored.rate_digest(), op.rate_digest());
        assert_eq!(restored.obs.total(), op.obs.total());
        for (a, b) in restored.wins.iter().zip(op.wins.iter()) {
            assert_eq!(a.windows.len(), b.windows.len());
            for (wa, wb) in a.windows.iter().zip(b.windows.iter()) {
                assert_eq!(wa.open_seq, wb.open_seq);
                assert_eq!(wa.open_ts, wb.open_ts);
                assert_eq!(wa.pms, wb.pms, "PM state diverged");
                assert_eq!(
                    wa.claimed.to_sorted_vec(),
                    wb.claimed.to_sorted_vec(),
                    "claim state diverged"
                );
                assert!(wa.counts.matches(&wa.pms), "cell index diverged");
            }
        }

        // continue the original stream on both: identical evolution
        // (tables only affect shedding, which this path never takes)
        let mut g = BusGen::with_seed(7);
        let _ = g.take_events(40_000); // the prefix tabled_operator consumed
        let (mut cost_a, mut cost_b) = (0.0f64, 0.0f64);
        let (mut ces_a, mut ces_b) = (Vec::new(), Vec::new());
        for e in &g.take_events(5_000) {
            let oa = op.process_event(e);
            let ob = restored.process_event(e);
            cost_a += oa.cost_ns;
            cost_b += ob.cost_ns;
            ces_a.extend(oa.completions);
            ces_b.extend(ob.completions);
        }
        assert_eq!(ces_a, ces_b, "post-restore completions diverged");
        assert_eq!(cost_a.to_bits(), cost_b.to_bits(), "FP cost diverged");
        assert_eq!(op.pm_count(), restored.pm_count());
        assert_eq!(op.obs.total(), restored.obs.total());
    }

    #[test]
    fn completions_without_shedding_are_deterministic() {
        let run = || {
            let mut op = Operator::new(q4(3, 3000, 300).queries);
            let mut g = BusGen::with_seed(5);
            let mut all = Vec::new();
            for _ in 0..30_000 {
                all.extend(op.process_event(&g.next_event().unwrap()).completions);
            }
            all
        };
        assert_eq!(run(), run());
    }
}
