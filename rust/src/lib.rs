//! # pSPICE — Partial Match Shedding for Complex Event Processing
//!
//! A from-scratch reproduction of *"pSPICE: Partial Match Shedding for
//! Complex Event Processing"* (Slo, Bhowmik, Flaig, Rothermel, 2020) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the CEP coordinator: event streams, windows,
//!   NFA pattern matching, the multi-query operator (single-threaded or
//!   sharded across worker threads — [`runtime::sharded`]), the pSPICE load
//!   shedder and overload detector (paper Algorithms 1 & 2, shard-aware),
//!   both baselines (PM-BL, E-BL), dataset generators, a discrete-event load
//!   simulation, the [`pipeline`] builder façade tying them together, and the
//!   full experiment harness for the paper's Figures 5–9.
//! * **Layer 2 (JAX, build-time)** — the model-builder compute graph
//!   (Markov-chain completion probability + Markov-reward value iteration),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (Pallas, build-time)** — the fused batched recurrence step
//!   kernel inside that graph.
//!
//! The rust binary is self-contained once `make artifacts` has produced the
//! HLO artifacts; python never runs on the request path.  A pure-rust
//! fallback model engine ([`runtime::fallback`]) allows artifact-less
//! operation and differential testing of the AOT path.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`events`] | primitive events, schemas, stream abstraction, pooled batch/mask plane ([`events::EventBatch`], [`events::DropMask`]) |
//! | [`datasets`] | synthetic NYSE / RTLS-soccer / Dublin-bus generators + CSV + the mixed Q1–Q4 workload |
//! | [`query`] | pattern AST, Tesla-like DSL parser, built-in Q1–Q4 |
//! | [`nfa`] | pattern → state machine compilation, partial matches |
//! | [`windows`] | count/time/slide window policies and manager |
//! | [`operator`] | the CEP operator: match loop, observations, cost model, the [`operator::OperatorState`] abstraction |
//! | [`shedding`] | batch-first [`shedding::Shedder`] strategies (pSPICE / PM-BL / E-BL) + overload detector + the [`shedding::ShedderKind::build`] factory |
//! | [`model`] | observation stats → utility tables, behind the versioned model plane ([`model::UtilityModel`] trainers, epoch-numbered [`model::TableSet`] snapshots, the [`model::ModelController`] retrain loop) |
//! | [`runtime`] | model engines (PJRT/AOT behind the `xla` feature, rust fallback) + the sharded operator runtime |
//! | [`pipeline`] | the engine façade: [`pipeline::PipelineBuilder`] → [`pipeline::Pipeline`] (`prime` / `feed` / `run_to_end` / `run_realtime`) over 1..N shards |
//! | [`sim`] | the [`sim::Clock`] abstraction (virtual [`sim::SimClock`], monotonic [`sim::WallClock`]) + deterministic arrival schedules |
//! | [`ingest`] | real-time ingestion: [`ingest::Source`] trait (trace/tail/socket/synthetic overload generators) + the bounded backpressured [`ingest::IngestQueue`] |
//! | [`metrics`] | latency, wall-clock throughput, QoR (FN/FP) accounting |
//! | [`harness`] | experiment runner (built on [`pipeline`]) + Figure 5–9 drivers |
//! | [`scorecard`] | the gated evaluation protocol: run manifests, QoR/latency metrics with confidence intervals, the committed `SCORECARD.jsonl` trend ledger and its regression gates |
//! | [`linalg`] | dense matrices, regression, Markov oracle |
//! | [`config`] | TOML-subset experiment configuration |
//! | [`cli`] | argument parsing for the `pspice` binary |
//! | [`util`] | RNG, interner, running stats, logging |
//! | [`testing`] | minimal property-testing support (offline proptest stand-in) |

pub mod cli;
pub mod config;
pub mod datasets;
pub mod events;
pub mod harness;
pub mod ingest;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod nfa;
pub mod operator;
pub mod pipeline;
pub mod query;
pub mod runtime;
pub mod scorecard;
pub mod shedding;
pub mod sim;
pub mod testing;
pub mod util;
pub mod windows;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
