//! Least-squares regression for the overload detector's latency models
//! (paper §III-E):
//!
//! * `l_p = f(n_pm)` — event processing latency vs. number of live PMs,
//! * `l_s = g(n_pm)` — shedding latency vs. number of live PMs.
//!
//! The paper "appl\[ies\] several regression models … and use\[s\] a
//! regression model that results in lower error".  We fit three candidate
//! bases — linear, quadratic, and `n·log₂(n)` (the sort inside the
//! shedder) — and keep the one with the lowest residual sum of squares.
//! All models are constrained to be monotone-invertible on the fitted
//! range so `f⁻¹` (Alg. 1 line 7) is well-defined.

/// Candidate regression basis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegressionKind {
    /// `a + b·n`
    Linear,
    /// `a + b·n + c·n²`
    Quadratic,
    /// `a + b·n·log2(n+1)`
    NLogN,
}

/// A fitted latency model `latency = h(n_pm)` with a numeric inverse.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Chosen basis.
    pub kind: RegressionKind,
    /// Coefficients, meaning depends on `kind`.
    pub coef: Vec<f64>,
    /// Residual sum of squares on the training data.
    pub rss: f64,
    /// Largest `n` seen during fitting (inverse search upper bound).
    pub n_max: f64,
}

impl LatencyModel {
    /// Predicted latency for `n` partial matches.
    pub fn predict(&self, n: f64) -> f64 {
        let n = n.max(0.0);
        match self.kind {
            RegressionKind::Linear => self.coef[0] + self.coef[1] * n,
            RegressionKind::Quadratic => {
                self.coef[0] + self.coef[1] * n + self.coef[2] * n * n
            }
            RegressionKind::NLogN => self.coef[0] + self.coef[1] * n * (n + 1.0).log2(),
        }
        .max(0.0)
    }

    /// Inverse: the largest PM count whose predicted latency is ≤
    /// `latency` (Alg. 1 line 7, `n'_pm = f⁻¹(l'_p)`).  Monotone bisection
    /// over `[0, 4·n_max]`.
    pub fn inverse(&self, latency: f64) -> f64 {
        if latency <= self.predict(0.0) {
            return 0.0;
        }
        let (mut lo, mut hi) = (0.0f64, (self.n_max * 4.0).max(16.0));
        if self.predict(hi) <= latency {
            return hi;
        }
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if self.predict(mid) <= latency {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Solve the normal equations `(XᵀX) β = Xᵀy` for a small design matrix
/// via Gaussian elimination with partial pivoting.  Returns `None` if the
/// system is singular (degenerate data).
fn solve_normal(xtx: &mut [Vec<f64>], xty: &mut [f64]) -> Option<Vec<f64>> {
    let k = xty.len();
    for col in 0..k {
        // pivot
        let piv = (col..k).max_by(|&a, &b| xtx[a][col].abs().total_cmp(&xtx[b][col].abs()))?;
        if xtx[piv][col].abs() < 1e-12 {
            return None;
        }
        xtx.swap(col, piv);
        xty.swap(col, piv);
        for row in 0..k {
            if row == col {
                continue;
            }
            let factor = xtx[row][col] / xtx[col][col];
            for c in col..k {
                xtx[row][c] -= factor * xtx[col][c];
            }
            xty[row] -= factor * xty[col];
        }
    }
    Some((0..k).map(|i| xty[i] / xtx[i][i]).collect())
}

fn fit_basis(
    kind: RegressionKind,
    xs: &[f64],
    ys: &[f64],
) -> Option<LatencyModel> {
    let feats: Vec<Vec<f64>> = xs
        .iter()
        .map(|&n| match kind {
            RegressionKind::Linear => vec![1.0, n],
            RegressionKind::Quadratic => vec![1.0, n, n * n],
            RegressionKind::NLogN => vec![1.0, n * (n + 1.0).log2()],
        })
        .collect();
    let k = feats[0].len();
    let mut xtx = vec![vec![0.0; k]; k];
    let mut xty = vec![0.0; k];
    for (f, &y) in feats.iter().zip(ys) {
        for i in 0..k {
            for j in 0..k {
                xtx[i][j] += f[i] * f[j];
            }
            xty[i] += f[i] * y;
        }
    }
    let coef = solve_normal(&mut xtx, &mut xty)?;
    // Reject non-monotone fits (negative slope / dominant negative curvature):
    // the detector needs an invertible f.
    let slope_ok = match kind {
        RegressionKind::Linear | RegressionKind::NLogN => coef[1] > 0.0,
        RegressionKind::Quadratic => {
            coef[1] >= 0.0 && coef[2] >= 0.0 && (coef[1] > 0.0 || coef[2] > 0.0)
        }
    };
    if !slope_ok {
        return None;
    }
    let n_max = xs.iter().copied().fold(0.0, f64::max);
    let mut model = LatencyModel {
        kind,
        coef,
        rss: 0.0,
        n_max,
    };
    model.rss = xs
        .iter()
        .zip(ys)
        .map(|(&n, &y)| {
            let e = model.predict(n) - y;
            e * e
        })
        .sum();
    Some(model)
}

/// Fit all candidate bases to `(n_pm, latency)` samples and return the
/// lowest-RSS monotone model.  Needs ≥ 4 samples.
pub fn fit_latency_model(xs: &[f64], ys: &[f64]) -> Option<LatencyModel> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 4 {
        return None;
    }
    [
        RegressionKind::Linear,
        RegressionKind::Quadratic,
        RegressionKind::NLogN,
    ]
    .into_iter()
    .filter_map(|k| fit_basis(k, xs, ys))
    .min_by(|a, b| a.rss.total_cmp(&b.rss))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_linear() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|n| 3.0 + 0.5 * n).collect();
        let m = fit_latency_model(&xs, &ys).unwrap();
        assert!((m.predict(200.0) - 103.0).abs() < 1e-6, "{m:?}");
    }

    #[test]
    fn recovers_quadratic() {
        let xs: Vec<f64> = (1..60).map(|i| i as f64 * 5.0).collect();
        let ys: Vec<f64> = xs.iter().map(|n| 1.0 + 0.1 * n + 0.01 * n * n).collect();
        let m = fit_latency_model(&xs, &ys).unwrap();
        assert_eq!(m.kind, RegressionKind::Quadratic);
        assert!((m.predict(100.0) - (1.0 + 10.0 + 100.0)).abs() < 1e-4);
    }

    #[test]
    fn inverse_round_trips() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64 * 20.0).collect();
        let ys: Vec<f64> = xs.iter().map(|n| 2.0 + 0.25 * n).collect();
        let m = fit_latency_model(&xs, &ys).unwrap();
        for &n in &[0.0, 17.0, 500.0, 1999.0] {
            let lat = m.predict(n);
            let back = m.inverse(lat);
            assert!((back - n).abs() < 0.1, "n={n} back={back}");
        }
    }

    #[test]
    fn inverse_clamps_below() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|n| 5.0 + n).collect();
        let m = fit_latency_model(&xs, &ys).unwrap();
        assert_eq!(m.inverse(1.0), 0.0); // below intercept → drop to zero PMs
    }

    #[test]
    fn too_few_samples_is_none() {
        assert!(fit_latency_model(&[1.0, 2.0], &[1.0, 2.0]).is_none());
    }

    #[test]
    fn noisy_nlogn_picks_nlogn() {
        let xs: Vec<f64> = (1..200).map(|i| i as f64 * 50.0).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&n| 10.0 + 0.02 * n * (n + 1.0).log2())
            .collect();
        let m = fit_latency_model(&xs, &ys).unwrap();
        assert_eq!(m.kind, RegressionKind::NLogN);
    }
}
