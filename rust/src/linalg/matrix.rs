//! Small dense row-major matrix over `f64`.
//!
//! Sized for pattern state machines (m ≤ a few dozen states); clarity over
//! BLAS-level tuning, except `matmul` which is written loop-ordered (i,k,j)
//! so the inner loop is a contiguous axpy.

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.to_vec(),
        }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let src = &other.data[k * other.cols..(k + 1) * other.cols];
                let dst = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += a * s;
                }
            }
        }
        out
    }

    /// Matrix–vector product `self * v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            out[i] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        out
    }

    /// `self^k` by repeated squaring (square matrices only).
    pub fn pow(&self, mut k: u64) -> Mat {
        assert_eq!(self.rows, self.cols, "pow needs square matrix");
        let mut base = self.clone();
        let mut acc = Mat::eye(self.rows);
        while k > 0 {
            if k & 1 == 1 {
                acc = acc.matmul(&base);
            }
            base = base.matmul(&base);
            k >>= 1;
        }
        acc
    }

    /// Mean squared difference between two same-shape matrices — the
    /// paper's §III-D drift measure between old and new transition
    /// matrices.
    pub fn mse(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / n as f64
    }

    /// Max absolute entry difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// True if every row sums to 1 within `eps` (row-stochastic check).
    pub fn is_row_stochastic(&self, eps: f64) -> bool {
        (0..self.rows).all(|i| {
            let s: f64 = self.row(i).iter().sum();
            (s - 1.0).abs() <= eps && self.row(i).iter().all(|&x| x >= -eps)
        })
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_rows(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![7.0, -1.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(Mat::eye(2).matmul(&a), a);
        assert_eq!(a.matmul(&Mat::eye(2)), a);
    }

    #[test]
    fn pow_matches_repeated_matmul() {
        let a = Mat::from_rows(2, 2, &[0.5, 0.5, 0.25, 0.75]);
        let mut direct = Mat::eye(2);
        for _ in 0..9 {
            direct = direct.matmul(&a);
        }
        let fast = a.pow(9);
        assert!(fast.max_abs_diff(&direct) < 1e-12);
        assert!(a.pow(0).max_abs_diff(&Mat::eye(2)) < 1e-15);
    }

    #[test]
    fn stochastic_check() {
        let t = Mat::from_rows(2, 2, &[0.3, 0.7, 0.0, 1.0]);
        assert!(t.is_row_stochastic(1e-12));
        let bad = Mat::from_rows(2, 2, &[0.3, 0.6, 0.0, 1.0]);
        assert!(!bad.is_row_stochastic(1e-12));
    }

    #[test]
    fn mse_and_max_diff() {
        let a = Mat::from_rows(1, 2, &[1.0, 2.0]);
        let b = Mat::from_rows(1, 2, &[1.5, 2.0]);
        assert!((a.mse(&b) - 0.125).abs() < 1e-15);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-15);
    }
}
