//! Pure-rust Markov-chain / Markov-reward oracle.
//!
//! Mirrors the L2 JAX graph (`python/compile/model.py`) exactly:
//!
//! * completion probability `c_j = T · c_{j-1}`, `c_0 = e_m` (paper Eq. 3 —
//!   `c_j(i) == T^j(i, m)` for an absorbing final state),
//! * remaining processing time `τ_j = r + T · τ_{j-1}`, `τ_0 = 0`
//!   (value-iteration / Bellman backup for the Markov reward process).
//!
//! Used (a) as the fallback model engine when no AOT artifact is present,
//! (b) to differentially validate the PJRT path, and (c) by the bin
//! composition below which turns the learned one-event chain into a
//! per-bin chain (exact by Chapman–Kolmogorov).

use super::matrix::Mat;

/// Result of running the recurrence for `nbins` bins: row `j` (0-based)
/// holds the values when `j+1` bins remain in the window.
#[derive(Debug, Clone)]
pub struct MarkovTables {
    /// Completion probabilities, `nbins` rows × `m` states.
    pub completion: Vec<Vec<f64>>,
    /// Expected remaining processing time, `nbins` rows × `m` states.
    pub remaining_time: Vec<Vec<f64>>,
}

/// Advance the fused recurrence once (rust twin of the Pallas kernel).
pub fn step(t: &Mat, r: &[f64], c: &[f64], tau: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let c2 = t.matvec(c);
    let mut tau2 = t.matvec(tau);
    for (x, &ri) in tau2.iter_mut().zip(r) {
        *x += ri;
    }
    (c2, tau2)
}

/// Run the full recurrence for `nbins` bins (rust twin of
/// `model.build_tables` for a single pattern).
pub fn build_tables(t: &Mat, r: &[f64], nbins: usize) -> MarkovTables {
    let m = t.rows();
    assert_eq!(t.cols(), m);
    assert_eq!(r.len(), m);
    let mut c = vec![0.0; m];
    c[m - 1] = 1.0;
    let mut tau = vec![0.0; m];
    let mut completion = Vec::with_capacity(nbins);
    let mut remaining_time = Vec::with_capacity(nbins);
    for _ in 0..nbins {
        let (c2, tau2) = step(t, r, &c, &tau);
        c = c2;
        tau = tau2;
        completion.push(c.clone());
        remaining_time.push(tau.clone());
    }
    MarkovTables {
        completion,
        remaining_time,
    }
}

/// Compose the one-event chain `(T, r)` into the `bs`-event chain
/// `(T_bs, r_bs)` by binary decomposition of `bs`.
///
/// Chain composition is associative with
/// `(T_a, r_a) ∘ (T_b, r_b) = (T_a·T_b, r_a + T_a·r_b)`; the completion
/// and reward recurrences over the composed chain equal `bs` steps of the
/// original chain *exactly* (Chapman–Kolmogorov), which is what makes the
/// paper's binning + interpolation sound.
pub fn compose_bin(t: &Mat, r: &[f64], bs: u64) -> (Mat, Vec<f64>) {
    assert!(bs >= 1, "bin size must be >= 1");
    let m = t.rows();
    // accumulator starts as the identity chain (0 steps)
    let mut acc_t = Mat::eye(m);
    let mut acc_r = vec![0.0; m];
    let mut base_t = t.clone();
    let mut base_r = r.to_vec();
    let mut k = bs;
    while k > 0 {
        if k & 1 == 1 {
            // acc = acc ∘ base
            let new_r: Vec<f64> = acc_t
                .matvec(&base_r)
                .iter()
                .zip(&acc_r)
                .map(|(x, y)| x + y)
                .collect();
            acc_t = acc_t.matmul(&base_t);
            acc_r = new_r;
        }
        k >>= 1;
        if k > 0 {
            // base = base ∘ base
            let new_r: Vec<f64> = base_t
                .matvec(&base_r)
                .iter()
                .zip(&base_r)
                .map(|(x, y)| x + y)
                .collect();
            base_t = base_t.matmul(&base_t);
            base_r = new_r;
        }
    }
    (acc_t, acc_r)
}

/// Make the final state of a learned transition matrix absorbing and
/// renormalize rows; guards against sparse observation counts.
pub fn absorbing_normalize(t: &mut Mat) {
    let m = t.rows();
    for i in 0..m {
        if i == m - 1 {
            for j in 0..m {
                t[(i, j)] = if j == m - 1 { 1.0 } else { 0.0 };
            }
            continue;
        }
        let s: f64 = t.row(i).iter().sum();
        if s <= 0.0 {
            // never observed: stay put with certainty
            for j in 0..m {
                t[(i, j)] = if j == i { 1.0 } else { 0.0 };
            }
        } else {
            for j in 0..m {
                t[(i, j)] /= s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> (Mat, Vec<f64>) {
        // s1 -(0.3)-> s2, stay 0.7; s2 -(0.5)-> s3(final), stay 0.5
        let t = Mat::from_rows(
            3,
            3,
            &[0.7, 0.3, 0.0, 0.0, 0.5, 0.5, 0.0, 0.0, 1.0],
        );
        let r = vec![1.0, 2.0, 0.0];
        (t, r)
    }

    #[test]
    fn completion_equals_matrix_power() {
        let (t, r) = chain3();
        let tables = build_tables(&t, &r, 16);
        for j in 0..16 {
            let p = t.pow(j as u64 + 1);
            for i in 0..3 {
                assert!(
                    (tables.completion[j][i] - p[(i, 2)]).abs() < 1e-12,
                    "j={j} i={i}"
                );
            }
        }
    }

    #[test]
    fn completion_monotone_in_bins() {
        let (t, r) = chain3();
        let tables = build_tables(&t, &r, 64);
        for j in 1..64 {
            for i in 0..3 {
                assert!(tables.completion[j][i] + 1e-12 >= tables.completion[j - 1][i]);
            }
        }
    }

    #[test]
    fn reward_absorbing_state_is_zero() {
        let (t, r) = chain3();
        let tables = build_tables(&t, &r, 32);
        for row in &tables.remaining_time {
            assert!(row[2].abs() < 1e-12);
        }
    }

    #[test]
    fn compose_bin_equals_stepped() {
        let (t, r) = chain3();
        for bs in [1u64, 2, 3, 7, 16, 33] {
            let (tb, rb) = compose_bin(&t, &r, bs);
            // completion via composed chain, 1 step == bs steps of original
            let direct = build_tables(&t, &r, bs as usize);
            let via_bin = build_tables(&tb, &rb, 1);
            for i in 0..3 {
                assert!(
                    (via_bin.completion[0][i] - direct.completion[bs as usize - 1][i])
                        .abs()
                        < 1e-10,
                    "bs={bs}"
                );
                assert!(
                    (via_bin.remaining_time[0][i]
                        - direct.remaining_time[bs as usize - 1][i])
                        .abs()
                        < 1e-10,
                    "bs={bs}"
                );
            }
        }
    }

    #[test]
    fn compose_bin_power_matches_matrix_power() {
        let (t, r) = chain3();
        let (tb, _) = compose_bin(&t, &r, 12);
        assert!(tb.max_abs_diff(&t.pow(12)) < 1e-12);
    }

    #[test]
    fn absorbing_normalize_fixes_rows() {
        let mut t = Mat::from_rows(3, 3, &[2.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.2, 0.2, 0.2]);
        absorbing_normalize(&mut t);
        assert!(t.is_row_stochastic(1e-12));
        assert_eq!(t[(1, 1)], 1.0); // unobserved row -> stay put
        assert_eq!(t[(2, 2)], 1.0); // final row forced absorbing
        assert!((t[(0, 0)] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn deterministic_chain_reward_accumulates() {
        // deterministic advance s1->s2->s3(final), unit cost per event
        let t = Mat::from_rows(3, 3, &[0.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        let r = vec![1.0, 1.0, 0.0];
        let tables = build_tables(&t, &r, 5);
        // from s1 with >=2 events left: pays 1 (s1) + 1 (s2) = 2 then absorbs
        assert!((tables.remaining_time[4][0] - 2.0).abs() < 1e-12);
        assert!((tables.remaining_time[4][1] - 1.0).abs() < 1e-12);
        // completion: needs 2 events from s1
        assert_eq!(tables.completion[0][0], 0.0);
        assert_eq!(tables.completion[1][0], 1.0);
    }
}
