//! Dense linear algebra for the model builder and overload detector:
//!
//! * [`matrix`] — a small row-major `f64` matrix with the operations the
//!   Markov machinery needs (matmul, matvec, power, norms),
//! * [`regression`] — least-squares fits used for the paper's latency
//!   functions `l_p = f(n_pm)` and `l_s = g(n_pm)` (§III-E),
//! * [`markov`] — the pure-rust Markov-chain / Markov-reward oracle that
//!   mirrors the L2 JAX graph (used for tests, differential validation of
//!   the AOT artifacts, and artifact-less operation).

pub mod markov;
pub mod matrix;
pub mod regression;

pub use matrix::Mat;
pub use regression::{fit_latency_model, LatencyModel, RegressionKind};
