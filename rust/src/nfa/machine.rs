//! Compilation of a [`Query`] into a flat state machine and the single
//! hot-path transition function [`CompiledQuery::try_advance`].
//!
//! Every pattern shape flattens to: an ordered *head* of steps followed
//! by an optional *any-group* `(n, spec, distinct_slot)`.  The PM state
//! is the number of completed steps; state `m-1` is final.

use crate::events::Event;
use crate::query::{Pattern, Predicate, Query, StepSpec};

use super::pm::PartialMatch;

/// Outcome of offering one event to one PM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Event did not match the PM's next step (skipped under
    /// skip-till-next/any).
    NoMatch,
    /// PM advanced one state.
    Advanced,
    /// PM advanced into the final state: a complex event.
    Completed,
}

/// An any-group tail.
#[derive(Debug, Clone)]
pub struct AnyGroup {
    /// distinct matches required
    pub n: usize,
    /// the step each match must satisfy
    pub spec: StepSpec,
    /// slot whose values must be pairwise distinct
    pub distinct_slot: usize,
}

/// A query compiled for the operator hot path.
#[derive(Debug, Clone)]
pub struct CompiledQuery {
    /// the source query
    pub query: Query,
    /// ordered head steps
    pub head: Vec<StepSpec>,
    /// optional any-group tail
    pub any: Option<AnyGroup>,
    /// total Markov states (head + any + initial)
    pub m: usize,
    /// pure sequence with no key captures/correlations and ≤ 64 steps:
    /// step matching is PM-independent, enabling the per-event bitmask
    /// fast path ([`CompiledQuery::step_mask`]) — see EXPERIMENTS.md
    /// §Perf for the measured effect.
    pub key_free_seq: bool,
    /// event types this query can react to (steps + `OnMatch` open
    /// spec): an event outside this set cannot advance any PM or open
    /// an `OnMatch` window, so the operator skims it (bookkeeping +
    /// modeled cost only) — see EXPERIMENTS.md §Perf design note #2.
    pub types: crate::events::TypeMask,
}

/// Evaluate one predicate against an event given the PM's keys.
#[inline]
pub fn eval_pred(p: &Predicate, e: &Event, pm: &PartialMatch) -> bool {
    match p {
        Predicate::AttrCmp { slot, op, value } => op.eval(e.attrs[*slot], *value),
        Predicate::AttrIn { slot, values } => values.contains(&e.attrs[*slot]),
        Predicate::KeyCmp { slot, op, key } => {
            // an unbound key constrains nothing — the binding step itself
            // defines the correlation anchor
            !pm.has_key(*key) || op.eval(e.attrs[*slot], pm.keys[*key])
        }
    }
}

/// Does `e` satisfy `spec` for this PM (type + all predicates)?
#[inline]
pub fn matches_spec(spec: &StepSpec, e: &Event, pm: &PartialMatch) -> bool {
    e.etype == spec.etype && spec.preds.iter().all(|p| eval_pred(p, e, pm))
}

impl CompiledQuery {
    /// Compile a query.
    pub fn compile(query: Query) -> Self {
        let (head, any) = match query.pattern.clone() {
            Pattern::Seq(steps) => (steps, None),
            Pattern::Any {
                n,
                spec,
                distinct_slot,
            } => (
                Vec::new(),
                Some(AnyGroup {
                    n,
                    spec,
                    distinct_slot,
                }),
            ),
            Pattern::SeqAny {
                head,
                n,
                spec,
                distinct_slot,
            } => (
                head,
                Some(AnyGroup {
                    n,
                    spec,
                    distinct_slot,
                }),
            ),
        };
        let m = query.state_count();
        let key_free_seq = any.is_none()
            && head.len() <= 64
            && head.iter().all(|s| {
                s.bind_key.is_none()
                    && s.preds
                        .iter()
                        .all(|p| !matches!(p, Predicate::KeyCmp { .. }))
            });
        let types = query.type_mask();
        CompiledQuery {
            query,
            head,
            any,
            m,
            key_free_seq,
            types,
        }
    }

    /// Per-event step-match bitmask for [`Self::key_free_seq`] queries:
    /// bit `i` set ⇔ `e` satisfies step `i`.  A PM at state `s` advances
    /// on this event iff bit `s` is set — PM-independent, so the whole
    /// predicate evaluation happens once per event instead of once per
    /// (PM, event) check.
    #[inline]
    pub fn step_mask(&self, e: &Event) -> u64 {
        debug_assert!(self.key_free_seq);
        static DUMMY: std::sync::OnceLock<PartialMatch> = std::sync::OnceLock::new();
        let dummy = DUMMY.get_or_init(|| PartialMatch::seed(u64::MAX, 0));
        let mut mask = 0u64;
        for (i, spec) in self.head.iter().enumerate() {
            if matches_spec(spec, e, dummy) {
                mask |= 1 << i;
            }
        }
        mask
    }

    /// Is `state` the final (accepting) state?
    #[inline]
    pub fn is_final(&self, state: u32) -> bool {
        state as usize == self.m - 1
    }

    /// Offer event `e` to `pm`; advance it if the next step matches.
    ///
    /// Skip-till-next/any semantics: a non-matching event leaves the PM
    /// untouched (`NoMatch`), it never kills it — windows closing is
    /// what retires unfinished PMs.
    #[inline]
    pub fn try_advance(&self, pm: &mut PartialMatch, e: &Event) -> StepResult {
        let s = pm.state as usize;
        debug_assert!(s < self.m - 1, "PM already final");
        if s < self.head.len() {
            let spec = &self.head[s];
            if !matches_spec(spec, e, pm) {
                return StepResult::NoMatch;
            }
            if let Some((k, slot)) = spec.bind_key {
                pm.bind_key(k, e.attrs[slot]);
            }
            pm.state += 1;
        } else {
            let group = self
                .any
                .as_ref()
                .expect("state beyond head requires an any-group");
            if !matches_spec(&group.spec, e, pm) {
                return StepResult::NoMatch;
            }
            let id = e.attr_id(group.distinct_slot);
            if pm.seen.contains(id) {
                return StepResult::NoMatch;
            }
            if let Some((k, slot)) = group.spec.bind_key {
                pm.bind_key(k, e.attrs[slot]);
            }
            pm.seen.push(id);
            pm.state += 1;
        }
        if self.is_final(pm.state) {
            StepResult::Completed
        } else {
            StepResult::Advanced
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::bus;
    use crate::query::builtin::{q1, q3, q4};

    fn ev(etype: u16, attrs: &[f64]) -> Event {
        Event::new(0, 0, etype, attrs)
    }

    #[test]
    fn seq_advances_in_order_only() {
        use crate::query::builtin::PATTERN_RANKS as R;
        let cq = CompiledQuery::compile(q1(100).queries.remove(0));
        let mut pm = PartialMatch::seed(0, 0);
        let s0 = R[0] as f64;
        let s1 = R[1] as f64;
        // second pattern symbol rising first: not step 0 -> no match
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s1, 10.0, 1.0])), StepResult::NoMatch);
        // first symbol falling: predicate fails
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s0, 10.0, 0.0])), StepResult::NoMatch);
        // first symbol rising: advances
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s0, 10.0, 1.0])), StepResult::Advanced);
        assert_eq!(pm.state, 1);
        // now the second symbol rising advances
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s1, 10.0, 1.0])), StepResult::Advanced);
    }

    #[test]
    fn seq_completes_at_last_step() {
        use crate::query::builtin::PATTERN_RANKS as R;
        let cq = CompiledQuery::compile(q1(100).queries.remove(0));
        let mut pm = PartialMatch::seed(0, 0);
        for sym in &R[..9] {
            assert_eq!(
                cq.try_advance(&mut pm, &ev(0, &[*sym as f64, 1.0, 1.0])),
                StepResult::Advanced
            );
        }
        assert_eq!(
            cq.try_advance(&mut pm, &ev(0, &[R[9] as f64, 1.0, 1.0])),
            StepResult::Completed
        );
        assert!(cq.is_final(pm.state));
    }

    #[test]
    fn any_requires_distinct_and_same_key() {
        let cq = CompiledQuery::compile(q4(3, 1000, 500).queries.remove(0));
        let mut pm = PartialMatch::seed(0, 0);
        let delayed = |busid: f64, stop: f64| ev(0, &[busid, stop, 1.0, 5.0]);
        // first delayed bus binds stop 7
        assert_eq!(cq.try_advance(&mut pm, &delayed(1.0, 7.0)), StepResult::Advanced);
        assert_eq!(pm.keys[0], 7.0);
        // same bus again: distinctness rejects
        assert_eq!(cq.try_advance(&mut pm, &delayed(1.0, 7.0)), StepResult::NoMatch);
        // different stop: key correlation rejects
        assert_eq!(cq.try_advance(&mut pm, &delayed(2.0, 8.0)), StepResult::NoMatch);
        // on-time bus at stop 7: predicate rejects
        assert_eq!(
            cq.try_advance(&mut pm, &ev(0, &[3.0, 7.0, 0.0, 0.0])),
            StepResult::NoMatch
        );
        // two more distinct delayed buses at stop 7: completes
        assert_eq!(cq.try_advance(&mut pm, &delayed(2.0, 7.0)), StepResult::Advanced);
        assert_eq!(cq.try_advance(&mut pm, &delayed(3.0, 7.0)), StepResult::Completed);
        assert_eq!(pm.seen.to_vec(), vec![1, 2, 3]);
        let _ = bus::A_BUS;
    }

    #[test]
    fn seq_any_head_binds_team() {
        let cq = CompiledQuery::compile(q3(2, 1500).queries.remove(0));
        let mut pm = PartialMatch::seed(0, 0);
        // striker (player 9, team 0) takes possession
        assert_eq!(
            cq.try_advance(&mut pm, &ev(0, &[9.0, 0.0, 50.0, 30.0])),
            StepResult::Advanced
        );
        assert_eq!(pm.keys[0], 0.0);
        // own-team player close to ball: KeyCmp(team != 0) rejects
        assert_eq!(
            cq.try_advance(&mut pm, &ev(1, &[5.0, 0.0, 50.0, 30.0, 1.0])),
            StepResult::NoMatch
        );
        // far-away opponent: distance rejects
        assert_eq!(
            cq.try_advance(&mut pm, &ev(1, &[15.0, 1.0, 10.0, 10.0, 40.0])),
            StepResult::NoMatch
        );
        // two distinct close opponents: complete
        assert_eq!(
            cq.try_advance(&mut pm, &ev(1, &[15.0, 1.0, 50.0, 30.0, 2.0])),
            StepResult::Advanced
        );
        assert_eq!(
            cq.try_advance(&mut pm, &ev(1, &[16.0, 1.0, 50.0, 30.0, 2.5])),
            StepResult::Completed
        );
    }

    #[test]
    fn repetition_sequence_counts_states() {
        let cq = CompiledQuery::compile(crate::query::builtin::q2(100).queries.remove(0));
        assert_eq!(cq.m, 15);
        let mut pm = PartialMatch::seed(0, 0);
        // RE1 twice in a row per the repetition pattern
        let s0 = crate::query::builtin::PATTERN_RANKS[0] as f64;
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s0, 1.0, 1.0])), StepResult::Advanced);
        assert_eq!(cq.try_advance(&mut pm, &ev(0, &[s0, 1.0, 1.0])), StepResult::Advanced);
        assert_eq!(pm.state, 2);
    }
}
