//! Pattern state machines and partial matches.

pub mod machine;
pub mod pm;

pub use machine::{CompiledQuery, StepResult};
pub use pm::{PartialMatch, SeenSet};
