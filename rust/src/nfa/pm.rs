//! The partial match (PM): a live instance of a pattern state machine
//! inside one window — exactly the unit of state that pSPICE sheds.

/// Maximum number of correlation keys a PM can carry.
pub const MAX_KEYS: usize = 2;

/// A partial match.  `state` counts completed steps, so `state == 0` is
/// the paper's initial state `s_1` and `state == m-1` is the final state
/// `s_m` (at which point the PM has become a complex event and is
/// removed from the operator).
#[derive(Debug, Clone, PartialEq)]
pub struct PartialMatch {
    /// Unique id (diagnostics only; identity for QoR accounting is
    /// `(query, window, key-bits)`, which is shedding-invariant).
    pub id: u64,
    /// Current state, 0-based (0 = initial).
    pub state: u32,
    /// Captured correlation keys (see `StepSpec::bind_key`).
    pub keys: [f64; MAX_KEYS],
    /// Bitmask of which keys are bound.
    pub keys_set: u8,
    /// Distinct ids consumed by the any-group so far.
    pub seen: Vec<i64>,
    /// Sequence number of the event that opened the surrounding window
    /// (for diagnostics and QoR identity).
    pub opened_seq: u64,
}

impl PartialMatch {
    /// Fresh PM at the initial state.
    pub fn seed(id: u64, opened_seq: u64) -> Self {
        PartialMatch {
            id,
            state: 0,
            keys: [0.0; MAX_KEYS],
            keys_set: 0,
            seen: Vec::new(),
            opened_seq,
        }
    }

    /// Is key `k` bound?
    #[inline]
    pub fn has_key(&self, k: usize) -> bool {
        self.keys_set & (1 << k) != 0
    }

    /// Bind key `k` (first binding wins; re-binding is a no-op so the
    /// anchor step's capture is stable).
    #[inline]
    pub fn bind_key(&mut self, k: usize, v: f64) {
        if !self.has_key(k) {
            self.keys[k] = v;
            self.keys_set |= 1 << k;
        }
    }

    /// Stable identity bits of the bound keys (QoR identity component).
    pub fn key_bits(&self) -> u64 {
        // mix both key slots; unbound slots contribute 0
        let a = if self.has_key(0) {
            self.keys[0].to_bits()
        } else {
            0
        };
        let b = if self.has_key(1) {
            self.keys[1].to_bits()
        } else {
            0
        };
        a ^ b.rotate_left(32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_is_initial() {
        let pm = PartialMatch::seed(1, 42);
        assert_eq!(pm.state, 0);
        assert_eq!(pm.opened_seq, 42);
        assert!(!pm.has_key(0));
        assert!(pm.seen.is_empty());
    }

    #[test]
    fn key_binding_first_wins() {
        let mut pm = PartialMatch::seed(0, 0);
        pm.bind_key(0, 7.0);
        pm.bind_key(0, 9.0);
        assert_eq!(pm.keys[0], 7.0);
        assert!(pm.has_key(0));
        assert!(!pm.has_key(1));
    }

    #[test]
    fn key_bits_distinguish_keys() {
        let mut a = PartialMatch::seed(0, 0);
        a.bind_key(0, 7.0);
        let mut b = PartialMatch::seed(1, 0);
        b.bind_key(0, 8.0);
        assert_ne!(a.key_bits(), b.key_bits());
        let unbound = PartialMatch::seed(2, 0);
        assert_eq!(unbound.key_bits(), 0);
    }
}
