//! The partial match (PM): a live instance of a pattern state machine
//! inside one window — exactly the unit of state that pSPICE sheds.

/// Maximum number of correlation keys a PM can carry.
pub const MAX_KEYS: usize = 2;

/// Ids the any-group distinct-set can hold without spilling to the
/// heap.  Every built-in query needs at most `n ≤ 8` distinct matches,
/// so in practice the set lives entirely inside the PM and creating /
/// advancing a PM never touches the allocator.
pub const SEEN_INLINE: usize = 8;

/// The distinct-id set of an any-group: a fixed-size inline array with
/// a heap spill for pathological `n`.  Replaces the per-PM `Vec<i64>`
/// that used to make every seeded PM a heap allocation and every
/// distinctness check a pointer chase.
///
/// Append-only (ids are never removed; the PM is retired instead),
/// which is what makes the inline-prefix representation trivial.
#[derive(Debug, Clone, Default)]
pub struct SeenSet {
    len: u32,
    inline: [i64; SEEN_INLINE],
    /// overflow beyond [`SEEN_INLINE`] ids (empty — no allocation — for
    /// every built-in pattern)
    spill: Vec<i64>,
}

impl SeenSet {
    /// Empty set (no heap allocation).
    pub const fn new() -> Self {
        SeenSet {
            len: 0,
            inline: [0; SEEN_INLINE],
            spill: Vec::new(),
        }
    }

    /// Distinct ids recorded so far.
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// No ids recorded yet?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Has `id` been recorded?
    #[inline]
    pub fn contains(&self, id: i64) -> bool {
        let n = self.len as usize;
        let inline_n = n.min(SEEN_INLINE);
        if self.inline[..inline_n].contains(&id) {
            return true;
        }
        n > SEEN_INLINE && self.spill.contains(&id)
    }

    /// Record `id` (caller guarantees it is new — see
    /// [`SeenSet::contains`]).
    #[inline]
    pub fn push(&mut self, id: i64) {
        let n = self.len as usize;
        if n < SEEN_INLINE {
            self.inline[n] = id;
        } else {
            self.spill.push(id);
        }
        self.len += 1;
    }

    /// Ids in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = i64> + '_ {
        let inline_n = self.len().min(SEEN_INLINE);
        self.inline[..inline_n]
            .iter()
            .copied()
            .chain(self.spill.iter().copied())
    }

    /// Ids in insertion order, materialized (test/diagnostic helper).
    pub fn to_vec(&self) -> Vec<i64> {
        self.iter().collect()
    }
}

impl PartialEq for SeenSet {
    fn eq(&self, other: &Self) -> bool {
        self.len == other.len && self.iter().eq(other.iter())
    }
}

/// A partial match.  `state` counts completed steps, so `state == 0` is
/// the paper's initial state `s_1` and `state == m-1` is the final state
/// `s_m` (at which point the PM has become a complex event and is
/// removed from the operator).
#[derive(Debug, Clone, PartialEq)]
pub struct PartialMatch {
    /// Unique id (diagnostics only; identity for QoR accounting is
    /// `(query, window, key-bits)`, which is shedding-invariant).
    pub id: u64,
    /// Current state, 0-based (0 = initial).
    pub state: u32,
    /// Captured correlation keys (see `StepSpec::bind_key`).
    pub keys: [f64; MAX_KEYS],
    /// Bitmask of which keys are bound.
    pub keys_set: u8,
    /// Distinct ids consumed by the any-group so far.
    pub seen: SeenSet,
    /// Sequence number of the event that opened the surrounding window
    /// (for diagnostics and QoR identity).
    pub opened_seq: u64,
}

impl PartialMatch {
    /// Fresh PM at the initial state.
    pub fn seed(id: u64, opened_seq: u64) -> Self {
        PartialMatch {
            id,
            state: 0,
            keys: [0.0; MAX_KEYS],
            keys_set: 0,
            seen: SeenSet::new(),
            opened_seq,
        }
    }

    /// Is key `k` bound?
    #[inline]
    pub fn has_key(&self, k: usize) -> bool {
        self.keys_set & (1 << k) != 0
    }

    /// Bind key `k` (first binding wins; re-binding is a no-op so the
    /// anchor step's capture is stable).
    #[inline]
    pub fn bind_key(&mut self, k: usize, v: f64) {
        if !self.has_key(k) {
            self.keys[k] = v;
            self.keys_set |= 1 << k;
        }
    }

    /// Stable identity bits of the bound keys (QoR identity component).
    pub fn key_bits(&self) -> u64 {
        // mix both key slots; unbound slots contribute 0
        let a = if self.has_key(0) {
            self.keys[0].to_bits()
        } else {
            0
        };
        let b = if self.has_key(1) {
            self.keys[1].to_bits()
        } else {
            0
        };
        a ^ b.rotate_left(32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_is_initial() {
        let pm = PartialMatch::seed(1, 42);
        assert_eq!(pm.state, 0);
        assert_eq!(pm.opened_seq, 42);
        assert!(!pm.has_key(0));
        assert!(pm.seen.is_empty());
    }

    #[test]
    fn key_binding_first_wins() {
        let mut pm = PartialMatch::seed(0, 0);
        pm.bind_key(0, 7.0);
        pm.bind_key(0, 9.0);
        assert_eq!(pm.keys[0], 7.0);
        assert!(pm.has_key(0));
        assert!(!pm.has_key(1));
    }

    #[test]
    fn key_bits_distinguish_keys() {
        let mut a = PartialMatch::seed(0, 0);
        a.bind_key(0, 7.0);
        let mut b = PartialMatch::seed(1, 0);
        b.bind_key(0, 8.0);
        assert_ne!(a.key_bits(), b.key_bits());
        let unbound = PartialMatch::seed(2, 0);
        assert_eq!(unbound.key_bits(), 0);
    }

    #[test]
    fn seen_set_stays_inline_for_builtin_sizes() {
        let mut s = SeenSet::new();
        for id in 0..SEEN_INLINE as i64 {
            assert!(!s.contains(id));
            s.push(id);
            assert!(s.contains(id));
        }
        assert_eq!(s.len(), SEEN_INLINE);
        assert_eq!(s.to_vec(), (0..SEEN_INLINE as i64).collect::<Vec<_>>());
    }

    #[test]
    fn seen_set_spills_past_inline_capacity() {
        let mut s = SeenSet::new();
        let ids: Vec<i64> = (0..2 * SEEN_INLINE as i64 + 3).collect();
        for &id in &ids {
            s.push(id);
        }
        assert_eq!(s.len(), ids.len());
        for &id in &ids {
            assert!(s.contains(id), "id {id} lost across the spill");
        }
        assert!(!s.contains(-1));
        assert_eq!(s.to_vec(), ids);
    }

    #[test]
    fn seen_set_equality_is_content_based() {
        let mut a = SeenSet::new();
        let mut b = SeenSet::new();
        for id in [3, 1, 4] {
            a.push(id);
            b.push(id);
        }
        assert_eq!(a, b);
        b.push(15);
        assert_ne!(a, b);
    }
}
