//! Measured overload detection, and the [`OverloadGauge`] that lets
//! every consumer — shedders, the pipeline, the sharded coordinator —
//! speak to either detector through one interface.
//!
//! The classic [`OverloadDetector`](super::OverloadDetector) *predicts*
//! latency from regressions fitted at calibration time (paper Alg. 1).
//! [`MeasuredDetector`] never predicts: it maintains EWMAs over the
//! latencies the pipeline actually observed — the per-event drain cost
//! of recent batches and the marginal cost of carrying one PM — and
//! combines them with the *measured* queueing delay of the batch at
//! hand (in the real-time plane, straight from the ingest queue's
//! arrival stamps):
//!
//! ```text
//! l̂_p           = EWMA(batch makespan / batch events)       (drain)
//! β̂             = EWMA(l̂_p sample / n_pm)                   (marginal)
//! ŝ             = EWMA(shed cost / scanned PMs)
//! overloaded    ⇔ l_q + l̂_p + ŝ·n_pm + b_s > LB
//! ρ             = ⌈(l_q + l̂_p + ŝ·n_pm + b_s − LB) / β̂⌉
//! ```
//!
//! i.e. ρ is the number of PMs whose measured marginal cost covers the
//! bound violation.  Because the EWMAs are fed with batch *makespans*
//! (the slowest shard), parallelism is already priced in and no `1/k`
//! scaling applies — one of the documented ways the two detectors can
//! disagree (EXPERIMENTS.md design note #4).

use super::detector::OverloadDetector;

/// Which overload detector drives shedding
/// ([`crate::pipeline::PipelineBuilder::overload`] selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadKind {
    /// regression predictions fitted at calibration (paper Alg. 1)
    #[default]
    Predicted,
    /// EWMAs over observed batch latencies + measured queue delay
    Measured,
}

impl OverloadKind {
    /// Canonical CLI/config name.
    pub fn name(self) -> &'static str {
        match self {
            OverloadKind::Predicted => "predicted",
            OverloadKind::Measured => "measured",
        }
    }
}

impl std::str::FromStr for OverloadKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "predicted" => Ok(OverloadKind::Predicted),
            "measured" => Ok(OverloadKind::Measured),
            other => anyhow::bail!("unknown overload detector {other:?} (predicted|measured)"),
        }
    }
}

/// Overload detection from measured signals only (no fitted model).
#[derive(Debug, Clone)]
pub struct MeasuredDetector {
    /// Latency bound LB (ns).
    pub lb_ns: f64,
    /// Safety buffer `b_s` (ns).
    pub safety_ns: f64,
    /// EWMA smoothing factor per observed batch.
    alpha: f64,
    /// EWMA of per-event drain cost (ns/event) — the inverse drain rate.
    drain_ns: f64,
    /// EWMA of the marginal per-event cost of one live PM (ns/(event·PM)).
    per_pm_ns: f64,
    /// EWMA of the per-scanned-PM shed cost (ns/PM).
    shed_per_pm_ns: f64,
    /// batches observed
    samples: u64,
    /// batches observed with a live PM population
    pm_samples: u64,
    /// don't fire before this many batches have been seen
    min_samples: u64,
}

impl MeasuredDetector {
    /// Detector for a latency bound (ns) with a safety buffer.
    pub fn new(lb_ns: f64, safety_ns: f64) -> Self {
        MeasuredDetector {
            lb_ns,
            safety_ns,
            alpha: 0.1,
            drain_ns: 0.0,
            per_pm_ns: 0.0,
            shed_per_pm_ns: 0.0,
            samples: 0,
            pm_samples: 0,
            min_samples: 5,
        }
    }

    #[inline]
    fn ewma(current: f64, sample: f64, alpha: f64, first: bool) -> f64 {
        if first {
            sample
        } else {
            (1.0 - alpha) * current + alpha * sample
        }
    }

    /// Feed one observed batch: `n_pm` live PMs while it processed,
    /// `events` events, `cost_ns` its makespan (slowest shard).
    pub fn observe_batch(&mut self, n_pm: usize, events: usize, cost_ns: f64) {
        if events == 0 {
            return;
        }
        let per_event = cost_ns / events as f64;
        self.drain_ns = Self::ewma(self.drain_ns, per_event, self.alpha, self.samples == 0);
        self.samples += 1;
        if n_pm > 0 {
            let marginal = per_event / n_pm as f64;
            self.per_pm_ns =
                Self::ewma(self.per_pm_ns, marginal, self.alpha, self.pm_samples == 0);
            self.pm_samples += 1;
        }
    }

    /// Feed one observed shed round: `scanned` PMs scanned, `cost_ns`
    /// the round's makespan.
    pub fn observe_shedding(&mut self, scanned: usize, cost_ns: f64) {
        if scanned == 0 {
            return;
        }
        self.shed_per_pm_ns = Self::ewma(
            self.shed_per_pm_ns,
            cost_ns / scanned as f64,
            self.alpha,
            self.shed_per_pm_ns == 0.0,
        );
    }

    /// Enough observations to act on?
    pub fn ready(&self) -> bool {
        self.samples >= self.min_samples && self.drain_ns > 0.0
    }

    /// Measured per-event drain cost (ns); the drain *rate* is its
    /// inverse.
    pub fn drain_ns(&self) -> f64 {
        self.drain_ns
    }

    /// Measured drain rate (events per second).
    pub fn drain_rate_per_sec(&self) -> f64 {
        if self.drain_ns > 0.0 {
            1e9 / self.drain_ns
        } else {
            0.0
        }
    }

    /// Measured marginal cost of one live PM (ns per event per PM).
    pub fn per_pm_ns(&self) -> f64 {
        self.per_pm_ns
    }

    /// The measured analogue of Alg. 1: from the batch's *measured*
    /// queueing delay and the EWMA'd drain/marginal costs, return
    /// `Some(ρ)` when the bound is threatened.  `parallelism` is
    /// accepted for interface parity but unused — makespan observations
    /// already price the shards in.
    pub fn check_scaled(&self, l_q_ns: f64, n_pm: usize, _parallelism: usize) -> Option<usize> {
        if !self.ready() || n_pm == 0 {
            return None;
        }
        let l_s = self.shed_per_pm_ns * n_pm as f64;
        let projected = l_q_ns + self.drain_ns + l_s + self.safety_ns;
        let excess = projected - self.lb_ns;
        if excess <= 0.0 {
            return None;
        }
        // β̂ = measured cost of carrying one PM; when no marginal has
        // been observed yet, attribute the whole drain cost to the
        // population (the most aggressive consistent assumption)
        let marginal = if self.per_pm_ns > 0.0 {
            self.per_pm_ns
        } else {
            self.drain_ns / n_pm as f64
        };
        let rho = (excess / marginal).ceil().max(1.0) as usize;
        Some(rho.min(n_pm))
    }
}

/// One interface over both overload detectors.  Everything downstream
/// of the [`crate::pipeline::PipelineBuilder::overload`] switch — the
/// shedding strategies and, through them, the sharded coordinator —
/// holds an `OverloadGauge` and never knows which plane it is on.
#[derive(Debug, Clone)]
pub enum OverloadGauge {
    /// calibration-fitted regression predictions (paper Alg. 1)
    Predicted(OverloadDetector),
    /// EWMAs over observed latencies (measured plane)
    Measured(MeasuredDetector),
}

impl OverloadGauge {
    /// Which plane this gauge runs on.
    pub fn kind(&self) -> OverloadKind {
        match self {
            OverloadGauge::Predicted(_) => OverloadKind::Predicted,
            OverloadGauge::Measured(_) => OverloadKind::Measured,
        }
    }

    /// The latency bound LB (ns).
    pub fn lb_ns(&self) -> f64 {
        match self {
            OverloadGauge::Predicted(d) => d.lb_ns,
            OverloadGauge::Measured(d) => d.lb_ns,
        }
    }

    /// Can the gauge act yet (fitted / enough observations)?
    pub fn trained(&self) -> bool {
        match self {
            OverloadGauge::Predicted(d) => d.trained(),
            OverloadGauge::Measured(d) => d.ready(),
        }
    }

    /// Shard-aware overload check: `Some(ρ)` when shedding is needed.
    pub fn check_scaled(&self, l_q_ns: f64, n_pm: usize, parallelism: usize) -> Option<usize> {
        match self {
            OverloadGauge::Predicted(d) => d.check_scaled(l_q_ns, n_pm, parallelism),
            OverloadGauge::Measured(d) => d.check_scaled(l_q_ns, n_pm, parallelism),
        }
    }

    /// Estimated per-event processing latency at the current population
    /// for a `parallelism`-wide deployment (E-BL's controller input).
    pub fn estimate_lp_scaled(&self, n_pm: usize, parallelism: usize) -> f64 {
        match self {
            OverloadGauge::Predicted(d) => d.predict_lp(n_pm) / parallelism.max(1) as f64,
            // measured makespans already include the parallelism
            OverloadGauge::Measured(d) => d.drain_ns(),
        }
    }

    /// Record an observed shed round (feeds `g()` on the predicted
    /// plane, the shed-cost EWMA on the measured one).
    pub fn observe_shedding(&mut self, scanned: usize, cost_ns: f64) {
        match self {
            OverloadGauge::Predicted(d) => d.observe_shedding(scanned, cost_ns),
            OverloadGauge::Measured(d) => d.observe_shedding(scanned, cost_ns),
        }
    }

    /// Record an observed processing batch.  No-op on the predicted
    /// plane (its `f()` is frozen at calibration), the lifeblood of the
    /// measured one.
    pub fn observe_batch(&mut self, n_pm: usize, events: usize, cost_ns: f64) {
        match self {
            OverloadGauge::Predicted(_) => {}
            OverloadGauge::Measured(d) => d.observe_batch(n_pm, events, cost_ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed() -> MeasuredDetector {
        let mut d = MeasuredDetector::new(10_000.0, 0.0);
        // steady state: 1000 PMs, batches of 100 events costing 5µs
        // per event ⇒ marginal ≈ 5 ns/(event·PM)
        for _ in 0..50 {
            d.observe_batch(1_000, 100, 100.0 * 5_000.0);
            d.observe_shedding(1_000, 1_000.0);
        }
        d
    }

    #[test]
    fn needs_warmup_before_firing() {
        let mut d = MeasuredDetector::new(1_000.0, 0.0);
        d.observe_batch(100, 10, 1e9);
        assert!(!d.ready());
        assert_eq!(d.check_scaled(1e9, 100, 1), None, "unready never fires");
        for _ in 0..10 {
            d.observe_batch(100, 10, 1e9);
        }
        assert!(d.ready());
        assert!(d.check_scaled(1e9, 100, 1).is_some());
    }

    #[test]
    fn empty_batches_are_ignored() {
        let mut d = MeasuredDetector::new(1_000.0, 0.0);
        for _ in 0..100 {
            d.observe_batch(10, 0, 123.0);
            d.observe_shedding(0, 123.0);
        }
        assert!(!d.ready());
    }

    #[test]
    fn no_overload_when_drain_fits_the_bound() {
        let d = fed();
        // 5µs per event under a 10µs bound with no queueing: fine
        assert_eq!(d.check_scaled(0.0, 1_000, 1), None);
    }

    #[test]
    fn measured_queue_delay_drives_rho() {
        let d = fed();
        // 8µs of measured queueing on top of 5µs drain breaks the
        // 10µs bound by ~3µs+shed ⇒ ρ ≈ excess / 5ns ≈ 600+
        let rho = d.check_scaled(8_000.0, 1_000, 1).expect("overloaded");
        assert!(rho >= 600, "rho={rho}");
        assert!(rho <= 1_000, "clamped to the population");
        // more delay, more shedding
        let rho_hot = d.check_scaled(9_000.0, 1_000, 1).unwrap();
        assert!(rho_hot > rho);
        // hopeless delay drops everything
        assert_eq!(d.check_scaled(1e9, 1_000, 1), Some(1_000));
    }

    #[test]
    fn drain_rate_tracks_observations() {
        let d = fed();
        assert!((d.drain_ns() - 5_000.0).abs() < 1e-9);
        assert!((d.drain_rate_per_sec() - 200_000.0).abs() < 1e-3);
        assert!((d.per_pm_ns() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_adapts_to_regime_change() {
        let mut d = fed();
        assert!((d.drain_ns() - 5_000.0).abs() < 1e-9);
        // the operator suddenly drains 10x faster
        for _ in 0..100 {
            d.observe_batch(1_000, 100, 100.0 * 500.0);
        }
        assert!(d.drain_ns() < 600.0, "EWMA converges: {}", d.drain_ns());
    }

    #[test]
    fn gauge_dispatches_to_both_planes() {
        let m = OverloadGauge::Measured(fed());
        assert_eq!(m.kind(), OverloadKind::Measured);
        assert!(m.trained());
        assert_eq!(m.lb_ns(), 10_000.0);
        assert!(m.check_scaled(9_000.0, 1_000, 1).is_some());
        assert!((m.estimate_lp_scaled(123, 4) - 5_000.0).abs() < 1e-9);

        let p = OverloadGauge::Predicted(OverloadDetector::new(10_000.0, 0.0));
        assert_eq!(p.kind(), OverloadKind::Predicted);
        assert!(!p.trained(), "untrained regression");
        assert_eq!(p.check_scaled(1e9, 1_000, 1), None);
        // observe_batch is a no-op on the predicted plane
        let mut p = p;
        p.observe_batch(1_000, 100, 1e9);
        assert!(!p.trained());
    }

    #[test]
    fn overload_kind_round_trips() {
        for k in [OverloadKind::Predicted, OverloadKind::Measured] {
            assert_eq!(k.name().parse::<OverloadKind>().unwrap(), k);
        }
        assert!("psychic".parse::<OverloadKind>().is_err());
        assert_eq!(OverloadKind::default(), OverloadKind::Predicted);
    }
}
