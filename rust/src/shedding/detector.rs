//! The overload detector (paper Algorithm 1 + §III-E).
//!
//! For every incoming event it estimates
//!
//! ```text
//! l_e = l_q + f(n_pm)          (queueing + predicted processing latency)
//! l_s = g(n_pm)                (predicted shedding latency)
//! ```
//!
//! and, if `l_e + l_s + b_s > LB`, computes the PM budget that restores
//! the bound: `ρ = n_pm − f⁻¹(LB − l_q − l_s)`.
//!
//! `f` and `g` are least-squares regressions (several bases, lowest
//! error wins — [`crate::linalg::regression`]) over statistics gathered
//! at run time, exactly as §III-E prescribes.

use crate::linalg::{fit_latency_model, LatencyModel};

/// Overload detector state.
#[derive(Debug, Clone)]
pub struct OverloadDetector {
    /// Latency bound LB (virtual ns).
    pub lb_ns: f64,
    /// Safety buffer `b_s` (virtual ns) for hard bounds (§III-E Eq. 6).
    pub safety_ns: f64,
    /// fitted `l_p = f(n_pm)`
    f: Option<LatencyModel>,
    /// fitted `l_s = g(n_pm)`
    g: Option<LatencyModel>,
    f_n: Vec<f64>,
    f_y: Vec<f64>,
    g_n: Vec<f64>,
    g_y: Vec<f64>,
    /// max training samples kept per model (reservoir-ish: stride thin)
    cap: usize,
}

impl OverloadDetector {
    /// Detector for a latency bound (ns) with a safety buffer.
    pub fn new(lb_ns: f64, safety_ns: f64) -> Self {
        OverloadDetector {
            lb_ns,
            safety_ns,
            f: None,
            g: None,
            f_n: Vec::new(),
            f_y: Vec::new(),
            g_n: Vec::new(),
            g_y: Vec::new(),
            cap: 4096,
        }
    }

    fn push_capped(xs: &mut Vec<f64>, ys: &mut Vec<f64>, x: f64, y: f64, cap: usize) {
        if xs.len() >= cap {
            // thin by keeping every other sample (cheap, keeps range)
            let mut keep = false;
            xs.retain(|_| {
                keep = !keep;
                keep
            });
            let mut keep = false;
            ys.retain(|_| {
                keep = !keep;
                keep
            });
        }
        xs.push(x);
        ys.push(y);
    }

    /// Record an observed event-processing latency for `n_pm` live PMs.
    pub fn observe_processing(&mut self, n_pm: usize, l_p_ns: f64) {
        Self::push_capped(&mut self.f_n, &mut self.f_y, n_pm as f64, l_p_ns, self.cap);
    }

    /// Record an observed shedding latency for `n_pm` scanned PMs.
    pub fn observe_shedding(&mut self, n_pm: usize, l_s_ns: f64) {
        Self::push_capped(&mut self.g_n, &mut self.g_y, n_pm as f64, l_s_ns, self.cap);
    }

    /// (Re)fit both regressions.  Returns true when `f` is usable.
    pub fn fit(&mut self) -> bool {
        self.f = fit_latency_model(&self.f_n, &self.f_y);
        self.g = fit_latency_model(&self.g_n, &self.g_y);
        self.f.is_some()
    }

    /// Is the detector trained?
    pub fn trained(&self) -> bool {
        self.f.is_some()
    }

    /// Predicted event processing latency for `n_pm` PMs.
    pub fn predict_lp(&self, n_pm: usize) -> f64 {
        self.f.as_ref().map_or(0.0, |m| m.predict(n_pm as f64))
    }

    /// Predicted shedding latency for `n_pm` PMs.
    pub fn predict_ls(&self, n_pm: usize) -> f64 {
        self.g.as_ref().map_or(0.0, |m| m.predict(n_pm as f64))
    }

    /// Algorithm 1: given the event's queueing latency and the live PM
    /// count, return `Some(ρ)` if shedding is needed.
    pub fn check(&self, l_q_ns: f64, n_pm: usize) -> Option<usize> {
        self.check_scaled(l_q_ns, n_pm, 1)
    }

    /// Shard-aware Algorithm 1: with `parallelism` worker shards the
    /// matching and shedding work divide across workers, so the
    /// *predicted* latencies scale by `1/parallelism` while the PM
    /// budget (and the returned ρ) stays global.  `parallelism = 1` is
    /// exactly the paper's single-threaded detector.
    pub fn check_scaled(
        &self,
        l_q_ns: f64,
        n_pm: usize,
        parallelism: usize,
    ) -> Option<usize> {
        let k = parallelism.max(1) as f64;
        let f = self.f.as_ref()?;
        let l_p = f.predict(n_pm as f64) / k;
        let l_s = self.predict_ls(n_pm) / k;
        let l_e = l_q_ns + l_p;
        if l_e + l_s + self.safety_ns <= self.lb_ns {
            return None;
        }
        // l_p' = LB - l_q - l_s  (Alg. 1 line 6); the per-worker budget
        // maps back to a global PM count through the k-scaled inverse
        let lp_target = self.lb_ns - l_q_ns - l_s - self.safety_ns;
        let n_keep = if lp_target <= 0.0 {
            0.0
        } else {
            f.inverse(lp_target * k)
        };
        let rho = (n_pm as f64 - n_keep).ceil().max(0.0) as usize;
        if rho == 0 {
            None
        } else {
            Some(rho.min(n_pm))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// detector trained on a perfectly linear world:
    /// l_p = 100 + 10·n, l_s = 2·n
    fn trained() -> OverloadDetector {
        let mut d = OverloadDetector::new(10_000.0, 0.0);
        for n in (0..200).map(|i| i * 10) {
            d.observe_processing(n, 100.0 + 10.0 * n as f64);
            d.observe_shedding(n, 2.0 * n as f64);
        }
        assert!(d.fit());
        d
    }

    #[test]
    fn no_overload_below_bound() {
        let d = trained();
        // l_q=0, n=100: l_e = 1100, l_s = 200 -> fine under 10000
        assert_eq!(d.check(0.0, 100), None);
    }

    #[test]
    fn rho_restores_bound_exactly() {
        let d = trained();
        // n=2000: l_p = 20100, overload. lp' = 10000 - 0 - ls(2000)=4000
        // => target 6000 => n_keep = (6000-100)/10 = 590 => rho = 1410
        let rho = d.check(0.0, 2000).expect("overloaded");
        assert!((1405..=1415).contains(&rho), "rho={rho}");
        // after dropping rho, the predicted latency is under the bound
        let n_after = 2000 - rho;
        assert!(d.predict_lp(n_after) + d.predict_ls(2000) <= 10_000.0 + 50.0);
    }

    #[test]
    fn queueing_latency_tightens_budget() {
        let d = trained();
        let rho_idle = d.check(0.0, 2000).unwrap();
        let rho_queued = d.check(5_000.0, 2000).unwrap();
        assert!(rho_queued > rho_idle);
    }

    #[test]
    fn rho_clamps_to_all_pms() {
        let d = trained();
        // queueing alone exceeds the bound: drop everything
        let rho = d.check(20_000.0, 500).unwrap();
        assert_eq!(rho, 500);
    }

    #[test]
    fn safety_buffer_triggers_earlier() {
        let mut strict = trained();
        strict.safety_ns = 5_000.0;
        // n=800: l_e = 8100 + l_s 1600 = 9700 < 10000 without buffer,
        // but the 5000 buffer trips it
        assert_eq!(trained().check(0.0, 700), None);
        assert!(strict.check(0.0, 700).is_some());
    }

    #[test]
    fn parallelism_relaxes_the_budget() {
        let d = trained();
        // n=2000 overloads one worker (l_p = 20100 > 10000) but not
        // four: 20100/4 + 4000/4 = 6025 < 10000
        assert!(d.check(0.0, 2000).is_some());
        assert_eq!(d.check_scaled(0.0, 2000, 4), None);
        // at higher load both fire, but the sharded rho is smaller
        let rho1 = d.check(0.0, 5_000).unwrap();
        let rho4 = d.check_scaled(0.0, 5_000, 4).unwrap();
        assert!(rho4 < rho1, "rho4={rho4} rho1={rho1}");
        // scale 1 is exactly the unscaled path
        assert_eq!(d.check(0.0, 2000), d.check_scaled(0.0, 2000, 1));
    }

    #[test]
    fn untrained_never_fires() {
        let d = OverloadDetector::new(1000.0, 0.0);
        assert_eq!(d.check(1e9, 10_000), None);
        assert!(!d.trained());
    }

    #[test]
    fn sample_thinning_keeps_fit_usable() {
        let mut d = OverloadDetector::new(10_000.0, 0.0);
        for n in 0..20_000 {
            d.observe_processing(n, 100.0 + 10.0 * n as f64);
        }
        assert!(d.fit());
        let err = (d.predict_lp(5_000) - 50_100.0).abs() / 50_100.0;
        assert!(err < 0.05, "err={err}");
    }
}
