//! Load shedding: the paper's contribution (pSPICE) plus the two
//! baselines it is evaluated against and the overload detector
//! (Algorithm 1) they all share.
//!
//! * [`detector`] — Alg. 1: latency-regression overload detection and
//!   the drop amount ρ,
//! * [`measured`] — the model-free alternative ([`MeasuredDetector`]:
//!   EWMAs over observed batch latencies + measured queue delay) and
//!   the [`OverloadGauge`] every strategy holds so either detector
//!   plugs in behind one interface,
//! * [`pspice`] — Alg. 2: utility-ordered PM shedding (the white-box
//!   strategy),
//! * [`pm_baseline`] — PM-BL: Bernoulli-random PM shedding,
//! * [`event_baseline`] — E-BL: black-box input-event shedding in the
//!   style of He et al. (type-utility weighted sampling),
//! * [`none`] — pass-through (ground truth / calibration runs).
//!
//! Every strategy implements the batch-first [`Shedder`] trait against
//! the [`OperatorState`] abstraction, so the same strategy object runs
//! unchanged on the single-threaded operator (`parallelism() == 1`,
//! per-event dispatch) and on the sharded runtime (global ρ, k-way
//! merged victims).  Strategies are built through the single
//! [`ShedderKind::build`] factory.

pub mod detector;
pub mod event_baseline;
pub mod measured;
pub mod none;
pub mod pm_baseline;
pub mod pspice;

pub use detector::OverloadDetector;
pub use event_baseline::EventBaselineShedder;
pub use measured::{MeasuredDetector, OverloadGauge, OverloadKind};
pub use none::NoShedder;
pub use pm_baseline::PmBaselineShedder;
pub use pspice::PSpiceShedder;

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::events::{DropMask, Event};
use crate::model::plane::KeyUtilityTable;
use crate::model::ModelConfig;
use crate::operator::OperatorState;
use crate::query::Query;

/// What a shedder did for one batch of incoming events.
///
/// Reports are additive: per-batch reports are accumulated into run
/// totals with [`ShedReport::merge`] / `+=` instead of summing fields
/// by hand.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ShedReport {
    /// PMs dropped from the operator state (white-box shedders).
    pub dropped_pms: u64,
    /// PMs lost to worker failures — the *involuntary* shed rounds: a
    /// crashed shard's partial matches are accounted here (not in
    /// `dropped_pms`, which only counts deliberate strategy drops), so
    /// failure costs QoR on the same axis as shedding instead of
    /// costing availability.
    pub dropped_pms_failure: u64,
    /// Incoming events dropped (black-box shedders).
    pub dropped_events: u64,
    /// PMs a dead worker's respawn restored via snapshot + journal
    /// replay — state that would have been `dropped_pms_failure` under
    /// lossy recovery (recorded, never gated).
    pub recovered_pms: u64,
    /// Journaled events replayed into respawned workers.
    pub replayed_events: u64,
    /// Worker hangs detected by the dispatch deadline.
    pub hangs_detected: u64,
    /// Virtual cost of the shedding work (ns) — the paper's `l_s`.
    pub cost_ns: f64,
}

impl ShedReport {
    /// Fold another report into this one (all fields are additive).
    pub fn merge(&mut self, other: &ShedReport) {
        self.dropped_pms += other.dropped_pms;
        self.dropped_pms_failure += other.dropped_pms_failure;
        self.dropped_events += other.dropped_events;
        self.recovered_pms += other.recovered_pms;
        self.replayed_events += other.replayed_events;
        self.hangs_detected += other.hangs_detected;
        self.cost_ns += other.cost_ns;
    }
}

impl std::ops::AddAssign for ShedReport {
    fn add_assign(&mut self, rhs: ShedReport) {
        self.merge(&rhs);
    }
}

/// A load-shedding strategy, written once against [`OperatorState`].
///
/// `on_batch` runs *before* the state processes `events`, with the
/// batch's current queueing latency `l_q` (virtual ns).  White-box
/// strategies drop PMs through the state; black-box strategies mark
/// victim events in [`Shedder::event_mask`], in which case the state
/// gives those events window bookkeeping only (dropped events still
/// exist in the stream).  The single-threaded runtime dispatches
/// batches of one event, which reproduces the paper's per-event
/// shedding exactly.
pub trait Shedder {
    /// Which [`ShedderKind`] this strategy instantiates.
    fn kind(&self) -> ShedderKind;

    /// Strategy name for reports — derived from the kind, so the name
    /// table lives in exactly one place ([`ShedderKind::name`]).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Decide and perform shedding for one incoming event batch.
    fn on_batch(
        &mut self,
        events: &[Event],
        l_q_ns: f64,
        state: &mut dyn OperatorState,
    ) -> ShedReport;

    /// Per-event drop mask for the batch last passed to
    /// [`Shedder::on_batch`] (black-box strategies only; `None` means
    /// "process every event").  The word-packed [`DropMask`] flows
    /// through [`OperatorState::process_batch`] and, on the sharded
    /// runtime, straight into the pooled mask plane — no `Vec<bool>`
    /// copies anywhere on the drop path.
    fn event_mask(&self) -> Option<&DropMask> {
        None
    }

    /// Feed back what processing the batch actually cost: `n_pm` live
    /// PMs after it, `events` events, `cost_ns` the observed makespan.
    /// The pipeline calls this after every processed batch; strategies
    /// on the predicted plane ignore it (their regressions are frozen
    /// at calibration), strategies holding a measured
    /// [`OverloadGauge`] feed their drain-rate EWMAs.
    fn observe_batch(&mut self, _n_pm: usize, _events: usize, _cost_ns: f64) {}
}

/// Which strategy to instantiate (CLI/config selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedderKind {
    /// no shedding
    None,
    /// the paper's pSPICE
    PSpice,
    /// pSPICE-- (no processing-time term) — Fig. 8 ablation
    PSpiceMinus,
    /// random PM dropping
    PmBaseline,
    /// event dropping
    EventBaseline,
}

/// Every strategy selector, in canonical order.
pub const ALL_SHEDDER_KINDS: [ShedderKind; 5] = [
    ShedderKind::None,
    ShedderKind::PSpice,
    ShedderKind::PSpiceMinus,
    ShedderKind::PmBaseline,
    ShedderKind::EventBaseline,
];

/// Per-strategy RNG seed schedule: each randomized strategy derives its
/// stream from the experiment seed with a fixed xor offset, so
/// strategies never share RNG draws and runs stay reproducible across
/// shard counts.
///
/// | strategy | seed |
/// |---|---|
/// | none / pspice / pspice-- | (no RNG) |
/// | pm-bl | `seed ^ 0xBE11` |
/// | e-bl | `seed ^ 0xEB1` |
const PM_BL_SEED_XOR: u64 = 0xBE11;
/// E-BL's seed offset (see the schedule on [`PM_BL_SEED_XOR`]).
const E_BL_SEED_XOR: u64 = 0xEB1;

impl ShedderKind {
    /// Canonical strategy name — the single string table; every
    /// [`Shedder::name`] derives from it.
    pub fn name(self) -> &'static str {
        match self {
            ShedderKind::None => "none",
            ShedderKind::PSpice => "pspice",
            ShedderKind::PSpiceMinus => "pspice--",
            ShedderKind::PmBaseline => "pm-bl",
            ShedderKind::EventBaseline => "e-bl",
        }
    }

    /// Does this strategy rank PMs by utility tables (which the
    /// pipeline must build and install on the operator state)?
    pub fn needs_tables(self) -> bool {
        matches!(self, ShedderKind::PSpice | ShedderKind::PSpiceMinus)
    }

    /// Model-builder configuration for this strategy's utility tables
    /// (pSPICE-- drops the remaining-processing-time term, the paper's
    /// Fig. 8 ablation).
    pub fn model_config(self) -> ModelConfig {
        ModelConfig {
            use_tau: !matches!(self, ShedderKind::PSpiceMinus),
            ..ModelConfig::default()
        }
    }

    /// Build a boxed [`Shedder`] for this kind from an experiment
    /// configuration (the E-BL key slot is derived from the dataset).
    /// Delegates to [`ShedderKind::build_with`] — the single strategy
    /// construction site.
    pub fn build(
        self,
        cfg: &ExperimentConfig,
        queries: &[Query],
        detector: &OverloadDetector,
        seed: u64,
    ) -> Box<dyn Shedder> {
        self.build_with(queries, detector, cfg.dataset.key_slot(), seed)
    }

    /// Convenience around [`ShedderKind::build_from_plane`]: builds
    /// E-BL's [`KeyUtilityTable`] from `queries` and `key_slot` on the
    /// spot (strategies that don't read it get none).
    pub fn build_with(
        self,
        queries: &[Query],
        detector: &OverloadDetector,
        key_slot: usize,
        seed: u64,
    ) -> Box<dyn Shedder> {
        let key = matches!(self, ShedderKind::EventBaseline)
            .then(|| Arc::new(KeyUtilityTable::from_queries(queries, key_slot)));
        self.build_from_plane(detector, key.as_ref(), seed)
    }

    /// Build against the predicted plane: wraps `detector` in a
    /// [`OverloadGauge::Predicted`] and delegates to
    /// [`ShedderKind::build_from_gauge`] — the single strategy
    /// construction site.
    pub fn build_from_plane(
        self,
        detector: &OverloadDetector,
        key: Option<&Arc<KeyUtilityTable>>,
        seed: u64,
    ) -> Box<dyn Shedder> {
        self.build_from_gauge(&OverloadGauge::Predicted(detector.clone()), key, seed)
    }

    /// The single strategy construction site: build a boxed [`Shedder`]
    /// for this kind against the model plane.  `gauge` is the overload
    /// gauge — predicted (Alg. 1 regressions) or measured (latency
    /// EWMAs) — cloned per strategy; `seed` is the experiment seed,
    /// offset per strategy by the documented seed schedule; `key` is
    /// the `Arc`-shared [`KeyUtilityTable`] E-BL reads (the same one
    /// the pipeline's [`crate::model::TableSet`] snapshot carries;
    /// required for [`ShedderKind::EventBaseline`], ignored by every
    /// other kind).
    pub fn build_from_gauge(
        self,
        gauge: &OverloadGauge,
        key: Option<&Arc<KeyUtilityTable>>,
        seed: u64,
    ) -> Box<dyn Shedder> {
        match self {
            ShedderKind::None => Box::new(NoShedder),
            ShedderKind::PSpice | ShedderKind::PSpiceMinus => {
                Box::new(PSpiceShedder::from_gauge(gauge.clone(), self))
            }
            ShedderKind::PmBaseline => Box::new(PmBaselineShedder::from_gauge(
                gauge.clone(),
                seed ^ PM_BL_SEED_XOR,
            )),
            ShedderKind::EventBaseline => Box::new(EventBaselineShedder::from_gauge(
                gauge.clone(),
                Arc::clone(key.expect("e-bl needs a key-utility table")),
                seed ^ E_BL_SEED_XOR,
            )),
        }
    }
}

impl std::str::FromStr for ShedderKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" => Ok(ShedderKind::None),
            "pspice" => Ok(ShedderKind::PSpice),
            "pspice--" | "pspice-minus" => Ok(ShedderKind::PSpiceMinus),
            "pm-bl" | "pmbl" => Ok(ShedderKind::PmBaseline),
            "e-bl" | "ebl" => Ok(ShedderKind::EventBaseline),
            other => anyhow::bail!("unknown shedder {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::q1;

    #[test]
    fn kind_names_round_trip_through_from_str() {
        for kind in ALL_SHEDDER_KINDS {
            assert_eq!(kind.name().parse::<ShedderKind>().unwrap(), kind);
        }
    }

    #[test]
    fn factory_shedders_agree_with_kind_names() {
        // the naming satellite: Shedder::name derives from
        // ShedderKind::name for every variant the factory can build
        let cfg = ExperimentConfig::default();
        let queries = q1(1_000).queries;
        let det = OverloadDetector::new(1e9, 0.0);
        for kind in ALL_SHEDDER_KINDS {
            let s = kind.build(&cfg, &queries, &det, cfg.seed);
            assert_eq!(s.kind(), kind);
            assert_eq!(s.name(), kind.name());
        }
    }

    #[test]
    fn reports_merge_additively() {
        let mut total = ShedReport::default();
        total += ShedReport {
            dropped_pms: 3,
            dropped_pms_failure: 4,
            dropped_events: 1,
            recovered_pms: 7,
            replayed_events: 64,
            hangs_detected: 1,
            cost_ns: 10.0,
        };
        let mut other = ShedReport {
            dropped_pms: 2,
            dropped_pms_failure: 1,
            dropped_events: 0,
            recovered_pms: 3,
            replayed_events: 6,
            hangs_detected: 0,
            cost_ns: 5.5,
        };
        other.merge(&total);
        assert_eq!(other.dropped_pms, 5);
        assert_eq!(other.dropped_pms_failure, 5);
        assert_eq!(other.dropped_events, 1);
        assert_eq!(other.recovered_pms, 10);
        assert_eq!(other.replayed_events, 70);
        assert_eq!(other.hangs_detected, 1);
        assert!((other.cost_ns - 15.5).abs() < 1e-12);
    }

    #[test]
    fn only_utility_strategies_need_tables() {
        for kind in ALL_SHEDDER_KINDS {
            assert_eq!(
                kind.needs_tables(),
                matches!(kind, ShedderKind::PSpice | ShedderKind::PSpiceMinus)
            );
        }
        assert!(ShedderKind::PSpice.model_config().use_tau);
        assert!(!ShedderKind::PSpiceMinus.model_config().use_tau);
    }
}
