//! Load shedding: the paper's contribution (pSPICE) plus the two
//! baselines it is evaluated against and the overload detector
//! (Algorithm 1) they all share.
//!
//! * [`detector`] — Alg. 1: latency-regression overload detection and
//!   the drop amount ρ,
//! * [`pspice`] — Alg. 2: utility-ordered PM shedding (the white-box
//!   strategy),
//! * [`pm_baseline`] — PM-BL: Bernoulli-random PM shedding,
//! * [`event_baseline`] — E-BL: black-box input-event shedding in the
//!   style of [15]/[13] (type-utility weighted sampling),
//! * [`none`] — pass-through (ground truth / calibration runs).

pub mod detector;
pub mod event_baseline;
pub mod none;
pub mod pm_baseline;
pub mod pspice;

pub use detector::OverloadDetector;
pub use event_baseline::EventBaselineShedder;
pub use none::NoShedder;
pub use pm_baseline::PmBaselineShedder;
pub use pspice::PSpiceShedder;

use crate::events::Event;
use crate::operator::Operator;

/// What a shedder did for one incoming event.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ShedReport {
    /// PMs dropped from the operator state (white-box shedders).
    pub dropped_pms: usize,
    /// The incoming event itself was dropped (black-box shedders).
    pub dropped_event: bool,
    /// Virtual cost of the shedding work (ns) — the paper's `l_s`.
    pub cost_ns: f64,
}

/// A load-shedding strategy.
///
/// `on_event` runs *before* the operator processes `e`, with the
/// event's current queueing latency `l_q` (virtual ns).  White-box
/// strategies mutate the operator state; black-box strategies may claim
/// the event (`dropped_event`), in which case the operator never sees
/// it (but window accounting still advances — dropped events exist in
/// the stream).
pub trait Shedder {
    /// Strategy name for reports.
    fn name(&self) -> &'static str;

    /// Decide and perform shedding for one incoming event.
    fn on_event(&mut self, e: &Event, l_q_ns: f64, op: &mut Operator) -> ShedReport;

    /// Install freshly built utility tables (model retraining, paper
    /// §III-D).  Default: no-op — only utility-driven strategies care.
    fn update_tables(&mut self, _tables: Vec<crate::model::UtilityTable>) {}
}

/// Which strategy to instantiate (CLI/config selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedderKind {
    /// no shedding
    None,
    /// the paper's pSPICE
    PSpice,
    /// pSPICE-- (no processing-time term) — Fig. 8 ablation
    PSpiceMinus,
    /// random PM dropping
    PmBaseline,
    /// event dropping
    EventBaseline,
}

impl ShedderKind {
    /// Canonical strategy name — matches the `Shedder::name()` of the
    /// strategy this kind instantiates, so sharded and single-threaded
    /// runs report identically.
    pub fn name(self) -> &'static str {
        match self {
            ShedderKind::None => "none",
            ShedderKind::PSpice => "pspice",
            ShedderKind::PSpiceMinus => "pspice--",
            ShedderKind::PmBaseline => "pm-bl",
            ShedderKind::EventBaseline => "e-bl",
        }
    }
}

impl std::str::FromStr for ShedderKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" => Ok(ShedderKind::None),
            "pspice" => Ok(ShedderKind::PSpice),
            "pspice--" | "pspice-minus" => Ok(ShedderKind::PSpiceMinus),
            "pm-bl" | "pmbl" => Ok(ShedderKind::PmBaseline),
            "e-bl" | "ebl" => Ok(ShedderKind::EventBaseline),
            other => anyhow::bail!("unknown shedder {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip_through_from_str() {
        for kind in [
            ShedderKind::None,
            ShedderKind::PSpice,
            ShedderKind::PSpiceMinus,
            ShedderKind::PmBaseline,
            ShedderKind::EventBaseline,
        ] {
            assert_eq!(kind.name().parse::<ShedderKind>().unwrap(), kind);
        }
    }
}
