//! PM-BL (paper §IV-A): the random partial-match dropper — same
//! overload detector and drop amount ρ as pSPICE, but victims are
//! selected by a Bernoulli/uniform draw instead of by utility.

use crate::events::Event;
use crate::operator::Operator;
use crate::runtime::ShardedOperator;
use crate::util::Rng;

use super::detector::OverloadDetector;
use super::{ShedReport, Shedder};

/// The random PM-shedding baseline.
pub struct PmBaselineShedder {
    /// shared overload detector
    pub detector: OverloadDetector,
    rng: Rng,
    /// total PMs dropped (reporting)
    pub total_dropped: u64,
}

impl PmBaselineShedder {
    /// Baseline with its own RNG stream.
    pub fn new(detector: OverloadDetector, seed: u64) -> Self {
        PmBaselineShedder {
            detector,
            rng: Rng::seeded(seed),
            total_dropped: 0,
        }
    }

    /// Shard-aware PM-BL: same global ρ as pSPICE (detector latency
    /// scaled by the shard count), victims drawn uniformly across
    /// shards proportionally to their PM populations.
    pub fn on_batch(&mut self, l_q_ns: f64, sop: &mut ShardedOperator) -> ShedReport {
        let n_pm = sop.pm_count();
        let Some(rho) = self.detector.check_scaled(l_q_ns, n_pm, sop.n_shards())
        else {
            return ShedReport::default();
        };
        let dropped = sop.drop_random(rho, &mut self.rng);
        self.total_dropped += dropped as u64;
        // the cheap scan parallelizes across shards
        let cost_ns = (sop.cost.shed_drop_ns * dropped as f64
            + 0.25 * sop.cost.shed_scan_ns * n_pm as f64)
            / sop.n_shards() as f64;
        self.detector.observe_shedding(n_pm, cost_ns);
        ShedReport {
            dropped_pms: dropped,
            dropped_event: false,
            cost_ns,
        }
    }
}

impl Shedder for PmBaselineShedder {
    fn name(&self) -> &'static str {
        "pm-bl"
    }

    fn on_event(&mut self, _e: &Event, l_q_ns: f64, op: &mut Operator) -> ShedReport {
        let n_pm = op.pm_count();
        let Some(rho) = self.detector.check(l_q_ns, n_pm) else {
            return ShedReport::default();
        };
        let dropped = op.drop_random(rho, &mut self.rng);
        self.total_dropped += dropped as u64;
        // random selection still scans the PM population once but needs
        // no utility lookups/selection: model only the drop cost plus a
        // cheap scan (the paper notes PM-BL is slightly cheaper).
        let cost_ns = op.cost.shed_drop_ns * dropped as f64
            + 0.25 * op.cost.shed_scan_ns * n_pm as f64;
        self.detector.observe_shedding(n_pm, cost_ns);
        ShedReport {
            dropped_pms: dropped,
            dropped_event: false,
            cost_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::query::builtin::q4;

    #[test]
    fn drops_when_detector_fires() {
        let mut op = Operator::new(q4(6, 4000, 200).queries);
        let mut g = BusGen::with_seed(9);
        for _ in 0..40_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut det = OverloadDetector::new(1_000.0, 0.0);
        // linear world where the current PM count is way over budget
        for n in (0..100).map(|i| i * 50) {
            det.observe_processing(n, 10.0 * n as f64);
            det.observe_shedding(n, n as f64);
        }
        det.fit();
        let mut shed = PmBaselineShedder::new(det, 1);
        let before = op.pm_count();
        let e = g.next_event().unwrap();
        let rep = shed.on_event(&e, 0.0, &mut op);
        assert!(rep.dropped_pms > 0);
        assert_eq!(op.pm_count(), before - rep.dropped_pms);
        assert!(rep.cost_ns > 0.0);
    }
}
