//! PM-BL (paper §IV-A): the random partial-match dropper — same
//! overload detector and drop amount ρ as pSPICE, but victims are
//! selected by a Bernoulli/uniform draw instead of by utility.

use crate::events::Event;
use crate::operator::OperatorState;
use crate::util::Rng;

use super::detector::OverloadDetector;
use super::measured::OverloadGauge;
use super::{ShedReport, Shedder, ShedderKind};

/// The random PM-shedding baseline.
pub struct PmBaselineShedder {
    /// the overload gauge (predicted or measured plane)
    pub detector: OverloadGauge,
    rng: Rng,
    /// total PMs dropped (reporting)
    pub total_dropped: u64,
}

impl PmBaselineShedder {
    /// Baseline on the predicted plane with its own RNG stream.
    pub fn new(detector: OverloadDetector, seed: u64) -> Self {
        Self::from_gauge(OverloadGauge::Predicted(detector), seed)
    }

    /// Baseline from either overload plane.
    pub fn from_gauge(gauge: OverloadGauge, seed: u64) -> Self {
        PmBaselineShedder {
            detector: gauge,
            rng: Rng::seeded(seed),
            total_dropped: 0,
        }
    }
}

impl Shedder for PmBaselineShedder {
    fn kind(&self) -> ShedderKind {
        ShedderKind::PmBaseline
    }

    fn on_batch(
        &mut self,
        _events: &[Event],
        l_q_ns: f64,
        state: &mut dyn OperatorState,
    ) -> ShedReport {
        let n_pm = state.pm_count();
        let Some(rho) = self
            .detector
            .check_scaled(l_q_ns, n_pm, state.parallelism())
        else {
            return ShedReport::default();
        };
        let dropped = state.drop_random(rho, &mut self.rng);
        self.total_dropped += dropped as u64;
        // random selection still scans the PM population once but needs
        // no utility lookups, cell index or selection: model only the
        // drop cost plus a cheap per-PM scan (the paper notes PM-BL is
        // slightly cheaper).  `shed_scan_ns` is per *cell*, so dividing
        // by EST_PMS_PER_CELL recovers the per-PM scan unit; the scan
        // parallelizes across shards.
        let cost = state.cost();
        let per_pm_scan_ns = cost.shed_scan_ns / crate::operator::EST_PMS_PER_CELL;
        let cost_ns = (cost.shed_drop_ns * dropped as f64
            + 0.25 * per_pm_scan_ns * n_pm as f64)
            / state.parallelism() as f64;
        self.detector.observe_shedding(n_pm, cost_ns);
        ShedReport {
            dropped_pms: dropped as u64,
            cost_ns,
            ..ShedReport::default()
        }
    }

    fn observe_batch(&mut self, n_pm: usize, events: usize, cost_ns: f64) {
        self.detector.observe_batch(n_pm, events, cost_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::operator::Operator;
    use crate::query::builtin::q4;

    #[test]
    fn drops_when_detector_fires() {
        let mut op = Operator::new(q4(6, 4000, 200).queries);
        let mut g = BusGen::with_seed(9);
        for _ in 0..40_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut det = OverloadDetector::new(1_000.0, 0.0);
        // linear world where the current PM count is way over budget
        for n in (0..100).map(|i| i * 50) {
            det.observe_processing(n, 10.0 * n as f64);
            det.observe_shedding(n, n as f64);
        }
        det.fit();
        let mut shed = PmBaselineShedder::new(det, 1);
        let before = op.pm_count();
        let e = g.next_event().unwrap();
        let rep = shed.on_batch(&[e], 0.0, &mut op);
        assert!(rep.dropped_pms > 0);
        assert_eq!(op.pm_count() as u64, before as u64 - rep.dropped_pms);
        assert!(rep.cost_ns > 0.0);
    }
}
