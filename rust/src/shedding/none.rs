//! The pass-through shedder: never drops anything.  Used for the
//! ground-truth run and for calibration phases.

use crate::events::Event;
use crate::operator::OperatorState;

use super::{ShedReport, Shedder, ShedderKind};

/// No-op shedding strategy.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoShedder;

impl Shedder for NoShedder {
    fn kind(&self) -> ShedderKind {
        ShedderKind::None
    }

    fn on_batch(
        &mut self,
        _events: &[Event],
        _l_q_ns: f64,
        _state: &mut dyn OperatorState,
    ) -> ShedReport {
        ShedReport::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operator::Operator;
    use crate::query::builtin::q1;

    #[test]
    fn never_drops() {
        let mut op = Operator::new(q1(100).queries);
        let e = Event::new(0, 0, 0, &[0.0, 1.0, 1.0]);
        let rep = NoShedder.on_batch(&[e], f64::MAX, &mut op);
        assert_eq!(rep, ShedReport::default());
        assert!(NoShedder.event_mask().is_none());
    }
}
