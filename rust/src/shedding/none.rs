//! The pass-through shedder: never drops anything.  Used for the
//! ground-truth run and for calibration phases.

use crate::events::Event;
use crate::operator::Operator;

use super::{ShedReport, Shedder};

/// No-op shedding strategy.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoShedder;

impl Shedder for NoShedder {
    fn name(&self) -> &'static str {
        "none"
    }

    fn on_event(&mut self, _e: &Event, _l_q_ns: f64, _op: &mut Operator) -> ShedReport {
        ShedReport::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::builtin::q1;

    #[test]
    fn never_drops() {
        let mut op = Operator::new(q1(100).queries);
        let e = Event::new(0, 0, 0, &[0.0, 1.0, 1.0]);
        let rep = NoShedder.on_event(&e, f64::MAX, &mut op);
        assert_eq!(rep, ShedReport::default());
    }
}
