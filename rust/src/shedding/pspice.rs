//! pSPICE (paper Algorithm 2): drop the ρ lowest-utility partial
//! matches.  The utility ranking itself lives in the operator state
//! ([`OperatorState::shed_lowest`] — O(n) selection on one shard, k-way
//! candidate merge across shards); this strategy owns the *decision*:
//! Alg. 1's overload check, the drop amount ρ, and the shed-cost
//! feedback into the detector's `g()` regression.
//!
//! The same object drives both runtimes: on the sharded backend the
//! detector sees the global `n_pm` with latency predictions scaled by
//! the worker parallelism, and the shed cost is the slowest shard's
//! scan + drop (shards shed in parallel).

use crate::events::Event;
use crate::operator::OperatorState;

use super::detector::OverloadDetector;
use super::measured::OverloadGauge;
use super::{ShedReport, Shedder, ShedderKind};

/// The pSPICE load shedder (also pSPICE-- — the two differ only in the
/// utility tables the pipeline installs on the operator state).
pub struct PSpiceShedder {
    /// the overload gauge (predicted Alg. 1 regressions or measured
    /// latency EWMAs)
    pub detector: OverloadGauge,
    /// which ablation this instance reports as
    kind: ShedderKind,
    /// total PMs dropped over the run (reporting)
    pub total_dropped: u64,
    /// total shed invocations
    pub invocations: u64,
}

impl PSpiceShedder {
    /// Shedder from a trained predicted-plane detector.  `kind` must be
    /// [`ShedderKind::PSpice`] or [`ShedderKind::PSpiceMinus`].
    pub fn new(detector: OverloadDetector, kind: ShedderKind) -> Self {
        Self::from_gauge(OverloadGauge::Predicted(detector), kind)
    }

    /// Shedder from either overload plane.
    pub fn from_gauge(gauge: OverloadGauge, kind: ShedderKind) -> Self {
        assert!(
            matches!(kind, ShedderKind::PSpice | ShedderKind::PSpiceMinus),
            "PSpiceShedder only instantiates the pspice ablations"
        );
        PSpiceShedder {
            detector: gauge,
            kind,
            total_dropped: 0,
            invocations: 0,
        }
    }
}

impl Shedder for PSpiceShedder {
    fn kind(&self) -> ShedderKind {
        self.kind
    }

    fn on_batch(
        &mut self,
        _events: &[Event],
        l_q_ns: f64,
        state: &mut dyn OperatorState,
    ) -> ShedReport {
        let n_pm = state.pm_count();
        let Some(rho) = self
            .detector
            .check_scaled(l_q_ns, n_pm, state.parallelism())
        else {
            return ShedReport::default();
        };
        let shed = state.shed_lowest(rho);
        self.total_dropped += shed.dropped as u64;
        self.invocations += 1;
        // shards shed in parallel: the virtual cost is the slowest
        // shard's O(cells) decision + O(dropped) removal (one shard ⇒
        // exactly the paper's l_s, with the scan charged per cell)
        let cost_ns = shed
            .per_shard
            .iter()
            .map(|&(scanned, dropped)| state.cost().shed_ns(scanned, dropped))
            .fold(0.0f64, f64::max);
        self.detector.observe_shedding(shed.scanned, cost_ns);
        ShedReport {
            dropped_pms: shed.dropped as u64,
            cost_ns,
            ..ShedReport::default()
        }
    }

    fn observe_batch(&mut self, n_pm: usize, events: usize, cost_ns: f64) {
        self.detector.observe_batch(n_pm, events, cost_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::model::{ModelBuilder, ModelConfig};
    use crate::operator::Operator;
    use crate::query::builtin::q4;
    use crate::runtime::FallbackEngine;

    fn setup() -> (Operator, PSpiceShedder) {
        let mut op = Operator::new(q4(6, 4000, 200).queries);
        let mut g = BusGen::with_seed(7);
        for _ in 0..40_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 100,
                max_bins: 64,
                use_tau: true,
            },
            Box::new(FallbackEngine),
        );
        let tables = mb.build(&op).unwrap();
        op.install_tables(&tables);
        let det = OverloadDetector::new(1e9, 0.0);
        (op, PSpiceShedder::new(det, ShedderKind::PSpice))
    }

    #[test]
    fn untrained_detector_is_noop() {
        let (mut op, mut shed) = setup();
        let before = op.pm_count();
        let e = Event::new(0, 0, 0, &[0.0, 0.0, 0.0, 0.0]);
        let rep = shed.on_batch(&[e], 0.0, &mut op);
        assert_eq!(rep, ShedReport::default());
        assert_eq!(op.pm_count(), before);
    }

    #[test]
    fn trained_detector_drops_under_pressure() {
        let (mut op, mut shed) = setup();
        // steep linear world: current population is far over budget
        let mut det = OverloadDetector::new(1_000.0, 0.0);
        for n in (0..100).map(|i| i * 50) {
            det.observe_processing(n, 10.0 * n as f64);
            det.observe_shedding(n, n as f64);
        }
        assert!(det.fit());
        shed.detector = OverloadGauge::Predicted(det);
        let before = op.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let e = Event::new(0, 0, 0, &[0.0, 0.0, 0.0, 0.0]);
        let rep = shed.on_batch(&[e], 0.0, &mut op);
        assert!(rep.dropped_pms > 0);
        assert_eq!(rep.dropped_events, 0);
        assert!(rep.cost_ns > 0.0);
        assert_eq!(op.pm_count() as u64, before as u64 - rep.dropped_pms);
        assert_eq!(shed.total_dropped, rep.dropped_pms);
        assert!(shed.event_mask().is_none(), "white-box: no event mask");
    }
}
