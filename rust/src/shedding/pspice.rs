//! pSPICE (paper Algorithm 2): drop the ρ lowest-utility partial
//! matches, with utilities looked up in the precomputed tables.
//!
//! Selection uses `select_nth_unstable` (expected O(n)) instead of the
//! paper's full sort (O(n log n)) — strictly better than the complexity
//! the paper budgets for, and measured in `benches/shed_overhead.rs`.

use std::collections::HashSet;

use crate::events::Event;
use crate::model::UtilityTable;
use crate::operator::{Operator, PmRef};
use crate::runtime::ShardedOperator;

use super::detector::OverloadDetector;
use super::{ShedReport, Shedder};

/// The pSPICE load shedder.
pub struct PSpiceShedder {
    /// shared overload detector (Alg. 1)
    pub detector: OverloadDetector,
    /// per-query utility tables from the model builder
    pub tables: Vec<UtilityTable>,
    /// scratch buffer reused across calls (no hot-path allocation)
    scratch: Vec<PmRef>,
    /// keyed scratch for selection
    keyed: Vec<(f64, u64)>,
    /// total PMs dropped over the run (reporting)
    pub total_dropped: u64,
    /// total shed invocations
    pub invocations: u64,
}

impl PSpiceShedder {
    /// Shedder from a trained detector + tables.
    pub fn new(detector: OverloadDetector, tables: Vec<UtilityTable>) -> Self {
        PSpiceShedder {
            detector,
            tables,
            scratch: Vec::new(),
            keyed: Vec::new(),
            total_dropped: 0,
            invocations: 0,
        }
    }

    /// Utility of one PM (O(1) table lookup).
    #[inline]
    pub fn utility(&self, r: &PmRef) -> f64 {
        self.tables[r.query].lookup(r.state, r.remaining)
    }

    /// Algorithm 2: drop the `rho` lowest-utility PMs.  Returns
    /// (scanned, dropped).
    pub fn drop_lowest(&mut self, op: &mut Operator, rho: usize) -> (usize, usize) {
        op.pm_refs(&mut self.scratch);
        let n = self.scratch.len();
        if n == 0 || rho == 0 {
            return (n, 0);
        }
        let rho = rho.min(n);
        self.keyed.clear();
        self.keyed.reserve(n);
        for r in &self.scratch {
            self.keyed.push((self.tables[r.query].lookup(r.state, r.remaining), r.pm_id));
        }
        if rho < n {
            // total_cmp, not partial_cmp().unwrap(): a NaN utility (e.g.
            // from a degenerate table row) must not panic the hot path.
            // total order puts +NaN above every number, so poisoned PMs
            // are treated as high-utility and survive.
            self.keyed
                .select_nth_unstable_by(rho - 1, |a, b| a.0.total_cmp(&b.0));
        }
        let ids: HashSet<u64> = self.keyed[..rho].iter().map(|&(_, id)| id).collect();
        let dropped = op.drop_pms(&ids);
        (n, dropped)
    }

    /// Shard-aware Algorithm 2 for the sharded runtime: the detector
    /// sees the *global* `n_pm` and the batch queueing latency (scaled
    /// by the shard count), computes one global ρ, and the sharded
    /// operator drops the ρ globally lowest-utility PMs via a k-way
    /// merge over per-shard candidates.  Utility tables must have been
    /// installed on the workers with
    /// [`ShardedOperator::set_tables`].
    pub fn on_batch(&mut self, l_q_ns: f64, sop: &mut ShardedOperator) -> ShedReport {
        let n_pm = sop.pm_count();
        let Some(rho) = self.detector.check_scaled(l_q_ns, n_pm, sop.n_shards())
        else {
            return ShedReport::default();
        };
        let shed = sop.shed_lowest(rho);
        self.total_dropped += shed.dropped as u64;
        self.invocations += 1;
        // shards shed in parallel: the virtual cost is the slowest
        // shard's scan + drop
        let cost_ns = shed
            .per_shard
            .iter()
            .map(|&(scanned, dropped)| sop.cost.shed_ns(scanned, dropped))
            .fold(0.0f64, f64::max);
        self.detector.observe_shedding(shed.scanned, cost_ns);
        ShedReport {
            dropped_pms: shed.dropped,
            dropped_event: false,
            cost_ns,
        }
    }
}

impl Shedder for PSpiceShedder {
    fn name(&self) -> &'static str {
        "pspice"
    }

    fn update_tables(&mut self, tables: Vec<crate::model::UtilityTable>) {
        self.tables = tables;
    }

    fn on_event(&mut self, _e: &Event, l_q_ns: f64, op: &mut Operator) -> ShedReport {
        let n_pm = op.pm_count();
        let Some(rho) = self.detector.check(l_q_ns, n_pm) else {
            return ShedReport::default();
        };
        let (scanned, dropped) = self.drop_lowest(op, rho);
        self.total_dropped += dropped as u64;
        self.invocations += 1;
        let cost_ns = op.cost.shed_ns(scanned, dropped);
        self.detector.observe_shedding(scanned, cost_ns);
        ShedReport {
            dropped_pms: dropped,
            dropped_event: false,
            cost_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::BusGen;
    use crate::events::EventStream;
    use crate::model::{ModelBuilder, ModelConfig};
    use crate::query::builtin::q4;
    use crate::runtime::FallbackEngine;

    fn setup() -> (Operator, PSpiceShedder) {
        let mut op = Operator::new(q4(6, 4000, 200).queries);
        let mut g = BusGen::with_seed(7);
        for _ in 0..40_000 {
            op.process_event(&g.next_event().unwrap());
        }
        let mut mb = ModelBuilder::new(
            ModelConfig {
                eta: 100,
                max_bins: 64,
                use_tau: true,
            },
            Box::new(FallbackEngine),
        );
        let tables = mb.build(&op).unwrap();
        let det = OverloadDetector::new(1e9, 0.0);
        (op, PSpiceShedder::new(det, tables))
    }

    #[test]
    fn drops_exactly_rho() {
        let (mut op, mut shed) = setup();
        let before = op.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let (scanned, dropped) = shed.drop_lowest(&mut op, 10);
        assert_eq!(scanned, before);
        assert_eq!(dropped, 10);
        assert_eq!(op.pm_count(), before - 10);
    }

    #[test]
    fn drops_the_lowest_utilities() {
        let (mut op, mut shed) = setup();
        let mut refs = Vec::new();
        op.pm_refs(&mut refs);
        let mut utils: Vec<f64> = refs.iter().map(|r| shed.utility(r)).collect();
        utils.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rho = 8;
        let threshold = utils[rho - 1];
        shed.drop_lowest(&mut op, rho);
        // every survivor has utility >= the rho-th smallest
        let mut after = Vec::new();
        op.pm_refs(&mut after);
        for r in &after {
            assert!(
                shed.utility(r) >= threshold - 1e-12,
                "survivor below threshold"
            );
        }
    }

    #[test]
    fn nan_utilities_do_not_panic_selection() {
        // regression: partial_cmp().unwrap() panicked when a utility
        // table was poisoned with NaN; total_cmp must select anyway
        let (mut op, mut shed) = setup();
        for table in &mut shed.tables {
            for row in &mut table.rows {
                for (i, v) in row.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *v = f64::NAN;
                    }
                }
            }
        }
        let before = op.pm_count();
        assert!(before > 20, "need PMs, got {before}");
        let rho = 10;
        let (scanned, dropped) = shed.drop_lowest(&mut op, rho);
        assert_eq!(scanned, before);
        assert_eq!(dropped, rho, "exactly rho victims despite NaNs");
        assert_eq!(op.pm_count(), before - rho);
        // NaN-utility PMs sort above every real utility, so survivors
        // may carry NaN but no finite-utility PM above the threshold
        // was sacrificed for one
        let mut after = Vec::new();
        op.pm_refs(&mut after);
        assert_eq!(after.len(), before - rho);
    }

    #[test]
    fn rho_larger_than_population_drops_all() {
        let (mut op, mut shed) = setup();
        let before = op.pm_count();
        let (_, dropped) = shed.drop_lowest(&mut op, before + 1000);
        assert_eq!(dropped, before);
        assert_eq!(op.pm_count(), 0);
    }

    #[test]
    fn untrained_detector_is_noop() {
        let (mut op, mut shed) = setup();
        let before = op.pm_count();
        let e = Event::new(0, 0, 0, &[0.0, 0.0, 0.0, 0.0]);
        let rep = shed.on_event(&e, 0.0, &mut op);
        assert_eq!(rep, ShedReport::default());
        assert_eq!(op.pm_count(), before);
    }
}
