//! E-BL (paper §IV-A): the black-box event-shedding baseline in the
//! style of He et al. with the weighted-sampling flavor of Aurora-style
//! stream shedding.
//!
//! Events get a *type utility* proportional to how often their key
//! value (stock symbol / player id / bus id) is referenced by the
//! operator's patterns; within a utility class, victims are picked by
//! uniform sampling.  A proportional controller adapts the drop
//! fraction to keep the estimated event latency under LB.
//!
//! Because E-BL drops *events* (not PMs), it must drop in every window
//! the event belongs to, which is what makes its overhead grow with
//! window overlap (paper Fig. 9a) — modeled here by charging the drop
//! decision per open window.  Victims are reported through
//! [`Shedder::event_mask`]: the operator state gives masked events
//! window bookkeeping only.
//!
//! The per-key-value utilities live in the model plane's
//! [`KeyUtilityTable`] — built once from the query set and `Arc`-shared
//! with the pipeline's [`crate::model::TableSet`] snapshot, so the
//! black-box strategy reads the same versioned model plane the
//! white-box ones do.

use std::sync::Arc;

use crate::events::{DropMask, Event};
use crate::model::plane::KeyUtilityTable;
use crate::operator::OperatorState;
use crate::util::Rng;

use super::detector::OverloadDetector;
use super::measured::OverloadGauge;
use super::{ShedReport, Shedder, ShedderKind};

/// The event-shedding baseline.
pub struct EventBaselineShedder {
    /// overload gauge reused for the latency estimate (not for ρ)
    pub detector: OverloadGauge,
    /// shared per-key-value pattern utilities (the model plane's
    /// key-slot table)
    key: Arc<KeyUtilityTable>,
    /// current drop fraction in [0, max_drop]
    pub drop_p: f64,
    /// controller gain
    gain: f64,
    /// hard cap on the drop fraction
    max_drop: f64,
    /// victim sampling
    rng: Rng,
    /// running mean of the inverse-utility weight (drop-rate normalizer)
    mean_w: f64,
    /// per-event drop mask for the last batch (see `event_mask`) —
    /// word-packed and reused across batches, never reallocated
    mask: DropMask,
    /// total events dropped (reporting)
    pub total_dropped: u64,
}

impl EventBaselineShedder {
    /// Shedder on the predicted plane reading the given `Arc`-shared
    /// key-utility table (see [`KeyUtilityTable::from_queries`] for how
    /// it is built).
    pub fn new(detector: OverloadDetector, key: Arc<KeyUtilityTable>, seed: u64) -> Self {
        Self::from_gauge(OverloadGauge::Predicted(detector), key, seed)
    }

    /// Shedder from either overload plane.
    pub fn from_gauge(gauge: OverloadGauge, key: Arc<KeyUtilityTable>, seed: u64) -> Self {
        EventBaselineShedder {
            detector: gauge,
            key,
            drop_p: 0.0,
            gain: 0.5,
            max_drop: 0.95,
            rng: Rng::seeded(seed),
            mean_w: 1.0,
            mask: DropMask::default(),
            total_dropped: 0,
        }
    }

    /// The shared key-utility table this strategy reads.
    pub fn key_table(&self) -> &Arc<KeyUtilityTable> {
        &self.key
    }

    /// Utility of an event's key value (0 for values no pattern uses).
    #[inline]
    pub fn event_utility(&self, e: &Event) -> f64 {
        self.key.utility(e)
    }
}

impl Shedder for EventBaselineShedder {
    fn kind(&self) -> ShedderKind {
        ShedderKind::EventBaseline
    }

    fn on_batch(
        &mut self,
        events: &[Event],
        l_q_ns: f64,
        state: &mut dyn OperatorState,
    ) -> ShedReport {
        let k = state.parallelism() as f64;
        self.mask.reset(events.len());
        if self.detector.trained() {
            let lb = self.detector.lb_ns();
            let l_e =
                l_q_ns + self.detector.estimate_lp_scaled(state.pm_count(), state.parallelism());
            // proportional control on the relative bound violation: one
            // controller step covers the whole batch, so the
            // integration scales with the batch size.  Within a
            // multi-event batch there is no feedback shrinking the
            // error, so the per-decision movement is clamped (an
            // unclamped batch step turns the controller bang-bang);
            // per-event dispatch (batches of one) keeps the paper's
            // unclamped proportional step.
            let err = (l_e - lb) / lb;
            let mut step = self.gain * err * events.len() as f64;
            if events.len() > 1 {
                step = step.clamp(-0.1, 0.1);
            }
            self.drop_p = (self.drop_p + step).clamp(0.0, self.max_drop);
        }
        if self.drop_p <= 0.0 {
            return ShedReport::default();
        }
        // the drop decision is made in EVERY window the event belongs
        // to (black-box granularity — the paper's Fig. 9a overhead),
        // in parallel across shards
        let per_event_ns =
            state.cost().ebl_per_window_ns * state.open_windows().max(1) as f64;
        let mut dropped = 0u64;
        for (i, e) in events.iter().enumerate() {
            // weighted sampling (paper: "uniform sampling ... from the
            // same event type"): each type's drop probability is
            // proportional to the inverse-square of its pattern
            // utility, normalized by a running mean so the realized
            // drop rate tracks `drop_p`.
            let u = self.event_utility(e);
            let w = 1.0 / (1.0 + u) / (1.0 + u);
            self.mean_w = 0.999 * self.mean_w + 0.001 * w;
            let p = (self.drop_p * w / self.mean_w.max(1e-6)).clamp(0.0, 1.0);
            if self.rng.chance(p) {
                self.mask.mark(i);
                dropped += 1;
            }
        }
        self.total_dropped += dropped;
        ShedReport {
            dropped_events: dropped,
            cost_ns: per_event_ns * events.len() as f64 / k,
            ..ShedReport::default()
        }
    }

    fn event_mask(&self) -> Option<&DropMask> {
        Some(&self.mask)
    }

    fn observe_batch(&mut self, n_pm: usize, events: usize, cost_ns: f64) {
        self.detector.observe_batch(n_pm, events, cost_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::stock;
    use crate::operator::Operator;
    use crate::query::builtin::q1;

    fn shedder() -> (Operator, EventBaselineShedder) {
        let op = Operator::new(q1(1000).queries);
        let det = OverloadDetector::new(1_000_000.0, 0.0);
        let key = Arc::new(KeyUtilityTable::from_compiled(
            stock::A_SYMBOL,
            &op.queries,
        ));
        let s = EventBaselineShedder::new(det, key, 3);
        assert!(!s.key_table().is_empty());
        (op, s)
    }

    #[test]
    fn pattern_symbols_have_utility() {
        let (_, s) = shedder();
        // the pattern ranks appear in Q1's rising+falling variants
        for sym in crate::query::builtin::PATTERN_RANKS {
            let e = Event::new(0, 0, 0, &[sym as f64, 1.0, 1.0]);
            assert!(s.event_utility(&e) >= 2.0, "sym={sym}");
        }
        // symbol 400 appears nowhere
        let e = Event::new(0, 0, 0, &[400.0, 1.0, 1.0]);
        assert_eq!(s.event_utility(&e), 0.0);
    }

    #[test]
    fn no_drops_without_pressure() {
        let (mut op, mut s) = shedder();
        let e = Event::new(0, 0, 0, &[400.0, 1.0, 1.0]);
        let rep = s.on_batch(&[e], 0.0, &mut op);
        assert_eq!(rep.dropped_events, 0);
        assert_eq!(s.drop_p, 0.0);
        let mask = s.event_mask().expect("E-BL always reports a mask");
        assert_eq!(mask.len(), 1);
        assert!(!mask.get(0));
    }

    #[test]
    fn controller_raises_drop_p_under_pressure() {
        let (mut op, mut s) = shedder();
        // train the detector on a steep linear model
        let mut det = OverloadDetector::new(1_000_000.0, 0.0);
        for n in (0..100).map(|i| i * 100) {
            det.observe_processing(n, 1_000.0 * n as f64);
        }
        det.fit();
        s.detector = OverloadGauge::Predicted(det);
        // massive queueing latency: controller must react
        for seq in 0..50 {
            let e = Event::new(seq, seq, 0, &[400.0, 1.0, 1.0]);
            s.on_batch(&[e], 10_000_000.0, &mut op);
        }
        assert!(s.drop_p > 0.5, "drop_p={}", s.drop_p);
        // and unused symbols get dropped much more often than pattern symbols
        let mut dropped_junk = 0;
        let mut dropped_pattern = 0;
        for seq in 0..2000 {
            let junk = Event::new(seq, seq, 0, &[400.0, 1.0, 1.0]);
            let pat = Event::new(seq, seq, 0, &[30.0, 1.0, 1.0]);
            if s.on_batch(&[junk], 10_000_000.0, &mut op).dropped_events > 0 {
                dropped_junk += 1;
            }
            if s.on_batch(&[pat], 10_000_000.0, &mut op).dropped_events > 0 {
                dropped_pattern += 1;
            }
        }
        assert!(
            dropped_junk > dropped_pattern,
            "junk={dropped_junk} pattern={dropped_pattern}"
        );
    }

    #[test]
    fn batch_masks_cover_every_event() {
        let (mut op, mut s) = shedder();
        let mut det = OverloadDetector::new(1_000_000.0, 0.0);
        for n in (0..100).map(|i| i * 100) {
            det.observe_processing(n, 1_000.0 * n as f64);
        }
        det.fit();
        s.detector = OverloadGauge::Predicted(det);
        let events: Vec<Event> = (0..64)
            .map(|seq| Event::new(seq, seq, 0, &[400.0, 1.0, 1.0]))
            .collect();
        // several batches under pressure: the mask always matches the
        // batch length and the report counts its set bits
        for _ in 0..20 {
            let rep = s.on_batch(&events, 10_000_000.0, &mut op);
            let mask = s.event_mask().unwrap();
            assert_eq!(mask.len(), events.len());
            assert_eq!(mask.count() as u64, rep.dropped_events);
        }
        assert!(s.drop_p > 0.0);
    }
}
