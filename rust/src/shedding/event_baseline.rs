//! E-BL (paper §IV-A): the black-box event-shedding baseline in the
//! style of He et al. [15] with the weighted-sampling flavor of
//! Aurora-style stream shedding [13].
//!
//! Events get a *type utility* proportional to how often their key
//! value (stock symbol / player id / bus id) is referenced by the
//! operator's patterns; within a utility class, victims are picked by
//! uniform sampling.  A proportional controller adapts the drop
//! fraction to keep the estimated event latency under LB.
//!
//! Because E-BL drops *events* (not PMs), it must drop in every window
//! the event belongs to, which is what makes its overhead grow with
//! window overlap (paper Fig. 9a) — modeled here by charging the drop
//! decision per open window.

use std::collections::HashMap;

use crate::events::Event;
use crate::nfa::machine::CompiledQuery;
use crate::operator::Operator;
use crate::query::Predicate;
use crate::runtime::ShardedOperator;
use crate::util::Rng;

use super::detector::OverloadDetector;
use super::{ShedReport, Shedder};

/// The event-shedding baseline.
pub struct EventBaselineShedder {
    /// detector reused for the latency estimate (not for ρ)
    pub detector: OverloadDetector,
    /// attribute slot holding the event's key value (symbol/player/bus)
    pub key_slot: usize,
    /// utility per key value (occurrences in patterns)
    utilities: HashMap<i64, f64>,
    /// current drop fraction in [0, max_drop]
    pub drop_p: f64,
    /// controller gain
    gain: f64,
    /// hard cap on the drop fraction
    max_drop: f64,
    /// victim sampling
    rng: Rng,
    /// running mean of the inverse-utility weight (drop-rate normalizer)
    mean_w: f64,
    /// total events dropped (reporting)
    pub total_dropped: u64,
}

impl EventBaselineShedder {
    /// Build the per-key-value utilities from the operator's queries:
    /// each reference to a concrete key value in a pattern raises that
    /// value's utility (paper: "an event type receives a higher utility
    /// proportional to its repetition in patterns and in windows").
    pub fn new(detector: OverloadDetector, key_slot: usize, queries: &[CompiledQuery], seed: u64) -> Self {
        let mut utilities: HashMap<i64, f64> = HashMap::new();
        let mut bump = |preds: &[Predicate]| {
            for p in preds {
                match p {
                    Predicate::AttrCmp { slot, value, .. } if *slot == key_slot => {
                        *utilities.entry(*value as i64).or_insert(0.0) += 1.0;
                    }
                    Predicate::AttrIn { slot, values } if *slot == key_slot => {
                        for v in values {
                            *utilities.entry(*v as i64).or_insert(0.0) += 1.0;
                        }
                    }
                    _ => {}
                }
            }
        };
        for cq in queries {
            for s in &cq.head {
                bump(&s.preds);
            }
            if let Some(g) = &cq.any {
                bump(&g.spec.preds);
            }
        }
        EventBaselineShedder {
            detector,
            key_slot,
            utilities,
            drop_p: 0.0,
            gain: 0.5,
            max_drop: 0.95,
            rng: Rng::seeded(seed),
            mean_w: 1.0,
            total_dropped: 0,
        }
    }

    /// Utility of an event's key value (0 for values no pattern uses).
    #[inline]
    pub fn event_utility(&self, e: &Event) -> f64 {
        let key = e.attrs[self.key_slot] as i64;
        self.utilities.get(&key).copied().unwrap_or(0.0)
    }

    /// Adapt the drop fraction from the current latency estimate.
    fn adapt(&mut self, l_q_ns: f64, n_pm: usize) {
        let lb = self.detector.lb_ns;
        let l_e = l_q_ns + self.detector.predict_lp(n_pm);
        // proportional control on the relative bound violation
        let err = (l_e - lb) / lb;
        self.drop_p = (self.drop_p + self.gain * err).clamp(0.0, self.max_drop);
    }

    /// Shard-aware E-BL: adapt once per batch from the global latency
    /// estimate (predicted processing scaled by the shard count), then
    /// sample a per-event drop mask for
    /// [`ShardedOperator::process_batch_masked`].  Returns the mask,
    /// the number of dropped events, and the virtual drop-decision cost
    /// (per open window, parallel across shards — the paper's Fig. 9a
    /// overhead shape survives sharding).
    pub fn decide_batch(
        &mut self,
        l_q_ns: f64,
        sop: &ShardedOperator,
        events: &[Event],
    ) -> (Vec<bool>, u64, f64) {
        let n_shards = sop.n_shards() as f64;
        if self.detector.trained() {
            let lb = self.detector.lb_ns;
            let l_e =
                l_q_ns + self.detector.predict_lp(sop.pm_count()) / n_shards;
            let err = (l_e - lb) / lb;
            // one controller step covers the whole batch: scale the
            // integration by the batch size to match the per-event
            // controller's ramp, but clamp the per-decision movement —
            // within a batch there is no feedback shrinking the error,
            // so an unclamped step turns the controller bang-bang
            let step = (self.gain * err * events.len() as f64).clamp(-0.1, 0.1);
            self.drop_p = (self.drop_p + step).clamp(0.0, self.max_drop);
        }
        let mut mask = vec![false; events.len()];
        if self.drop_p <= 0.0 {
            return (mask, 0, 0.0);
        }
        let per_event_ns =
            sop.cost.ebl_per_window_ns * sop.open_windows().max(1) as f64;
        let mut dropped = 0u64;
        for (i, e) in events.iter().enumerate() {
            let u = self.event_utility(e);
            let w = 1.0 / (1.0 + u) / (1.0 + u);
            self.mean_w = 0.999 * self.mean_w + 0.001 * w;
            let p = (self.drop_p * w / self.mean_w.max(1e-6)).clamp(0.0, 1.0);
            if self.rng.chance(p) {
                mask[i] = true;
                dropped += 1;
            }
        }
        self.total_dropped += dropped;
        let cost_ns = per_event_ns * events.len() as f64 / n_shards;
        (mask, dropped, cost_ns)
    }
}

impl Shedder for EventBaselineShedder {
    fn name(&self) -> &'static str {
        "e-bl"
    }

    fn on_event(&mut self, e: &Event, l_q_ns: f64, op: &mut Operator) -> ShedReport {
        if self.detector.trained() {
            self.adapt(l_q_ns, op.pm_count());
        }
        if self.drop_p <= 0.0 {
            return ShedReport::default();
        }
        // weighted sampling (paper: "uniform sampling ... from the same
        // event type"): each type's drop probability is proportional to
        // the inverse-square of its pattern utility, normalized by a
        // running mean so the realized drop rate tracks `drop_p`.
        let u = self.event_utility(e);
        let w = 1.0 / (1.0 + u) / (1.0 + u);
        self.mean_w = 0.999 * self.mean_w + 0.001 * w;
        let p = (self.drop_p * w / self.mean_w.max(1e-6)).clamp(0.0, 1.0);
        let dropped = self.rng.chance(p);
        // the drop decision is made in EVERY window the event belongs
        // to (black-box granularity — the paper's Fig. 9a overhead)
        let open_windows: usize = op.wins.iter().map(|q| q.windows.len()).sum();
        let cost_ns = op.cost.ebl_per_window_ns * open_windows.max(1) as f64;
        if dropped {
            self.total_dropped += 1;
            ShedReport {
                dropped_pms: 0,
                dropped_event: true,
                cost_ns,
            }
        } else {
            ShedReport {
                dropped_pms: 0,
                dropped_event: false,
                cost_ns: if self.drop_p > 0.0 { cost_ns } else { 0.0 },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::stock;
    use crate::query::builtin::q1;

    fn shedder() -> (Operator, EventBaselineShedder) {
        let op = Operator::new(q1(1000).queries);
        let det = OverloadDetector::new(1_000_000.0, 0.0);
        let s = EventBaselineShedder::new(det, stock::A_SYMBOL, &op.queries, 3);
        (op, s)
    }

    #[test]
    fn pattern_symbols_have_utility() {
        let (_, s) = shedder();
        // the pattern ranks appear in Q1's rising+falling variants
        for sym in crate::query::builtin::PATTERN_RANKS {
            let e = Event::new(0, 0, 0, &[sym as f64, 1.0, 1.0]);
            assert!(s.event_utility(&e) >= 2.0, "sym={sym}");
        }
        // symbol 400 appears nowhere
        let e = Event::new(0, 0, 0, &[400.0, 1.0, 1.0]);
        assert_eq!(s.event_utility(&e), 0.0);
    }

    #[test]
    fn no_drops_without_pressure() {
        let (mut op, mut s) = shedder();
        let e = Event::new(0, 0, 0, &[400.0, 1.0, 1.0]);
        let rep = s.on_event(&e, 0.0, &mut op);
        assert!(!rep.dropped_event);
        assert_eq!(s.drop_p, 0.0);
    }

    #[test]
    fn controller_raises_drop_p_under_pressure() {
        let (mut op, mut s) = shedder();
        // train the detector on a steep linear model
        for n in (0..100).map(|i| i * 100) {
            s.detector.observe_processing(n, 1_000.0 * n as f64);
        }
        s.detector.fit();
        // massive queueing latency: controller must react
        for seq in 0..50 {
            let e = Event::new(seq, seq, 0, &[400.0, 1.0, 1.0]);
            s.on_event(&e, 10_000_000.0, &mut op);
        }
        assert!(s.drop_p > 0.5, "drop_p={}", s.drop_p);
        // and unused symbols get dropped much more often than pattern symbols
        let mut dropped_junk = 0;
        let mut dropped_pattern = 0;
        for seq in 0..2000 {
            let junk = Event::new(seq, seq, 0, &[400.0, 1.0, 1.0]);
            let pat = Event::new(seq, seq, 0, &[30.0, 1.0, 1.0]);
            if s.on_event(&junk, 10_000_000.0, &mut op).dropped_event {
                dropped_junk += 1;
            }
            if s.on_event(&pat, 10_000_000.0, &mut op).dropped_event {
                dropped_pattern += 1;
            }
        }
        assert!(
            dropped_junk > dropped_pattern,
            "junk={dropped_junk} pattern={dropped_pattern}"
        );
    }
}
