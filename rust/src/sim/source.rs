//! Rate-controlled arrival schedule.
//!
//! The paper streams "at event input rates which are ... higher than
//! the maximum operator throughput by 20%..100%".  [`RateSource`]
//! produces the deterministic arrival time of each event for a target
//! rate expressed as a multiple of measured capacity.

/// Deterministic arrival schedule: event `i` arrives at `i·dt`.
#[derive(Debug, Clone, Copy)]
pub struct RateSource {
    /// inter-arrival gap (virtual ns)
    pub dt_ns: f64,
    /// arrivals start at this offset (ns)
    pub start_ns: f64,
}

impl RateSource {
    /// Source from a measured per-event capacity cost and a rate factor
    /// (1.2 = 120% of max throughput ⇒ arrivals come 1/1.2× as far
    /// apart as the operator can drain them).
    pub fn from_capacity(mean_cost_ns: f64, rate_factor: f64, start_ns: f64) -> Self {
        assert!(mean_cost_ns > 0.0 && rate_factor > 0.0);
        RateSource {
            dt_ns: mean_cost_ns / rate_factor,
            start_ns,
        }
    }

    /// Arrival time of the `i`-th event of this phase.
    #[inline]
    pub fn arrival_ns(&self, i: u64) -> f64 {
        self.start_ns + self.dt_ns * i as f64
    }

    /// Events per second implied by the schedule.
    pub fn rate_per_sec(&self) -> f64 {
        1e9 / self.dt_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overload_factor_shrinks_gap() {
        let base = RateSource::from_capacity(1000.0, 1.0, 0.0);
        let hot = RateSource::from_capacity(1000.0, 2.0, 0.0);
        assert!((base.dt_ns - 1000.0).abs() < 1e-12);
        assert!((hot.dt_ns - 500.0).abs() < 1e-12);
        assert!((hot.rate_per_sec() - 2e6).abs() < 1.0);
    }

    #[test]
    fn arrivals_are_evenly_spaced() {
        let s = RateSource::from_capacity(100.0, 1.25, 50.0);
        assert_eq!(s.arrival_ns(0), 50.0);
        let gap = s.arrival_ns(11) - s.arrival_ns(10);
        assert!((gap - 80.0).abs() < 1e-12);
    }
}
