//! Virtual-time load simulation.
//!
//! The paper overloads a real single-threaded operator with wall-clock
//! event rates.  We reproduce the same queueing dynamics in *virtual
//! time*: events arrive on a deterministic schedule, the operator's
//! clock advances by the cost model's per-event processing cost, and
//! queueing latency is the gap between arrival and processing start.
//! Deterministic, seed-stable, and orders of magnitude faster than
//! wall-clock replay (DESIGN.md §3).
//!
//! Since the real-time ingestion plane, the clock itself is a trait:
//! [`Clock`] is implemented by the virtual [`SimClock`] (bit-exact with
//! the historical runs, pinned by the `pipeline_regression` test) and
//! by [`WallClock`], which anchors the same semantics to monotonic wall
//! time with a virtual offset for fast-forwarding.

pub mod clock;
pub mod source;

pub use clock::{Clock, SimClock, WallClock, WallTimer};
pub use source::RateSource;
